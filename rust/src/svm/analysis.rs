//! The Eq. 7 analytic accuracy model.
//!
//! §3.2 ties the approximation knob (feature count `p`) to the expected
//! accuracy: the probability that the prefix classification is coherent
//! with the full-feature one,
//!
//! `P(class_p == class_n) = 2 ∫₀^∞ f_{S_p}(k) (1 − F_{R_p}(k)) dk`  (Eq. 7)
//!
//! where `S_p` is the partial score and `R_p` the residual contribution
//! of the unprocessed features. Both are sums of per-feature terms
//! `c_j·x_j`, so with (approximately) independent features they are
//! normal with moments accumulated from training data.
//!
//! * binary case, zero-mean symmetric: Eq. 7 verbatim, by quadrature;
//! * binary case, general means: the sign-coherence double integral;
//! * multi-class: the fitted-Gaussian model evaluated by deterministic
//!   Monte Carlo over class-score vectors (the "computed numerically"
//!   route the paper takes for Eq. 8/9), yielding the whole curve
//!   `p → P(class_p == class_n)` in one pass.

use crate::svm::anytime::AnytimeSvm;
use crate::svm::model::argmax;
use crate::util::rng::Rng;
use crate::util::stats::{integrate_to_inf, normal_cdf, normal_pdf};

/// Moments of the per-feature score contributions `z_j = c_j·x_j` for one
/// binary problem, in anytime processing order.
#[derive(Clone, Debug)]
pub struct TermMoments {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

impl TermMoments {
    /// Estimate from data: `z_ij = c_j·x_ij` over rows of standardised
    /// features and one weight vector, in the given feature order.
    pub fn estimate(weights: &[f64], rows_scaled: &[Vec<f64>], order: &[usize]) -> TermMoments {
        let m = rows_scaled.len().max(1) as f64;
        let mut mean = vec![0.0; order.len()];
        let mut var = vec![0.0; order.len()];
        for (k, &j) in order.iter().enumerate() {
            let mut s = 0.0;
            let mut s2 = 0.0;
            for r in rows_scaled {
                let z = weights[j] * r[j];
                s += z;
                s2 += z * z;
            }
            mean[k] = s / m;
            var[k] = (s2 / m - mean[k] * mean[k]).max(0.0);
        }
        TermMoments { mean, var }
    }

    /// Moments of `S_p` (prefix sum of the first `p` terms).
    pub fn prefix(&self, p: usize) -> (f64, f64) {
        (self.mean[..p].iter().sum(), self.var[..p].iter().sum())
    }

    /// Moments of `R_p` (residual: terms `p..n`).
    pub fn residual(&self, p: usize) -> (f64, f64) {
        (self.mean[p..].iter().sum(), self.var[p..].iter().sum())
    }
}

/// Eq. 7 for the symmetric zero-mean binary case:
/// `2 ∫₀^∞ f_S(k)·(1 − F_R(k)) dk` with S ~ N(0, var_s), R ~ N(0, var_r).
pub fn coherence_binary_symmetric(var_s: f64, var_r: f64) -> f64 {
    if var_s <= 0.0 {
        // No processed signal: the sign of S is degenerate; coherence is
        // the chance level 1/2.
        return 0.5;
    }
    if var_r <= 0.0 {
        return 1.0; // nothing left out
    }
    let sd_s = var_s.sqrt();
    let sd_r = var_r.sqrt();
    2.0 * integrate_to_inf(
        |k| normal_pdf(k, 0.0, sd_s) * (1.0 - normal_cdf(-k, 0.0, sd_r)),
        0.0,
        200,
    )
}

/// General binary sign-coherence: `P(sign(S) == sign(S + R))` with
/// independent `S ~ N(mu_s, var_s)` and `R ~ N(mu_r, var_r)`.
pub fn coherence_binary(mu_s: f64, var_s: f64, mu_r: f64, var_r: f64) -> f64 {
    if var_s <= 1e-18 {
        // Degenerate S: sign fixed at sign(mu_s).
        if var_r <= 1e-18 {
            return if (mu_s + mu_r) * mu_s >= 0.0 { 1.0 } else { 0.0 };
        }
        let sd_r = var_r.sqrt();
        return if mu_s >= 0.0 {
            1.0 - normal_cdf(-mu_s, mu_r, sd_r)
        } else {
            normal_cdf(-mu_s, mu_r, sd_r)
        };
    }
    if var_r <= 1e-18 {
        // Deterministic residual shift.
        let sd_s = var_s.sqrt();
        // P(S>0, S+mu_r>0) + P(S<0, S+mu_r<0)
        let a = 0.0f64.max(-mu_r);
        let b = 0.0f64.min(-mu_r);
        return (1.0 - normal_cdf(a, mu_s, sd_s)) + normal_cdf(b, mu_s, sd_s);
    }
    let sd_s = var_s.sqrt();
    let sd_r = var_r.sqrt();
    // P(S>0, R>-S): integrate f_S(k)·(1-F_R(-k)) over k>0,
    // plus P(S<0, R<-S): integrate f_S(k)·F_R(-k) over k<0 (k→-k).
    let pos = integrate_to_inf(
        |k| normal_pdf(k, mu_s, sd_s) * (1.0 - normal_cdf(-k, mu_r, sd_r)),
        0.0,
        200,
    );
    let neg = integrate_to_inf(
        |k| normal_pdf(-k, mu_s, sd_s) * normal_cdf(k, mu_r, sd_r),
        0.0,
        200,
    );
    pos + neg
}

/// Per-class per-feature Gaussian input model fitted on training data
/// (standardised features), the generative model behind the multi-class
/// numeric evaluation.
#[derive(Clone, Debug)]
pub struct ClassFeatureModel {
    pub classes: usize,
    /// `mean[c][j]`, `var[c][j]` of standardised feature j in class c.
    pub mean: Vec<Vec<f64>>,
    pub var: Vec<Vec<f64>>,
    /// Class prior (fraction of training data).
    pub prior: Vec<f64>,
}

impl ClassFeatureModel {
    pub fn fit(rows_scaled: &[Vec<f64>], labels: &[usize], classes: usize) -> ClassFeatureModel {
        let n = rows_scaled[0].len();
        let mut mean = vec![vec![0.0; n]; classes];
        let mut var = vec![vec![0.0; n]; classes];
        let mut count = vec![0usize; classes];
        for (r, &l) in rows_scaled.iter().zip(labels) {
            count[l] += 1;
            for (j, &v) in r.iter().enumerate() {
                mean[l][j] += v;
            }
        }
        for c in 0..classes {
            let m = count[c].max(1) as f64;
            for j in 0..n {
                mean[c][j] /= m;
            }
        }
        for (r, &l) in rows_scaled.iter().zip(labels) {
            for (j, &v) in r.iter().enumerate() {
                let d = v - mean[l][j];
                var[l][j] += d * d;
            }
        }
        for c in 0..classes {
            let m = count[c].max(1) as f64;
            for j in 0..n {
                var[c][j] = (var[c][j] / m).max(1e-12);
            }
        }
        let total: usize = count.iter().sum();
        let prior = count.iter().map(|&k| k as f64 / total.max(1) as f64).collect();
        ClassFeatureModel { classes, mean, var, prior }
    }
}

/// The multi-class Eq. 7/8/9 evaluation: for each prefix length in `ps`,
/// the probability that the prefix argmax equals the full argmax, under
/// the fitted Gaussian input model. Deterministic given the seed.
pub fn coherence_curve_model(
    asvm: &AnytimeSvm,
    model: &ClassFeatureModel,
    ps: &[usize],
    draws: usize,
    seed: u64,
) -> Vec<f64> {
    let classes = asvm.svm.classes;
    let n = asvm.svm.features;
    let mut rng = Rng::new(seed);
    let mut agree = vec![0usize; ps.len()];
    let mut total = 0usize;
    for c in 0..classes {
        let share = (draws as f64 * model.prior[c]).round() as usize;
        for _ in 0..share.max(1) {
            // Draw a standardised feature vector from class c's model.
            let x: Vec<f64> = (0..n)
                .map(|j| model.mean[c][j] + model.var[c][j].sqrt() * rng.gaussian())
                .collect();
            // Per-class score contributions in anytime order.
            let mut scores = asvm.svm.bias.clone();
            let full: Vec<f64> = (0..classes)
                .map(|h| {
                    asvm.svm.bias[h]
                        + asvm.svm.weights[h].iter().zip(&x).map(|(w, v)| w * v).sum::<f64>()
                })
                .collect();
            let full_class = argmax(&full);
            let mut pi = 0;
            for used in 0..=n {
                if pi < ps.len() && ps[pi] == used {
                    if argmax(&scores) == full_class {
                        agree[pi] += 1;
                    }
                    pi += 1;
                }
                if used < n {
                    let j = asvm.order[used];
                    for (h, s) in scores.iter_mut().enumerate() {
                        *s += asvm.svm.weights[h][j] * x[j];
                    }
                }
            }
            total += 1;
        }
    }
    agree.iter().map(|&a| a as f64 / total.max(1) as f64).collect()
}

/// Expected *accuracy* as a function of the prefix length: coherent
/// prefixes inherit the full model's accuracy; incoherent ones are right
/// at roughly chance (the paper's Fig. 4 blue curve starts at 1/c).
pub fn expected_accuracy(coherence: &[f64], full_accuracy: f64, classes: usize) -> Vec<f64> {
    coherence
        .iter()
        .map(|&q| q * full_accuracy + (1.0 - q) / classes as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::train::{train_ovr, TrainConfig};

    #[test]
    fn symmetric_formula_limits() {
        // All variance processed → certain coherence.
        assert!((coherence_binary_symmetric(1.0, 0.0) - 1.0).abs() < 1e-9);
        // Nothing processed → chance.
        assert!((coherence_binary_symmetric(0.0, 1.0) - 0.5).abs() < 1e-9);
        // Equal split: P = 3/4 for symmetric normals.
        let p = coherence_binary_symmetric(1.0, 1.0);
        assert!((p - 0.75).abs() < 1e-6, "p={p}");
        // Monotone in processed share.
        let lo = coherence_binary_symmetric(0.2, 0.8);
        let hi = coherence_binary_symmetric(0.8, 0.2);
        assert!(hi > lo);
    }

    #[test]
    fn general_binary_reduces_to_symmetric() {
        let a = coherence_binary(0.0, 2.0, 0.0, 1.0);
        let b = coherence_binary_symmetric(2.0, 1.0);
        assert!((a - b).abs() < 1e-6, "a={a} b={b}");
    }

    #[test]
    fn general_binary_against_monte_carlo() {
        let mut rng = Rng::new(123);
        for &(mu_s, var_s, mu_r, var_r) in
            &[(0.5, 1.0, -0.2, 0.5), (-1.0, 0.3, 0.4, 2.0), (0.0, 1.0, 1.0, 1.0)]
        {
            let analytic = coherence_binary(mu_s, var_s, mu_r, var_r);
            let n = 200_000;
            let mut agree = 0;
            for _ in 0..n {
                let s = mu_s + var_s.sqrt() * rng.gaussian();
                let r = mu_r + var_r.sqrt() * rng.gaussian();
                if (s > 0.0) == (s + r > 0.0) {
                    agree += 1;
                }
            }
            let mc = agree as f64 / n as f64;
            assert!(
                (analytic - mc).abs() < 5e-3,
                "analytic={analytic} mc={mc} case=({mu_s},{var_s},{mu_r},{var_r})"
            );
        }
    }

    #[test]
    fn term_moments_prefix_residual_partition() {
        let weights = vec![2.0, -1.0, 0.5];
        let rows = vec![vec![1.0, 0.0, 2.0], vec![-1.0, 1.0, 0.0], vec![0.0, -1.0, 1.0]];
        let order = vec![0, 2, 1];
        let tm = TermMoments::estimate(&weights, &rows, &order);
        let (ms, vs) = tm.prefix(2);
        let (mr, vr) = tm.residual(2);
        let (mt, vt) = tm.prefix(3);
        assert!((ms + mr - mt).abs() < 1e-12);
        assert!((vs + vr - vt).abs() < 1e-12);
    }

    /// The model-based multi-class curve should track the empirical curve
    /// on data drawn from the same distribution.
    #[test]
    fn model_curve_tracks_empirical_curve() {
        // Build a 4-class planted problem (as in anytime tests).
        let mut rng = Rng::new(7);
        let n = 30;
        let mut dirs = vec![vec![0.0; n]; 4];
        let mut drng = Rng::new(99);
        for d in dirs.iter_mut() {
            for (j, v) in d.iter_mut().enumerate() {
                *v = drng.gaussian() * 0.85f64.powi(j as i32);
            }
        }
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for c in 0..4 {
            for _ in 0..150 {
                rows.push(
                    (0..n).map(|j| dirs[c][j] * 2.0 + rng.gaussian()).collect::<Vec<f64>>(),
                );
                labels.push(c);
            }
        }
        let svm = train_ovr(&rows, &labels, 4, &TrainConfig::default());
        let asvm = AnytimeSvm::by_coefficient_magnitude(svm);
        let scaled: Vec<Vec<f64>> = rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
        let model = ClassFeatureModel::fit(&scaled, &labels, 4);
        let ps = [0usize, 5, 10, 20, 30];
        let expected = coherence_curve_model(&asvm, &model, &ps, 4000, 5);
        let measured = asvm.coherence_curve(&rows, &ps);
        for (i, &p) in ps.iter().enumerate() {
            assert!(
                (expected[i] - measured[i]).abs() < 0.12,
                "p={p}: expected={} measured={}",
                expected[i],
                measured[i]
            );
        }
        // And the curve must rise to 1 at p = n.
        assert!((expected[4] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn expected_accuracy_interpolates_chance_to_ceiling() {
        let acc = expected_accuracy(&[1.0 / 6.0, 0.5, 1.0], 0.88, 6);
        assert!(acc[0] < 0.30);
        assert!((acc[2] - 0.88).abs() < 1e-12);
        assert!(acc[0] < acc[1] && acc[1] < acc[2]);
    }
}
