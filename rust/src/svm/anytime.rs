//! Anytime support-vector machines (§3.2).
//!
//! The classification `wᵢ·x = Σⱼ wᵢⱼxⱼ` is computed incrementally over a
//! feature *prefix*: features are processed in decreasing aggregate
//! coefficient magnitude — the order Eq. 6 suggests, since features with
//! small `cⱼ` contribute little to the residual `R` that could flip the
//! argmax — caching partial per-class scores so that each additional
//! feature is one multiply-add per class plus the feature's extraction
//! cost. Stopping after `p` features yields exactly the paper's
//! approximate classification (Eq. 2).

use crate::svm::model::{argmax, OvrSvm};

/// An OvR SVM plus the anytime processing order.
#[derive(Clone, Debug)]
pub struct AnytimeSvm {
    pub svm: OvrSvm,
    /// Feature indices in processing order (most important first).
    pub order: Vec<usize>,
}

impl AnytimeSvm {
    /// Order features by `Σ_c |w_cj|` descending — the magnitude ordering
    /// §3.2 derives and §5.1 validates.
    pub fn by_coefficient_magnitude(svm: OvrSvm) -> AnytimeSvm {
        let n = svm.features;
        let mut idx: Vec<usize> = (0..n).collect();
        let mag: Vec<f64> = (0..n)
            .map(|j| svm.weights.iter().map(|w| w[j].abs()).sum())
            .collect();
        idx.sort_by(|&a, &b| mag[b].partial_cmp(&mag[a]).unwrap());
        AnytimeSvm { svm, order: idx }
    }

    /// A deliberately bad (ascending-magnitude) order, used by the
    /// ablation bench to confirm the ordering matters.
    pub fn by_reverse_magnitude(svm: OvrSvm) -> AnytimeSvm {
        let mut a = AnytimeSvm::by_coefficient_magnitude(svm);
        a.order.reverse();
        a
    }

    /// Start a classification round: scores begin at the biases.
    pub fn begin(&self) -> ScoreState {
        ScoreState { scores: self.svm.bias.clone(), used: 0 }
    }

    /// Fold the next feature (in anytime order) into the partial scores.
    /// `raw` is the full raw feature vector (extraction of the single
    /// feature is the caller's energy-accounted step).
    pub fn add_feature(&self, state: &mut ScoreState, raw: &[f64]) {
        let j = self.order[state.used];
        let xj = self.svm.scaler.apply_one(j, raw[j]);
        for (c, s) in state.scores.iter_mut().enumerate() {
            *s += self.svm.weights[c][j] * xj;
        }
        state.used += 1;
    }

    /// Classification from the current partial scores (Eq. 9 argmax).
    pub fn classify(&self, state: &ScoreState) -> usize {
        argmax(&state.scores)
    }

    /// Convenience: classification using exactly `p` features.
    pub fn classify_with(&self, raw: &[f64], p: usize) -> usize {
        let mut st = self.begin();
        for _ in 0..p.min(self.order.len()) {
            self.add_feature(&mut st, raw);
        }
        self.classify(&st)
    }

    /// Coherence of prefix classifications with the full classification,
    /// measured over a dataset: `out[p] = P(class_p == class_n)` (§3.2's
    /// empirical counterpart, plotted in Fig. 4).
    pub fn coherence_curve(&self, rows: &[Vec<f64>], ps: &[usize]) -> Vec<f64> {
        let mut agree = vec![0usize; ps.len()];
        for raw in rows {
            let full = self.svm.classify(raw);
            let mut st = self.begin();
            let mut pi = 0;
            for used in 0..=self.order.len() {
                if pi < ps.len() && ps[pi] == used {
                    if self.classify(&st) == full {
                        agree[pi] += 1;
                    }
                    pi += 1;
                }
                if used < self.order.len() {
                    self.add_feature(&mut st, raw);
                }
            }
        }
        agree.iter().map(|&a| a as f64 / rows.len().max(1) as f64).collect()
    }

    /// Accuracy against labels for each prefix length in `ps` (Fig. 4's
    /// "measured accuracy").
    pub fn accuracy_curve(&self, rows: &[Vec<f64>], labels: &[usize], ps: &[usize]) -> Vec<f64> {
        let mut correct = vec![0usize; ps.len()];
        for (raw, &label) in rows.iter().zip(labels) {
            let mut st = self.begin();
            let mut pi = 0;
            for used in 0..=self.order.len() {
                if pi < ps.len() && ps[pi] == used {
                    if self.classify(&st) == label {
                        correct[pi] += 1;
                    }
                    pi += 1;
                }
                if used < self.order.len() {
                    self.add_feature(&mut st, raw);
                }
            }
        }
        correct.iter().map(|&a| a as f64 / rows.len().max(1) as f64).collect()
    }
}

/// Cached partial per-class scores (the volatile round state of §4.3 —
/// small enough that *no* persistent state is needed).
#[derive(Clone, Debug)]
pub struct ScoreState {
    pub scores: Vec<f64>,
    /// Features folded in so far.
    pub used: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::train::{train_ovr, TrainConfig};
    use crate::util::rng::Rng;

    /// 4-class problem with planted importance decay: feature j carries
    /// signal ∝ decay^j.
    fn planted(n_features: usize, per_class: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let classes = 4;
        // Random unit directions per class, scaled by importance decay.
        let mut dirs = vec![vec![0.0; n_features]; classes];
        let mut drng = Rng::new(999);
        for d in dirs.iter_mut() {
            for (j, v) in d.iter_mut().enumerate() {
                *v = drng.gaussian() * 0.85f64.powi(j as i32);
            }
        }
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for c in 0..classes {
            for _ in 0..per_class {
                let x: Vec<f64> = (0..n_features)
                    .map(|j| dirs[c][j] * 2.0 + rng.gaussian())
                    .collect();
                rows.push(x);
                labels.push(c);
            }
        }
        (rows, labels)
    }

    fn trained() -> (AnytimeSvm, Vec<Vec<f64>>, Vec<usize>) {
        let (rows, labels) = planted(40, 100, 11);
        let svm = train_ovr(&rows, &labels, 4, &TrainConfig::default());
        let (test_rows, test_labels) = planted(40, 60, 12);
        (AnytimeSvm::by_coefficient_magnitude(svm), test_rows, test_labels)
    }

    #[test]
    fn full_prefix_equals_direct_classification() {
        let (asvm, rows, _) = trained();
        for raw in rows.iter().take(50) {
            assert_eq!(asvm.classify_with(raw, 40), asvm.svm.classify(raw));
        }
    }

    #[test]
    fn incremental_matches_subset_classification() {
        let (asvm, rows, _) = trained();
        for raw in rows.iter().take(20) {
            for p in [1usize, 5, 17, 33] {
                let inc = asvm.classify_with(raw, p);
                let direct = asvm.svm.classify_subset(raw, &asvm.order[..p]);
                assert_eq!(inc, direct, "p={p}");
            }
        }
    }

    #[test]
    fn coherence_grows_with_prefix_and_hits_one() {
        let (asvm, rows, _) = trained();
        let ps = [0usize, 5, 10, 20, 40];
        let curve = asvm.coherence_curve(&rows, &ps);
        assert!((curve[4] - 1.0).abs() < 1e-12, "full prefix must be coherent");
        assert!(curve[3] > curve[1], "coherence should grow: {curve:?}");
        assert!(curve[1] > curve[0], "coherence should grow: {curve:?}");
    }

    #[test]
    fn magnitude_order_dominates_reverse_order() {
        let (asvm, rows, _) = trained();
        let rev = AnytimeSvm::by_reverse_magnitude(asvm.svm.clone());
        let ps = [10usize];
        let good = asvm.coherence_curve(&rows, &ps)[0];
        let bad = rev.coherence_curve(&rows, &ps)[0];
        assert!(
            good > bad + 0.1,
            "magnitude order {good} should beat reverse {bad}"
        );
    }

    #[test]
    fn accuracy_curve_saturates_at_full_model_accuracy() {
        let (asvm, rows, labels) = trained();
        let ps = [0usize, 10, 40];
        let acc = asvm.accuracy_curve(&rows, &labels, &ps);
        let full = asvm.svm.accuracy(&rows, &labels);
        assert!((acc[2] - full).abs() < 1e-12);
        assert!(acc[0] < acc[2], "chance start below ceiling: {acc:?}");
    }
}
