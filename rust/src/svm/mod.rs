//! Support-vector machines and the anytime variation of §3.
//!
//! * [`model`] — one-versus-rest linear SVM (the hardware-friendly
//!   formulation of Anguita et al. the paper builds on), with f32 and Q15
//!   fixed-point scoring paths (the MCU has no FPU, §4.3).
//! * [`train`] — Pegasos-style stochastic sub-gradient training with
//!   feature standardisation (the offline phase of §4.2).
//! * [`anytime`] — incremental prefix classification: features are
//!   processed in decreasing hyperplane-coefficient magnitude (the
//!   ordering Eq. 6 suggests), caching partial scores so accuracy can be
//!   refined as energy allows.
//! * [`analysis`] — the Eq. 7 accuracy model: the probability that a
//!   classification with `p < n` features is coherent with the
//!   full-feature one, closed-form for the binary case and fitted
//!   Monte-Carlo for the multi-class case, both "computed numerically"
//!   as the paper prescribes.

pub mod analysis;
pub mod anytime;
pub mod model;
pub mod train;
