//! Pegasos-style training for the OvR linear SVM.
//!
//! The paper trains offline with scikit's SVM (§4.2); here the offline
//! phase is a deterministic stochastic sub-gradient solver for the same
//! primal objective, `λ/2·||w||² + mean(hinge)`, one binary problem per
//! class. Training runs in milliseconds for the corpus sizes the
//! experiments use and is exactly reproducible from the seed.

use crate::svm::model::{OvrSvm, Scaler};
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Regularisation λ of the Pegasos objective.
    pub lambda: f64,
    /// Number of epochs over the data.
    pub epochs: usize,
    /// RNG seed for sample order.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig { lambda: 3e-3, epochs: 30, seed: 0x5EED }
    }
}

/// Train a one-versus-rest linear SVM on raw (unscaled) features.
pub fn train_ovr(
    rows: &[Vec<f64>],
    labels: &[usize],
    classes: usize,
    cfg: &TrainConfig,
) -> OvrSvm {
    assert_eq!(rows.len(), labels.len());
    assert!(!rows.is_empty());
    let n = rows[0].len();
    let scaler = Scaler::fit(rows);
    let data: Vec<Vec<f64>> = rows.iter().map(|r| scaler.apply(r)).collect();

    let mut weights = vec![vec![0.0; n]; classes];
    let mut bias = vec![0.0; classes];
    for c in 0..classes {
        let y: Vec<f64> =
            labels.iter().map(|&l| if l == c { 1.0 } else { -1.0 }).collect();
        let (mut w, mut b) = pegasos(&data, &y, cfg, c as u64);
        // Normalise the hyperplane to unit ||w||: OvR argmax compares
        // scores across independently-trained binary problems, which is
        // only meaningful when each score is a geometric margin.
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for wj in w.iter_mut() {
            *wj /= norm;
        }
        b /= norm;
        weights[c] = w;
        bias[c] = b;
    }
    OvrSvm { classes, features: n, weights, bias, scaler }
}

/// Pegasos primal solver for one binary problem. The bias is trained as
/// an augmented, regularised weight over a constant pseudo-feature — the
/// unregularised-bias variant diverges under Pegasos' aggressive early
/// step sizes (eta = 1/(λt)).
fn pegasos(data: &[Vec<f64>], y: &[f64], cfg: &TrainConfig, class_tag: u64) -> (Vec<f64>, f64) {
    let m = data.len();
    let n = data[0].len();
    let mut rng = Rng::new(cfg.seed ^ class_tag.wrapping_mul(0x9E3779B97F4A7C15));
    let mut w = vec![0.0; n];
    let mut b = 0.0;
    let mut t = 0u64;
    let mut order: Vec<usize> = (0..m).collect();
    // Iterate averaging over the second half of training: averaged
    // Pegasos converges O(1/T) and yields far better-calibrated scores,
    // which the OvR argmax depends on.
    let mut w_avg = vec![0.0; n];
    let mut b_avg = 0.0;
    let mut avg_count = 0u64;
    let total_iters = (cfg.epochs * m) as u64;
    for _ in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            t += 1;
            let eta = 1.0 / (cfg.lambda * t as f64);
            let margin =
                y[i] * (b + w.iter().zip(&data[i]).map(|(wj, xj)| wj * xj).sum::<f64>());
            // Regularisation shrink (bias included: augmented feature).
            let shrink = 1.0 - eta * cfg.lambda;
            for wj in w.iter_mut() {
                *wj *= shrink;
            }
            b *= shrink;
            if margin < 1.0 {
                for (wj, xj) in w.iter_mut().zip(&data[i]) {
                    *wj += eta * y[i] * xj;
                }
                b += eta * y[i]; // constant pseudo-feature value 1
            }
            if t > total_iters / 2 {
                for (aj, wj) in w_avg.iter_mut().zip(&w) {
                    *aj += wj;
                }
                b_avg += b;
                avg_count += 1;
            }
        }
    }
    if avg_count > 0 {
        for aj in w_avg.iter_mut() {
            *aj /= avg_count as f64;
        }
        b_avg /= avg_count as f64;
        (w_avg, b_avg)
    } else {
        (w, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Three Gaussian blobs in 5-D (two informative dims, three noise).
    fn blobs(n_per: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers = [[3.0, 0.0], [-3.0, 3.0], [0.0, -3.0]];
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for (c, center) in centers.iter().enumerate() {
            for _ in 0..n_per {
                let mut x = vec![
                    center[0] + rng.gaussian() * 0.8,
                    center[1] + rng.gaussian() * 0.8,
                ];
                for _ in 0..3 {
                    x.push(rng.gaussian()); // pure noise dims
                }
                rows.push(x);
                labels.push(c);
            }
        }
        (rows, labels)
    }

    #[test]
    fn separable_blobs_reach_high_accuracy() {
        let (rows, labels) = blobs(120, 1);
        let svm = train_ovr(&rows, &labels, 3, &TrainConfig::default());
        let acc = svm.accuracy(&rows, &labels);
        assert!(acc > 0.95, "train accuracy {acc}");
        // Held-out set from a different seed.
        let (test_rows, test_labels) = blobs(60, 2);
        let test_acc = svm.accuracy(&test_rows, &test_labels);
        assert!(test_acc > 0.93, "test accuracy {test_acc}");
    }

    #[test]
    fn informative_features_get_larger_weights() {
        let (rows, labels) = blobs(150, 3);
        let svm = train_ovr(&rows, &labels, 3, &TrainConfig::default());
        // Aggregate |w| per feature across classes.
        let mag = |j: usize| -> f64 {
            (0..3).map(|c| svm.weights[c][j].abs()).sum()
        };
        let informative = mag(0) + mag(1);
        let noise = mag(2) + mag(3) + mag(4);
        assert!(
            informative > 3.0 * noise,
            "informative={informative} noise={noise}"
        );
    }

    #[test]
    fn training_is_deterministic() {
        let (rows, labels) = blobs(50, 4);
        let a = train_ovr(&rows, &labels, 3, &TrainConfig::default());
        let b = train_ovr(&rows, &labels, 3, &TrainConfig::default());
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.bias, b.bias);
    }
}
