//! One-versus-rest linear SVM.
//!
//! The classifier of Anguita et al. [4] as the paper uses it: `c`
//! hyperplanes over `n` features restricted to the linearly separable
//! subset (no kernels, §4.2). Scoring is a plain inner product, which is
//! what makes the anytime prefix decomposition of §3.2 possible.

use crate::util::fixed::{Acc, Q15};

/// Feature standardiser fitted on the training set (mean/std per
/// feature). The MCU applies it as part of feature extraction.
#[derive(Clone, Debug)]
pub struct Scaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Scaler {
    /// Fit on row-major data (`rows × n`).
    pub fn fit(rows: &[Vec<f64>]) -> Scaler {
        assert!(!rows.is_empty());
        let n = rows[0].len();
        let m = rows.len() as f64;
        let mut mean = vec![0.0; n];
        for r in rows {
            for (j, &v) in r.iter().enumerate() {
                mean[j] += v;
            }
        }
        for mj in &mut mean {
            *mj /= m;
        }
        let mut std = vec![0.0; n];
        for r in rows {
            for (j, &v) in r.iter().enumerate() {
                std[j] += (v - mean[j]) * (v - mean[j]);
            }
        }
        for sj in &mut std {
            *sj = (*sj / m).sqrt();
            if *sj < 1e-9 {
                *sj = 1.0; // constant feature: leave centred at zero
            }
        }
        Scaler { mean, std }
    }

    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .enumerate()
            .map(|(j, &v)| (v - self.mean[j]) / self.std[j])
            .collect()
    }

    pub fn apply_one(&self, j: usize, v: f64) -> f64 {
        (v - self.mean[j]) / self.std[j]
    }
}

/// OvR linear SVM over standardised features.
#[derive(Clone, Debug)]
pub struct OvrSvm {
    pub classes: usize,
    pub features: usize,
    /// `weights[c][j]`: hyperplane coefficients.
    pub weights: Vec<Vec<f64>>,
    /// Per-class bias.
    pub bias: Vec<f64>,
    /// Standardiser applied to raw features before scoring.
    pub scaler: Scaler,
}

impl OvrSvm {
    /// Per-class decision scores for a *raw* (unscaled) feature vector.
    pub fn scores(&self, raw: &[f64]) -> Vec<f64> {
        let x = self.scaler.apply(raw);
        self.scores_scaled(&x)
    }

    /// Per-class decision scores for an already-standardised vector.
    pub fn scores_scaled(&self, x: &[f64]) -> Vec<f64> {
        self.weights
            .iter()
            .zip(self.bias.iter())
            .map(|(w, b)| b + w.iter().zip(x.iter()).map(|(wi, xi)| wi * xi).sum::<f64>())
            .collect()
    }

    /// OvR classification: class whose hyperplane scores highest (Eq. 9).
    pub fn classify(&self, raw: &[f64]) -> usize {
        argmax(&self.scores(raw))
    }

    /// Classification using only the features listed in `subset`
    /// (Eq. 2's approximation; remaining features contribute zero, i.e.
    /// their standardised mean).
    pub fn classify_subset(&self, raw: &[f64], subset: &[usize]) -> usize {
        let mut scores = self.bias.clone();
        for &j in subset {
            let xj = self.scaler.apply_one(j, raw[j]);
            for (c, s) in scores.iter_mut().enumerate() {
                *s += self.weights[c][j] * xj;
            }
        }
        argmax(&scores)
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, rows: &[Vec<f64>], labels: &[usize]) -> f64 {
        assert_eq!(rows.len(), labels.len());
        if rows.is_empty() {
            return 0.0;
        }
        let correct = rows
            .iter()
            .zip(labels)
            .filter(|(r, &l)| self.classify(r) == l)
            .count();
        correct as f64 / rows.len() as f64
    }
}

/// Index of the maximum (first wins ties) — Eq. 9.
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Q15 fixed-point twin of [`OvrSvm`] — what the MSP430 firmware runs
/// (§4.3). Weights share one scale; scores accumulate exactly in Q30, so
/// the argmax is comparable across classes without renormalising.
#[derive(Clone, Debug)]
pub struct FixedOvrSvm {
    pub classes: usize,
    pub features: usize,
    pub weights: Vec<Vec<Q15>>,
    pub bias: Vec<Acc>,
    /// f64 scale such that `w_f64 = w_q15.to_f64() * scale`.
    pub weight_scale: f64,
    /// Input quantisation scale (features mapped into [-1,1) by this).
    pub input_scale: f64,
}

impl FixedOvrSvm {
    /// Quantise a trained f64 model. `input_scale` should cover the
    /// standardised feature range (±4 σ covers essentially everything).
    pub fn quantise(svm: &OvrSvm, input_scale: f64) -> FixedOvrSvm {
        let wmax = svm
            .weights
            .iter()
            .flatten()
            .fold(0.0f64, |m, w| m.max(w.abs()))
            .max(1e-12);
        let weight_scale = wmax * 1.0001;
        let weights: Vec<Vec<Q15>> = svm
            .weights
            .iter()
            .map(|row| row.iter().map(|&w| Q15::from_f64(w / weight_scale)).collect())
            .collect();
        // Bias mapped into the Q30 accumulator domain:
        // acc_f64 = (w/wscale)·(x/xscale) summed ⇒ bias/(wscale·xscale).
        let bias: Vec<Acc> = svm
            .bias
            .iter()
            .map(|&b| {
                let v = b / (weight_scale * input_scale);
                Acc((v * (1u64 << 30) as f64) as i64)
            })
            .collect();
        FixedOvrSvm {
            classes: svm.classes,
            features: svm.features,
            weights,
            bias,
            weight_scale,
            input_scale,
        }
    }

    /// Classify a standardised f64 vector through the Q15 path.
    pub fn classify_scaled(&self, x: &[f64]) -> usize {
        let xq: Vec<Q15> =
            x.iter().map(|&v| Q15::from_f64(v / self.input_scale)).collect();
        let mut best = 0usize;
        let mut best_acc = Acc(i64::MIN);
        for c in 0..self.classes {
            let mut acc = self.bias[c];
            for (w, q) in self.weights[c].iter().zip(xq.iter()) {
                acc.mac(*w, *q);
            }
            if acc > best_acc {
                best_acc = acc;
                best = c;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built 3-class model over 4 features.
    fn toy() -> OvrSvm {
        OvrSvm {
            classes: 3,
            features: 4,
            weights: vec![
                vec![1.0, 0.0, 0.0, 0.1],
                vec![0.0, 1.0, 0.0, -0.1],
                vec![0.0, 0.0, 1.0, 0.0],
            ],
            bias: vec![0.0, 0.0, 0.0],
            scaler: Scaler { mean: vec![0.0; 4], std: vec![1.0; 4] },
        }
    }

    #[test]
    fn classify_picks_matching_axis() {
        let svm = toy();
        assert_eq!(svm.classify(&[2.0, 0.1, 0.1, 0.0]), 0);
        assert_eq!(svm.classify(&[0.1, 2.0, 0.1, 0.0]), 1);
        assert_eq!(svm.classify(&[0.1, 0.1, 2.0, 0.0]), 2);
    }

    #[test]
    fn subset_classification_matches_prefix_formula() {
        let svm = toy();
        // Using only feature 1, class 1 wins when x1 > 0.
        assert_eq!(svm.classify_subset(&[5.0, 1.0, 0.0, 0.0], &[1]), 1);
        // With all features it flips to class 0.
        assert_eq!(svm.classify(&[5.0, 1.0, 0.0, 0.0]), 0);
    }

    #[test]
    fn scaler_fit_and_apply() {
        let rows = vec![vec![1.0, 10.0], vec![3.0, 10.0], vec![5.0, 10.0]];
        let s = Scaler::fit(&rows);
        assert!((s.mean[0] - 3.0).abs() < 1e-12);
        let x = s.apply(&[3.0, 10.0]);
        assert!(x[0].abs() < 1e-12);
        assert!(x[1].abs() < 1e-12); // constant feature centred
        // Std of col 0 is sqrt(8/3).
        let want = (8.0f64 / 3.0).sqrt();
        assert!((s.std[0] - want).abs() < 1e-12);
    }

    #[test]
    fn accuracy_counts_matches() {
        let svm = toy();
        let rows = vec![
            vec![2.0, 0.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0, 0.0],
            vec![0.0, 0.0, 2.0, 0.0],
            vec![2.0, 0.0, 0.0, 0.0],
        ];
        let labels = vec![0, 1, 2, 1]; // last is wrong on purpose
        assert!((svm.accuracy(&rows, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn fixed_point_agrees_with_float_on_clear_margins() {
        let svm = toy();
        let fx = FixedOvrSvm::quantise(&svm, 8.0);
        let mut rng = crate::util::rng::Rng::new(77);
        let mut agree = 0;
        let total = 500;
        for _ in 0..total {
            let x: Vec<f64> = (0..4).map(|_| rng.range(-3.0, 3.0)).collect();
            let f = argmax(&svm.scores_scaled(&x));
            let q = fx.classify_scaled(&x);
            if f == q {
                agree += 1;
            }
        }
        // Quantisation flips only near-tie samples.
        assert!(agree as f64 / total as f64 > 0.97, "agree={agree}/{total}");
    }
}
