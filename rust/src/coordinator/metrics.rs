//! Campaign metrics.
//!
//! The four metrics of §5: classification *accuracy* against ground
//! truth; *coherence* between two devices' outputs when ground truth is
//! unavailable (§5.3 aligns BLE packets closer than one sensor window);
//! system *throughput* (results per unit time, reported normalised);
//! and *latency* in power cycles between acquisition and emission.

use crate::audio::app::AudioOutput;
use crate::exec::{Campaign, RoundResult};
use crate::har::app::HarOutput;
use crate::imgproc::app::CornerOutput;
use crate::imgproc::equivalence::equivalent;
use crate::imgproc::harris::{harris_full, HarrisConfig};
use crate::imgproc::images::{render, Picture};
use crate::imgproc::Corner;
use crate::util::stats::Histogram;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

/// Process-wide memo of full-precision Harris reference maps, keyed by
/// `(picture, seed, size)`. Figs. 13-15 evaluate every emitted round of
/// every (policy, trace) cell against the same handful of reference
/// pictures; before this cache each metric call recomputed
/// `harris_full(render(...))` per campaign. The map is tiny (corner
/// lists for the synthetic picture pool) and rendering is deterministic,
/// so sharing across fleet threads is safe.
///
/// Layout: an `RwLock` index of per-key `OnceLock` slots. Once a key's
/// slot exists, lookups take only the read lock (shared, uncontended),
/// and the `OnceLock` guarantees each reference is rendered exactly once
/// — the old single-`Mutex` memo serialised every fleet worker's lookup
/// through one lock and could render the same picture twice under a
/// first-call race.
type HarrisKey = (&'static str, u64, usize);
type HarrisSlot = Arc<OnceLock<Arc<Vec<Corner>>>>;
static HARRIS_REFS: OnceLock<RwLock<HashMap<HarrisKey, HarrisSlot>>> = OnceLock::new();

/// How many times a reference was actually rendered (diagnostics: with
/// the per-key slots this equals the number of distinct keys requested).
static HARRIS_RENDERS: AtomicU64 = AtomicU64::new(0);

/// Number of full-precision reference renders performed so far in this
/// process.
pub fn harris_reference_renders() -> u64 {
    HARRIS_RENDERS.load(Ordering::Relaxed)
}

/// The full-precision Harris detections for `(picture, seed)` rendered at
/// `size`, computed once per process.
pub fn harris_reference(picture: Picture, seed: u64, size: usize) -> Arc<Vec<Corner>> {
    let index = HARRIS_REFS.get_or_init(|| RwLock::new(HashMap::new()));
    let key = (picture.name(), seed, size);
    // Fast path: shared read lock, dropped before any rendering.
    let slot = {
        let map = index.read().expect("harris memo poisoned");
        map.get(&key).map(Arc::clone)
    };
    let slot = slot.unwrap_or_else(|| {
        let mut map = index.write().expect("harris memo poisoned");
        Arc::clone(map.entry(key).or_default())
    });
    // Render outside both map locks; the OnceLock admits one renderer
    // per key and blocks only same-key callers.
    Arc::clone(slot.get_or_init(|| {
        HARRIS_RENDERS.fetch_add(1, Ordering::Relaxed);
        Arc::new(harris_full(&render(picture, size, size, seed), &HarrisConfig::default()))
    }))
}

/// Fraction of a campaign's emitted outputs satisfying `correct` — the
/// quality kernel every workload's accuracy/equivalence metric shares
/// (empty campaigns report 0.0).
fn emitted_fraction<O>(campaign: &Campaign<O>, correct: impl Fn(&O) -> bool) -> f64 {
    let mut total = 0usize;
    let mut ok = 0usize;
    for r in campaign.emitted() {
        if let Some(out) = &r.output {
            total += 1;
            if correct(out) {
                ok += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        ok as f64 / total as f64
    }
}

/// Classification accuracy over emitted results.
pub fn har_accuracy(campaign: &Campaign<HarOutput>) -> f64 {
    emitted_fraction(campaign, |out| out.predicted == out.truth as usize)
}

/// Detection accuracy over emitted audio rounds (predicted event class
/// against the scene ground truth the output carries).
pub fn audio_accuracy(campaign: &Campaign<AudioOutput>) -> f64 {
    emitted_fraction(campaign, |out| out.predicted == out.truth)
}

/// Align two campaigns' emitted rounds by sampling slot and report the
/// fraction of aligned pairs with identical classifications (§5.3/§5.4's
/// coherence). Rounds align when their acquisition times fall in the
/// same `period` slot.
pub fn har_coherence(
    a: &Campaign<HarOutput>,
    b: &Campaign<HarOutput>,
    period: f64,
) -> f64 {
    let slot = |r: &RoundResult<HarOutput>| (r.acquired_at / period).floor() as i64;
    let mut by_slot: HashMap<i64, usize> = HashMap::new();
    for r in b.emitted() {
        if let Some(out) = &r.output {
            by_slot.insert(slot(r), out.predicted);
        }
    }
    let mut total = 0usize;
    let mut same = 0usize;
    for r in a.emitted() {
        if let Some(out) = &r.output {
            if let Some(&other) = by_slot.get(&slot(r)) {
                total += 1;
                if out.predicted == other {
                    same += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

/// Throughput of `a` normalised to `b` (emitted results per second).
pub fn throughput_ratio<O1, O2>(a: &Campaign<O1>, b: &Campaign<O2>) -> f64 {
    let tb = b.throughput();
    if tb == 0.0 {
        0.0
    } else {
        a.throughput() / tb
    }
}

/// Latency distribution in power cycles over emitted rounds.
pub fn latency_histogram<O>(campaign: &Campaign<O>, max_cycles: usize) -> Histogram {
    let mut h = Histogram::new(0.0, max_cycles as f64, max_cycles);
    for r in campaign.emitted() {
        h.add(r.latency_cycles as f64);
    }
    h
}

/// Fraction of emitted rounds delivered within the acquisition cycle.
pub fn same_cycle_fraction<O>(campaign: &Campaign<O>) -> f64 {
    let mut total = 0usize;
    let mut same = 0usize;
    for r in campaign.emitted() {
        total += 1;
        if r.latency_cycles == 0 {
            same += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

/// Imaging: per-picture-kind equivalence pooled over several campaigns
/// (the paper's Fig. 13 aggregates across all energy traces).
pub fn corner_equivalence_by_picture(
    campaigns: &[&Campaign<CornerOutput>],
    size: usize,
) -> Vec<(crate::imgproc::images::Picture, f64)> {
    let mut counts: HashMap<&'static str, (usize, usize)> = HashMap::new();
    for campaign in campaigns {
        for r in campaign.emitted() {
            if let Some(out) = &r.output {
                let reference = harris_reference(out.picture, out.picture_seed, size);
                let entry = counts.entry(out.picture.name()).or_insert((0, 0));
                entry.1 += 1;
                if equivalent(&reference, &out.corners) {
                    entry.0 += 1;
                }
            }
        }
    }
    crate::imgproc::images::Picture::ALL
        .iter()
        .map(|&p| {
            let (ok, total) = counts.get(p.name()).copied().unwrap_or((0, 0));
            (p, if total == 0 { 0.0 } else { ok as f64 / total as f64 })
        })
        .collect()
}

/// Imaging: fraction of emitted outputs equivalent (paper §6.3 metric) to
/// the unperforated reference for the same picture. Reference detections
/// are cached per (picture, seed).
pub fn corner_equivalence_fraction(campaign: &Campaign<CornerOutput>, size: usize) -> f64 {
    emitted_fraction(campaign, |out| {
        let reference = harris_reference(out.picture, out.picture_seed, size);
        equivalent(&reference, &out.corners)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::har::Activity;

    fn round(
        sample_id: u64,
        t: f64,
        predicted: usize,
        truth: Activity,
        latency: u64,
    ) -> RoundResult<HarOutput> {
        RoundResult {
            sample_id,
            acquired_at: t,
            emitted_at: Some(t + 1.0),
            latency_cycles: latency,
            steps_executed: 10,
            output: Some(HarOutput { predicted, truth, features_used: 10 }),
        }
    }

    fn campaign(rounds: Vec<RoundResult<HarOutput>>, duration: f64) -> Campaign<HarOutput> {
        Campaign {
            rounds,
            duration,
            power_failures: 0,
            power_cycles: 1,
            app_energy: 0.0,
            state_energy: 0.0,
            violations: Vec::new(),
        }
    }

    #[test]
    fn accuracy_counts_matches() {
        let c = campaign(
            vec![
                round(0, 0.0, 0, Activity::Walking, 0),
                round(1, 60.0, 3, Activity::Sitting, 0),
                round(2, 120.0, 5, Activity::Sitting, 0),
            ],
            180.0,
        );
        assert!((har_accuracy(&c) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn coherence_aligns_by_slot() {
        let a = campaign(
            vec![
                round(0, 1.0, 0, Activity::Walking, 0),
                round(1, 61.0, 1, Activity::Walking, 0),
                round(2, 121.0, 2, Activity::Walking, 0),
            ],
            180.0,
        );
        let b = campaign(
            vec![
                round(0, 2.0, 0, Activity::Walking, 0), // same slot, same class
                round(1, 62.0, 4, Activity::Walking, 0), // same slot, differs
                // slot 2 missing in b
            ],
            180.0,
        );
        assert!((har_coherence(&a, &b, 60.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn harris_reference_memo_is_shared_across_threads() {
        // A seed no other test uses: this test owns the key outright.
        const SEED: u64 = 0xC0FFEE;
        let renders_before = harris_reference_renders();
        let handles: Vec<_> = (0..16)
            .map(|_| {
                std::thread::spawn(|| harris_reference(Picture::Checker, SEED, 48))
            })
            .collect();
        let refs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Every thread sees the same memoised corner list.
        for r in &refs[1..] {
            assert!(Arc::ptr_eq(&refs[0], r));
        }
        // The key was rendered (other keys may render concurrently in
        // parallel tests, so only a lower bound is race-free; the
        // pointer equality above rules out duplicate renders here).
        assert!(harris_reference_renders() > renders_before);
        // Later lookups keep returning the same allocation.
        assert!(Arc::ptr_eq(&refs[0], &harris_reference(Picture::Checker, SEED, 48)));
    }

    #[test]
    fn throughput_ratio_and_latency() {
        let a = campaign(vec![round(0, 0.0, 0, Activity::Walking, 0)], 100.0);
        let b = campaign(
            vec![
                round(0, 0.0, 0, Activity::Walking, 2),
                round(1, 50.0, 0, Activity::Walking, 7),
            ],
            100.0,
        );
        assert!((throughput_ratio(&a, &b) - 0.5).abs() < 1e-12);
        let h = latency_histogram(&b, 10);
        assert_eq!(h.bins[2], 1);
        assert_eq!(h.bins[7], 1);
        assert_eq!(same_cycle_fraction(&b), 0.0);
        assert_eq!(same_cycle_fraction(&a), 1.0);
    }
}
