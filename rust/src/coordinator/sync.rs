//! Coordination-free multi-device fleet sync.
//!
//! Every scenario before this module simulated one device at a time.
//! Real deployments of the paper's prototypes are *fleets* of
//! batteryless nodes that must reconcile observations opportunistically:
//! two endpoints can exchange data only in the instants both happen to
//! be powered, there is no coordinator, and merge order is whatever the
//! energy environment dictates. This module simulates exactly that —
//! N devices on correlated-but-distinct synth environments, each
//! keeping a **per-column-versioned** table of detection results, and
//! exchanging **only changed columns** at deterministic powered-overlap
//! rendezvous.
//!
//! The replication layer is a delta-state CRDT in the dag-CRR style the
//! roadmap names:
//!
//! * **Per-column versioning.** Every `(row, column)` cell carries a
//!   [`Stamp`] — a Lamport-style version plus the writer id. A local
//!   write bumps the locally known version, so later writes dominate
//!   earlier ones wherever they meet.
//! * **Symmetric tiebreakers.** Concurrent writes at the same version
//!   are ordered by the total order `(version, value bits, writer)`.
//!   Join = max under that order: commutative, associative, idempotent
//!   — so the converged state is independent of merge order, which
//!   `tests/fleet_sync.rs` checks bitwise over distinct schedules.
//! * **Delta sync of changed columns only.** Each replica keeps a
//!   per-writer sequence log with the *prefix invariant*: it holds a
//!   contiguous prefix `1..=vv[w]` of every writer `w`'s updates. A
//!   meeting exchanges version vectors (8 bytes per device) and then
//!   only the log entries the peer has not covered — columns untouched
//!   since the peers last aligned are never re-shipped.
//! * **Coordination-free GC.** Each replica gossips an ack matrix
//!   (`acked[peer][writer]`: a lower bound on what `peer` holds from
//!   `writer`). Log entries at or below the minimum over all other
//!   peers can never be requested again and are pruned locally — no
//!   round, no leader, no handshake. Safety: acks only ever
//!   under-report, and version vectors only grow, so a pruned sequence
//!   is provably covered at every peer that could ask for it.
//!
//! The meeting model is deterministic: a device is *up* when its raw
//! harvester power clears `up_fraction` x its own mean power; a pair
//! meets on a fixed rendezvous grid when both are up, thinned by a
//! per-(cell, slot, pair) seeded drop-out draw and an optional
//! asymmetric-overlap matrix. Clock skew shifts each device's local
//! observation windows. Everything — observation, detection, meeting,
//! exchange — is a pure function of `(spec, supplies, horizon, seed)`,
//! so fleet sweeps stream, dedup, and resume like any other campaign.

use crate::coordinator::store::digest::FleetDigest;
use crate::energy::harvester::Harvester;
use crate::util::json::{opt_f64, opt_usize, Value};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, VecDeque};

/// Column index of the quantised window energy a detection observed.
pub const COL_ENERGY: u8 = 0;
/// Column index of the detection flag (always 1.0 when present).
pub const COL_DETECT: u8 = 1;
/// Column of the shared fleet-aggregate row: every device writes its own
/// running detection count here, which makes concurrent same-column
/// writes — the symmetric-tiebreak path — a permanent part of every run.
pub const COL_COUNT: u8 = 2;
/// Row id of the shared aggregate row (all devices write it).
pub const AGG_ROW: u32 = u32::MAX;

/// Wire cost of one shipped column: key (5) + stamp (11) + value (8).
pub const BYTES_PER_ENTRY: u64 = 24;
/// Fixed per-direction message overhead before the version vector.
pub const EXCHANGE_OVERHEAD: u64 = 16;
/// A window whose harvested energy clears this multiple of the device's
/// mean window energy counts as a detection event.
pub const DETECT_FACTOR: f64 = 1.1;

/// Fleet axis caps: row ids pack `(device, window)` into 16+16 bits.
pub const MAX_DEVICES: usize = 64;
const MAX_WINDOWS_PER_DEVICE: f64 = 65536.0;
/// Per-cell rendezvous budget (slots x pairs): a hostile spec must fail
/// validation, not allocate an unbounded event list in a fleet worker.
const MAX_MEETINGS_PER_CELL: f64 = 2_000_000.0;

// ---------------------------------------------------------------------
// Fleet spec.
// ---------------------------------------------------------------------

/// The fleet axes of a `WorkloadSpec::Fleet` scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    /// Fleet size (2..=64 devices).
    pub devices: usize,
    /// A device is powered when raw harvester power >= `up_fraction` x
    /// its own mean power.
    pub up_fraction: f64,
    /// Rendezvous grid period, seconds: pairs may meet at `k x period`.
    pub meeting_period: f64,
    /// Local observation window length, seconds.
    pub obs_period: f64,
    /// Probability a powered-overlap rendezvous is lost anyway
    /// (deterministic per-(cell, slot, pair) draw), in `[0, 1)`.
    pub drop_rate: f64,
    /// Maximum per-device clock offset, seconds (>= 0): shifts each
    /// device's observation windows by a seeded draw in `[0, skew]`.
    pub clock_skew: f64,
    /// Optional symmetric `devices x devices` matrix in `[0, 1]` scaling
    /// each pair's rendezvous success (asymmetric harvest topologies);
    /// `None` = all pairs at 1.
    pub overlap: Option<Vec<Vec<f64>>>,
}

impl Default for FleetSpec {
    fn default() -> FleetSpec {
        FleetSpec {
            devices: 4,
            up_fraction: 1.0,
            meeting_period: 15.0,
            obs_period: 60.0,
            drop_rate: 0.0,
            clock_skew: 0.0,
            overlap: None,
        }
    }
}

impl FleetSpec {
    /// Structural validation (everything that does not need the
    /// horizon).
    pub fn validate(&self) -> Result<(), String> {
        if self.devices < 2 || self.devices > MAX_DEVICES {
            return Err(format!(
                "fleet needs 2..={MAX_DEVICES} devices, got {}",
                self.devices
            ));
        }
        if !(self.up_fraction.is_finite() && self.up_fraction > 0.0 && self.up_fraction <= 100.0)
        {
            return Err(format!(
                "fleet up_fraction must be finite in (0, 100], got {}",
                self.up_fraction
            ));
        }
        if !(self.meeting_period.is_finite() && self.meeting_period > 0.0) {
            return Err(format!(
                "fleet meeting_period must be finite and positive, got {}",
                self.meeting_period
            ));
        }
        if !(self.obs_period.is_finite() && self.obs_period > 0.0) {
            return Err(format!(
                "fleet obs_period must be finite and positive, got {}",
                self.obs_period
            ));
        }
        if !(self.drop_rate.is_finite() && (0.0..1.0).contains(&self.drop_rate)) {
            return Err(format!(
                "fleet drop_rate must be finite in [0, 1), got {}",
                self.drop_rate
            ));
        }
        if !(self.clock_skew.is_finite() && self.clock_skew >= 0.0) {
            return Err(format!(
                "fleet clock_skew must be finite and non-negative, got {}",
                self.clock_skew
            ));
        }
        if let Some(m) = &self.overlap {
            if m.len() != self.devices {
                return Err(format!(
                    "fleet overlap must be a {0}x{0} matrix, got {1} rows",
                    self.devices,
                    m.len()
                ));
            }
            // Shape and range first, symmetry second: the transpose
            // lookup below may only index rows already proven square.
            for (i, row) in m.iter().enumerate() {
                if row.len() != self.devices {
                    return Err(format!(
                        "fleet overlap row {i} has {} entries (need {})",
                        row.len(),
                        self.devices
                    ));
                }
                for (j, &x) in row.iter().enumerate() {
                    if !(x.is_finite() && (0.0..=1.0).contains(&x)) {
                        return Err(format!(
                            "fleet overlap[{i}][{j}] must be finite in [0, 1], got {x}"
                        ));
                    }
                }
            }
            for (i, row) in m.iter().enumerate() {
                for (j, &x) in row.iter().enumerate() {
                    if m[j][i] != x {
                        return Err(format!(
                            "fleet overlap must be symmetric: [{i}][{j}]={x} but [{j}][{i}]={}",
                            m[j][i]
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Resource validation against the (unresolved, i.e. largest)
    /// campaign horizon — hostile specs must fail at parse/validate
    /// time, never inside a fleet worker.
    pub fn validate_with_horizon(&self, horizon: f64) -> Result<(), String> {
        let windows = horizon / self.obs_period;
        if windows > MAX_WINDOWS_PER_DEVICE {
            return Err(format!(
                "fleet horizon/obs_period = {windows:.0} windows per device \
                 (max {MAX_WINDOWS_PER_DEVICE:.0}: row ids pack device and window)"
            ));
        }
        let pairs = (self.devices * (self.devices - 1) / 2) as f64;
        let meetings = (horizon / self.meeting_period) * pairs;
        if meetings > MAX_MEETINGS_PER_CELL {
            return Err(format!(
                "fleet rendezvous budget {meetings:.0} exceeds {MAX_MEETINGS_PER_CELL:.0} \
                 (horizon/meeting_period x device pairs)"
            ));
        }
        Ok(())
    }

    /// Pair meeting-success scale from the overlap matrix (1 without
    /// one).
    pub fn overlap_at(&self, i: usize, j: usize) -> f64 {
        self.overlap.as_ref().map_or(1.0, |m| m[i][j])
    }

    pub fn to_json(&self) -> Value {
        let mut fields: Vec<(&str, Value)> = vec![
            ("kind", "fleet".into()),
            ("devices", (self.devices as f64).into()),
            ("up_fraction", self.up_fraction.into()),
            ("meeting_period", self.meeting_period.into()),
            ("obs_period", self.obs_period.into()),
            ("drop_rate", self.drop_rate.into()),
            ("clock_skew", self.clock_skew.into()),
        ];
        if let Some(m) = &self.overlap {
            fields.push((
                "overlap",
                Value::Arr(m.iter().map(|row| Value::nums(row)).collect()),
            ));
        }
        Value::obj(fields)
    }

    /// Parse the `{"kind": "fleet", ...}` workload object. Strict: an
    /// unknown key is an error, matching the scenario parser's policy.
    pub fn from_json(v: &Value) -> Result<FleetSpec, String> {
        const KEYS: [&str; 8] = [
            "kind",
            "devices",
            "up_fraction",
            "meeting_period",
            "obs_period",
            "drop_rate",
            "clock_skew",
            "overlap",
        ];
        let obj = v.as_obj().ok_or("fleet workload must be a JSON object")?;
        for key in obj.keys() {
            if !KEYS.contains(&key.as_str()) {
                return Err(format!("unknown fleet key '{key}'"));
            }
        }
        let mut spec = FleetSpec::default();
        if let Some(n) = opt_usize(v, "devices")? {
            spec.devices = n;
        }
        if let Some(x) = opt_f64(v, "up_fraction")? {
            spec.up_fraction = x;
        }
        if let Some(x) = opt_f64(v, "meeting_period")? {
            spec.meeting_period = x;
        }
        if let Some(x) = opt_f64(v, "obs_period")? {
            spec.obs_period = x;
        }
        if let Some(x) = opt_f64(v, "drop_rate")? {
            spec.drop_rate = x;
        }
        if let Some(x) = opt_f64(v, "clock_skew")? {
            spec.clock_skew = x;
        }
        if !matches!(v.get("overlap"), Value::Null) {
            let rows = v
                .get("overlap")
                .as_arr()
                .ok_or("fleet 'overlap' must be an array of number arrays")?;
            let m = rows
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or("fleet 'overlap' rows must be arrays")?
                        .iter()
                        .map(|x| {
                            x.as_f64()
                                .ok_or_else(|| "fleet 'overlap' entries must be numbers".to_string())
                        })
                        .collect::<Result<Vec<f64>, String>>()
                })
                .collect::<Result<Vec<Vec<f64>>, String>>()?;
            spec.overlap = Some(m);
        }
        // Structural validation happens here (parse time); the horizon
        // budget re-checks in Scenario::validate where the horizon is
        // known.
        spec.validate()?;
        Ok(spec)
    }
}

// ---------------------------------------------------------------------
// The per-column-versioned replica.
// ---------------------------------------------------------------------

/// A table coordinate: `(row, column)`.
pub type Key = (u32, u8);

/// Per-column version stamp: Lamport-style version + writer id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stamp {
    pub version: u64,
    pub writer: u16,
}

/// One versioned table cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColCell {
    pub stamp: Stamp,
    pub value: f64,
}

/// The symmetric total order both endpoints of a merge agree on:
/// version first, then the value's bit pattern, then the writer id.
/// Join = max under this order, which makes the merge commutative,
/// associative, and idempotent — the converged state cannot depend on
/// exchange order.
fn rank(c: &ColCell) -> (u64, u64, u16) {
    (c.stamp.version, c.value.to_bits(), c.stamp.writer)
}

/// One shipped delta entry: a writer-sequence slot plus the sender's
/// current (already-merged) cell for that key. Shipping the *current*
/// cell keeps relays monotone: anyone who applied sequence `seq` holds a
/// cell at least as high in the join order as the write that created it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeltaEntry {
    pub writer: u16,
    pub seq: u64,
    pub key: Key,
    pub cell: ColCell,
}

/// What `Replica::apply` did with a delta.
pub struct ApplyOutcome {
    /// Entries that extended this replica's version vector.
    pub applied: u64,
    /// Keys this replica had never seen before (any column).
    pub new_keys: Vec<Key>,
}

/// What one bidirectional exchange moved.
pub struct ExchangeOutcome {
    /// Log entries shipped in both directions.
    pub entries: u64,
    /// Modelled wire bytes: two message headers + version vectors +
    /// `BYTES_PER_ENTRY` per shipped column.
    pub bytes: u64,
    /// Keys newly known to the first endpoint.
    pub new_a: Vec<Key>,
    /// Keys newly known to the second endpoint.
    pub new_b: Vec<Key>,
}

/// One device's replica of the fleet's detection/result table.
#[derive(Clone, Debug)]
pub struct Replica {
    id: u16,
    n: usize,
    /// The versioned table: join-of-writes per column.
    cells: BTreeMap<Key, ColCell>,
    /// Version vector: `vv[w]` = highest contiguous sequence applied
    /// from writer `w` (the prefix invariant).
    vv: Vec<u64>,
    /// Retransmission log per writer: `(seq, key)` in ascending `seq`,
    /// front-pruned by [`Replica::gc`].
    logs: Vec<VecDeque<(u64, Key)>>,
    /// Gossiped ack matrix: `acked[p][w]` is a lower bound on peer `p`'s
    /// `vv[w]`.
    acked: Vec<Vec<u64>>,
    /// Log entries retired by coordination-free GC.
    pub gc_pruned: u64,
}

impl Replica {
    pub fn new(id: usize, n: usize) -> Replica {
        assert!(id < n, "replica id {id} out of range for fleet of {n}");
        Replica {
            id: id as u16,
            n,
            cells: BTreeMap::new(),
            vv: vec![0; n],
            logs: vec![VecDeque::new(); n],
            acked: vec![vec![0; n]; n],
            gc_pruned: 0,
        }
    }

    pub fn id(&self) -> usize {
        self.id as usize
    }

    pub fn vv(&self) -> &[u64] {
        &self.vv
    }

    /// Retained retransmission-log entries (all writers) — what GC is
    /// bounding.
    pub fn log_entries(&self) -> usize {
        self.logs.iter().map(|l| l.len()).sum()
    }

    /// The converged-comparable view: every cell with its stamp, in key
    /// order. Value compared by bit pattern so `-0.0 != 0.0` and state
    /// equality is exact.
    pub fn state(&self) -> Vec<(Key, u64, u16, u64)> {
        self.cells
            .iter()
            .map(|(&k, c)| (k, c.stamp.version, c.stamp.writer, c.value.to_bits()))
            .collect()
    }

    /// FNV-1a fingerprint of [`state`](Replica::state) — a compact
    /// equality witness for tests and benches.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut fold = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for ((row, col), version, writer, bits) in self.state() {
            fold(((row as u64) << 8) | col as u64);
            fold(version);
            fold(writer as u64);
            fold(bits);
        }
        h
    }

    /// Local write: bump the locally known column version, append to the
    /// own-writer log.
    pub fn write(&mut self, row: u32, col: u8, value: f64) {
        let key = (row, col);
        let version = self.cells.get(&key).map(|c| c.stamp.version).unwrap_or(0) + 1;
        self.cells.insert(
            key,
            ColCell { stamp: Stamp { version, writer: self.id }, value },
        );
        let me = self.id as usize;
        let seq = self.vv[me] + 1;
        self.vv[me] = seq;
        self.logs[me].push_back((seq, key));
    }

    /// The changed-columns-only delta for a peer at `peer_vv`: for every
    /// writer, the log entries past the peer's prefix, each carrying the
    /// sender's current cell.
    pub fn delta_for(&self, peer_vv: &[u64]) -> Vec<DeltaEntry> {
        let mut out = Vec::new();
        for w in 0..self.n {
            let mut sent = 0u64;
            for &(seq, key) in &self.logs[w] {
                if seq > peer_vv[w] {
                    let cell = *self.cells.get(&key).expect("logged key is present");
                    out.push(DeltaEntry { writer: w as u16, seq, key, cell });
                    sent += 1;
                }
            }
            // GC safety: everything the peer lacks must still be in the
            // log (acks never over-report, so pruned seqs are covered).
            debug_assert_eq!(
                sent,
                self.vv[w].saturating_sub(peer_vv[w].min(self.vv[w])),
                "retransmission log lost entries the peer still needs"
            );
        }
        out
    }

    /// Apply a delta: extend the per-writer prefixes and join each
    /// shipped cell into the table.
    pub fn apply(&mut self, delta: &[DeltaEntry]) -> ApplyOutcome {
        let mut applied = 0u64;
        let mut new_keys = Vec::new();
        for e in delta {
            let w = e.writer as usize;
            if e.seq <= self.vv[w] {
                continue; // already covered (idempotent)
            }
            debug_assert_eq!(
                e.seq,
                self.vv[w] + 1,
                "delta must extend writer {w}'s prefix contiguously"
            );
            self.vv[w] = e.seq;
            self.logs[w].push_back((e.seq, e.key));
            applied += 1;
            match self.cells.get(&e.key) {
                None => {
                    self.cells.insert(e.key, e.cell);
                    new_keys.push(e.key);
                }
                Some(cur) => {
                    if rank(&e.cell) > rank(cur) {
                        self.cells.insert(e.key, e.cell);
                    }
                }
            }
        }
        ApplyOutcome { applied, new_keys }
    }

    /// Coordination-free GC: prune log entries every *other* replica is
    /// known (lower bound) to hold. Purely local — no round, no leader.
    pub fn gc(&mut self) {
        if self.n < 2 {
            return;
        }
        for w in 0..self.n {
            let mut threshold = u64::MAX;
            for p in 0..self.n {
                if p != self.id as usize {
                    threshold = threshold.min(self.acked[p][w]);
                }
            }
            while let Some(&(seq, _)) = self.logs[w].front() {
                if seq <= threshold {
                    self.logs[w].pop_front();
                    self.gc_pruned += 1;
                } else {
                    break;
                }
            }
        }
    }
}

/// One bidirectional powered-overlap exchange: swap version vectors,
/// ship both changed-column deltas, gossip ack knowledge, GC both ends.
pub fn exchange(a: &mut Replica, b: &mut Replica) -> ExchangeOutcome {
    assert_eq!(a.n, b.n, "replicas belong to different fleets");
    let n = a.n;
    let d_ab = a.delta_for(&b.vv);
    let d_ba = b.delta_for(&a.vv);
    let out_b = b.apply(&d_ab);
    let out_a = a.apply(&d_ba);
    debug_assert_eq!(a.vv, b.vv, "a bidirectional exchange must align the version vectors");
    let (ai, bi) = (a.id as usize, b.id as usize);
    for w in 0..n {
        // Direct knowledge: each endpoint now provably holds the joined
        // prefix.
        a.acked[bi][w] = a.acked[bi][w].max(a.vv[w]);
        b.acked[ai][w] = b.acked[ai][w].max(b.vv[w]);
        // Gossip: merge what each endpoint knows about third parties.
        for p in 0..n {
            let m = a.acked[p][w].max(b.acked[p][w]);
            a.acked[p][w] = m;
            b.acked[p][w] = m;
        }
    }
    a.gc();
    b.gc();
    let entries = (d_ab.len() + d_ba.len()) as u64;
    ExchangeOutcome {
        entries,
        bytes: 2 * (EXCHANGE_OVERHEAD + 8 * n as u64) + BYTES_PER_ENTRY * entries,
        new_a: out_a.new_keys,
        new_b: out_b.new_keys,
    }
}

// ---------------------------------------------------------------------
// The deterministic fleet cell simulation.
// ---------------------------------------------------------------------

/// Seed of device `d`'s supply within a fleet cell: the same synth
/// family (the spec), a distinct member per device — correlated but not
/// identical environments.
pub fn device_seed(cell_seed: u64, device: usize) -> u64 {
    cell_seed ^ (device as u64 + 1).wrapping_mul(0xD134_2543_DE82_EF95)
}

/// Seed of the drop-out draw for rendezvous `slot` of pair `(i, j)` —
/// keyed by identity, not processing order, so the schedule is a pure
/// function of the cell.
fn meet_seed(cell_seed: u64, slot: u64, i: usize, j: usize) -> u64 {
    let mut x = cell_seed ^ 0xA076_1D64_78BD_642F;
    x ^= slot.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x = x.wrapping_mul(0xD134_2543_DE82_EF95);
    x ^= ((i as u64) << 32) | j as u64;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// Quantise a window energy the way a fixed-point ADC accumulator would
/// (nanojoule steps) — keeps shipped values platform-independent.
fn quantise(energy: f64) -> f64 {
    (energy * 1e9).round() / 1e9
}

/// Forward-only integrator over one harvester's piecewise-constant
/// segments: total energy of `[a, b)` in O(segments advanced), amortised
/// O(1) per observation window because windows arrive in time order.
struct EnergyCursor {
    segs: crate::energy::harvester::Segments,
    cur: crate::energy::harvester::Segment,
}

impl EnergyCursor {
    fn new(h: &Harvester) -> EnergyCursor {
        let mut segs = h.segments(0.0);
        let cur = segs.next().expect("harvester segments tile all of time");
        EnergyCursor { segs, cur }
    }

    fn energy(&mut self, a: f64, b: f64) -> f64 {
        let mut e = 0.0;
        loop {
            if self.cur.end <= a {
                self.cur = self.segs.next().expect("harvester segments tile all of time");
                continue;
            }
            let lo = self.cur.start.max(a);
            let hi = self.cur.end.min(b);
            if hi > lo {
                e += self.cur.power * (hi - lo);
            }
            if self.cur.end >= b {
                return e;
            }
            self.cur = self.segs.next().expect("harvester segments tile all of time");
        }
    }
}

/// Seconds of `[0, horizon)` a supply spends at or above `threshold`.
fn powered_time(h: &Harvester, threshold: f64, horizon: f64) -> f64 {
    let mut up = 0.0;
    for (guard, seg) in h.segments(0.0).enumerate() {
        if seg.start >= horizon || guard > 4_000_000 {
            break;
        }
        if seg.power >= threshold {
            up += seg.end.min(horizon) - seg.start.max(0.0);
        }
        if seg.end >= horizon {
            break;
        }
    }
    up
}

/// The merged event timeline of one fleet cell. Observations sort before
/// meetings at equal times (a detection made "now" can ship "now"), and
/// ties break on identity — the order is a pure function of the cell.
enum Event {
    Obs { device: usize, window: u32 },
    Meet { slot: u64, i: usize, j: usize },
}

/// Run one fleet cell: N replicas on `supplies`, opportunistic delta
/// sync, convergence and bytes accounting. Pure and deterministic in
/// `(spec, supplies, horizon, cell_seed)`.
pub fn run_fleet_cell(
    spec: &FleetSpec,
    supplies: &[Harvester],
    horizon: f64,
    cell_seed: u64,
) -> FleetDigest {
    let n = spec.devices;
    assert_eq!(supplies.len(), n, "fleet cell needs one supply per device");
    let means: Vec<f64> = supplies.iter().map(|h| h.mean_power()).collect();
    let thresholds: Vec<f64> = means.iter().map(|m| spec.up_fraction * m).collect();
    let skews: Vec<f64> = {
        let root = Rng::new(cell_seed ^ 0x5EED_F1EE_7B0A_D5E5);
        (0..n)
            .map(|d| root.clone().fork(d as u64 + 1).uniform() * spec.clock_skew)
            .collect()
    };
    let powered =
        |d: usize, t: f64| -> bool { supplies[d].power_at(t) >= thresholds[d] };

    // Build the merged timeline.
    let mut events: Vec<(f64, Event)> = Vec::new();
    for d in 0..n {
        let mut w = 0u32;
        loop {
            let t1 = (w as f64 + 1.0) * spec.obs_period + skews[d];
            if t1 > horizon {
                break;
            }
            events.push((t1, Event::Obs { device: d, window: w }));
            w += 1;
        }
    }
    let mut slot = 0u64;
    loop {
        let t = (slot as f64 + 1.0) * spec.meeting_period;
        if t > horizon {
            break;
        }
        for i in 0..n {
            for j in (i + 1)..n {
                events.push((t, Event::Meet { slot, i, j }));
            }
        }
        slot += 1;
    }
    events.sort_by(|(ta, ea), (tb, eb)| {
        ta.total_cmp(tb).then_with(|| event_order(ea).cmp(&event_order(eb)))
    });

    let mut replicas: Vec<Replica> = (0..n).map(|d| Replica::new(d, n)).collect();
    let mut cursors: Vec<EnergyCursor> = supplies.iter().map(EnergyCursor::new).collect();
    let mut det_count = vec![0u64; n];
    let mut detect_time: BTreeMap<Key, f64> = BTreeMap::new();
    let mut known: BTreeMap<Key, u32> = BTreeMap::new();

    let mut meetings = 0u64;
    let mut dropped = 0u64;
    let mut exchanges = 0u64;
    let mut bytes = 0u64;
    let mut detections = 0u64;
    let mut propagated = 0u64;
    let mut latency_sum = 0.0f64;
    let mut last_change = 0.0f64;

    for (t, ev) in events {
        match ev {
            Event::Obs { device: d, window: w } => {
                // A device can only sample and record while powered.
                if !powered(d, t) {
                    continue;
                }
                let e = cursors[d].energy(t - spec.obs_period, t);
                if e <= DETECT_FACTOR * means[d] * spec.obs_period {
                    continue;
                }
                detections += 1;
                det_count[d] += 1;
                let row = ((d as u32) << 16) | (w & 0xFFFF);
                replicas[d].write(row, COL_ENERGY, quantise(e));
                replicas[d].write(row, COL_DETECT, 1.0);
                // Every device churns the shared aggregate row: the
                // symmetric tiebreak is exercised in every run, not just
                // contrived tests.
                replicas[d].write(AGG_ROW, COL_COUNT, det_count[d] as f64);
                detect_time.insert((row, COL_DETECT), t);
                known.insert((row, COL_DETECT), 1);
            }
            Event::Meet { slot, i, j } => {
                if !(powered(i, t) && powered(j, t)) {
                    continue;
                }
                meetings += 1;
                let p = (1.0 - spec.drop_rate) * spec.overlap_at(i, j);
                let mut draw = Rng::new(meet_seed(cell_seed, slot, i, j));
                if !draw.chance(p) {
                    dropped += 1;
                    continue;
                }
                exchanges += 1;
                let (lo, hi) = replicas.split_at_mut(j);
                let out = exchange(&mut lo[i], &mut hi[0]);
                bytes += out.bytes;
                if out.entries > 0 {
                    last_change = t;
                }
                for key in out.new_a.iter().chain(out.new_b.iter()) {
                    if key.1 != COL_DETECT || key.0 == AGG_ROW {
                        continue;
                    }
                    let c = known.get_mut(key).expect("detections are registered at origin");
                    *c += 1;
                    if *c == n as u32 {
                        propagated += 1;
                        latency_sum += t - detect_time[key];
                    }
                }
            }
        }
    }

    let reference = replicas[0].state();
    let converged = replicas
        .iter()
        .all(|r| r.state() == reference && r.vv() == replicas[0].vv());
    let duty_sum: f64 = (0..n)
        .map(|d| powered_time(&supplies[d], thresholds[d], horizon) / horizon)
        .sum();
    FleetDigest {
        devices: n as u64,
        meetings,
        dropped,
        exchanges,
        bytes,
        detections,
        propagated,
        latency_sum,
        duty_sum,
        converged,
        converged_at: if converged { last_change } else { horizon },
        gc_pruned: replicas.iter().map(|r| r.gc_pruned).sum(),
    }
}

fn event_order(e: &Event) -> (u8, u64, u64) {
    match e {
        Event::Obs { device, window } => (0, *device as u64, *window as u64),
        Event::Meet { slot, i, j } => (1, ((*i as u64) << 32) | *j as u64, *slot),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_writes_version_per_column() {
        let mut r = Replica::new(0, 2);
        r.write(1, COL_ENERGY, 5.0);
        r.write(1, COL_ENERGY, 7.0);
        r.write(1, COL_DETECT, 1.0);
        let state = r.state();
        assert_eq!(state.len(), 2);
        assert_eq!(state[0], ((1, COL_ENERGY), 2, 0, 7.0f64.to_bits()));
        assert_eq!(state[1], ((1, COL_DETECT), 1, 0, 1.0f64.to_bits()));
        assert_eq!(r.vv(), &[3, 0]);
    }

    #[test]
    fn exchange_ships_only_changed_columns() {
        let mut a = Replica::new(0, 2);
        let mut b = Replica::new(1, 2);
        a.write(1, COL_ENERGY, 5.0);
        a.write(1, COL_DETECT, 1.0);
        let out = exchange(&mut a, &mut b);
        assert_eq!(out.entries, 2);
        assert_eq!(out.new_b.len(), 2);
        assert_eq!(a.state(), b.state());
        // Nothing changed since: the next meeting ships version vectors
        // only.
        let out = exchange(&mut a, &mut b);
        assert_eq!(out.entries, 0);
        assert_eq!(out.bytes, 2 * (EXCHANGE_OVERHEAD + 16));
        // One new column -> exactly one entry, not the whole table.
        b.write(2, COL_DETECT, 1.0);
        let out = exchange(&mut a, &mut b);
        assert_eq!(out.entries, 1);
        assert_eq!(out.new_a, vec![(2, COL_DETECT)]);
    }

    #[test]
    fn concurrent_writes_resolve_symmetrically() {
        // Both write the same column concurrently at the same version:
        // the (version, value bits, writer) order must pick the same
        // winner regardless of which side merges first.
        let mut a = Replica::new(0, 2);
        let mut b = Replica::new(1, 2);
        a.write(AGG_ROW, COL_COUNT, 3.0);
        b.write(AGG_ROW, COL_COUNT, 5.0);
        let (mut a2, mut b2) = (a.clone(), b.clone());
        exchange(&mut a, &mut b);
        exchange(&mut b2, &mut a2);
        assert_eq!(a.state(), b.state());
        assert_eq!(a.state(), a2.state());
        assert_eq!(a2.state(), b2.state());
        // Higher value bits win the version tie.
        let winner = a.state()[0];
        assert_eq!(winner.3, 5.0f64.to_bits());
        assert_eq!(winner.2, 1, "writer 1 wrote the winning value");
    }

    #[test]
    fn equal_values_tiebreak_on_writer() {
        let mut a = Replica::new(0, 2);
        let mut b = Replica::new(1, 2);
        a.write(7, COL_DETECT, 1.0);
        b.write(7, COL_DETECT, 1.0);
        exchange(&mut a, &mut b);
        let s = a.state();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].2, 1, "equal version+value must fall to the higher writer id");
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn relay_through_a_third_party_converges() {
        let mut r: Vec<Replica> = (0..3).map(|d| Replica::new(d, 3)).collect();
        r[0].write(1, COL_DETECT, 1.0);
        // 0 never meets 2; the update travels 0 -> 1 -> 2.
        let (a, rest) = r.split_at_mut(1);
        exchange(&mut a[0], &mut rest[0]);
        let (b, c) = rest.split_at_mut(1);
        let out = exchange(&mut b[0], &mut c[0]);
        assert_eq!(out.new_b, vec![(1, COL_DETECT)]);
        assert_eq!(r[2].state(), r[0].state());
    }

    #[test]
    fn gc_prunes_fully_acknowledged_entries_and_only_those() {
        let mut a = Replica::new(0, 2);
        let mut b = Replica::new(1, 2);
        a.write(1, COL_ENERGY, 5.0);
        a.write(1, COL_DETECT, 1.0);
        assert_eq!(a.log_entries(), 2);
        exchange(&mut a, &mut b);
        // Two-device fleet: one exchange proves the peer holds
        // everything, so both logs drain completely.
        assert_eq!(a.log_entries(), 0, "acknowledged entries must be pruned");
        assert_eq!(b.log_entries(), 0);
        assert!(a.gc_pruned >= 2);
        // New local writes are retained until the peer acks again.
        a.write(2, COL_ENERGY, 3.0);
        a.gc();
        assert_eq!(a.log_entries(), 1, "unacked entries must survive GC");
    }

    #[test]
    fn gc_in_a_triangle_waits_for_the_slowest_peer() {
        let mut r: Vec<Replica> = (0..3).map(|d| Replica::new(d, 3)).collect();
        r[0].write(1, COL_DETECT, 1.0);
        let (a, rest) = r.split_at_mut(1);
        exchange(&mut a[0], &mut rest[0]);
        // Replica 2 has not acked: both 0 and 1 must retain the entry.
        assert_eq!(r[0].log_entries(), 1, "entry retained while a peer lags");
        assert_eq!(r[1].log_entries(), 1);
        let (b, c) = rest.split_at_mut(1);
        exchange(&mut b[0], &mut c[0]);
        // 1 now knows 2 has it; 0 still does not know that.
        assert_eq!(r[1].log_entries(), 0);
        assert_eq!(r[2].log_entries(), 1, "2 cannot know 0 already holds it");
        assert_eq!(r[0].log_entries(), 1);
        // The ack matrix gossips back: 0 learns via its next meeting.
        let (a, rest) = r.split_at_mut(1);
        exchange(&mut a[0], &mut rest[0]);
        assert_eq!(r[0].log_entries(), 0, "gossiped acks must eventually free the log");
    }

    #[test]
    fn merge_order_never_changes_the_converged_state() {
        // Three replicas, overlapping writes including a same-column
        // conflict, three structurally different exchange schedules.
        let build = || {
            let mut r: Vec<Replica> = (0..3).map(|d| Replica::new(d, 3)).collect();
            r[0].write(1, COL_ENERGY, 4.5);
            r[0].write(1, COL_DETECT, 1.0);
            r[1].write(2, COL_DETECT, 1.0);
            r[1].write(AGG_ROW, COL_COUNT, 1.0);
            r[2].write(AGG_ROW, COL_COUNT, 2.0);
            r[2].write(3, COL_ENERGY, 0.25);
            r
        };
        let run = |schedule: &[(usize, usize)]| -> Vec<_> {
            let mut r = build();
            for &(i, j) in schedule {
                let (lo, hi) = r.split_at_mut(j.max(i));
                let (x, y) = (i.min(j), 0);
                exchange(&mut lo[x], &mut hi[y]);
            }
            assert_eq!(r[0].state(), r[1].state());
            assert_eq!(r[1].state(), r[2].state());
            r[0].state()
        };
        let s1 = run(&[(0, 1), (1, 2), (0, 1)]);
        let s2 = run(&[(1, 2), (0, 2), (1, 2), (0, 1)]);
        let s3 = run(&[(0, 2), (0, 1), (1, 2), (0, 2)]);
        assert_eq!(s1, s2, "schedules must converge to identical state");
        assert_eq!(s2, s3);
    }

    #[test]
    fn spec_validation_rejects_hostile_fields() {
        assert!(FleetSpec::default().validate().is_ok());
        let bad = |f: &dyn Fn(&mut FleetSpec)| {
            let mut s = FleetSpec::default();
            f(&mut s);
            s.validate()
        };
        assert!(bad(&|s| s.devices = 1).is_err());
        assert!(bad(&|s| s.devices = 1000).is_err());
        assert!(bad(&|s| s.drop_rate = 1.0).is_err());
        assert!(bad(&|s| s.drop_rate = -0.1).is_err());
        assert!(bad(&|s| s.clock_skew = f64::NAN).is_err());
        assert!(bad(&|s| s.clock_skew = -1.0).is_err());
        assert!(bad(&|s| s.meeting_period = 0.0).is_err());
        assert!(bad(&|s| s.obs_period = f64::INFINITY).is_err());
        assert!(bad(&|s| s.up_fraction = 0.0).is_err());
        // Overlap: wrong shape, out-of-range, asymmetric.
        assert!(bad(&|s| s.overlap = Some(vec![vec![1.0; 4]; 3])).is_err());
        assert!(bad(&|s| s.overlap = Some(vec![vec![2.0; 4]; 4])).is_err());
        let mut asym = vec![vec![1.0; 4]; 4];
        asym[0][1] = 0.5;
        assert!(bad(&|s| s.overlap = Some(asym.clone())).is_err());
        // Budget caps against the horizon.
        let s = FleetSpec { meeting_period: 1e-4, ..FleetSpec::default() };
        assert!(s.validate_with_horizon(3600.0).is_err());
        let s = FleetSpec { obs_period: 1e-3, ..FleetSpec::default() };
        assert!(s.validate_with_horizon(3600.0).is_err());
        assert!(FleetSpec::default().validate_with_horizon(3600.0).is_ok());
    }

    #[test]
    fn spec_json_round_trips() {
        let spec = FleetSpec {
            devices: 3,
            up_fraction: 0.8,
            meeting_period: 10.0,
            obs_period: 30.0,
            drop_rate: 0.25,
            clock_skew: 2.0,
            overlap: Some(vec![
                vec![1.0, 0.5, 0.1],
                vec![0.5, 1.0, 0.9],
                vec![0.1, 0.9, 1.0],
            ]),
        };
        let text = crate::util::json::to_string(&spec.to_json());
        let back = FleetSpec::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert!(FleetSpec::from_json(
            &crate::util::json::parse(r#"{"kind":"fleet","sneaky":1}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn fleet_cell_is_deterministic_and_converges_on_constant_supplies() {
        let spec = FleetSpec { devices: 3, ..FleetSpec::default() };
        let supplies: Vec<Harvester> =
            (0..3).map(|d| Harvester::Constant(1e-3 * (d + 1) as f64)).collect();
        let a = run_fleet_cell(&spec, &supplies, 600.0, 7);
        let b = run_fleet_cell(&spec, &supplies, 600.0, 7);
        assert_eq!(a, b, "fleet cells must be pure functions of their inputs");
        // Constant supplies: always powered, every meeting connects.
        assert!(a.converged, "an always-up fleet must converge");
        assert_eq!(a.dropped, 0);
        assert!((a.duty_sum - 3.0).abs() < 1e-9);
        // Constant supplies never clear the detection threshold, so the
        // only traffic is version vectors.
        assert_eq!(a.detections, 0);
        assert!(a.bytes > 0, "vv exchange costs bytes even with no deltas");
    }

    #[test]
    fn dropout_loses_rendezvous_but_not_correctness() {
        let spec =
            FleetSpec { devices: 3, drop_rate: 0.5, clock_skew: 5.0, ..FleetSpec::default() };
        let supplies: Vec<Harvester> =
            (0..3).map(|d| Harvester::Constant(1e-3 * (d + 1) as f64)).collect();
        let d = run_fleet_cell(&spec, &supplies, 900.0, 11);
        assert!(d.dropped > 0, "a 50% drop rate must lose some rendezvous");
        assert_eq!(d.meetings, d.exchanges + d.dropped);
        assert!(d.converged, "enough meetings survive to converge");
    }
}
