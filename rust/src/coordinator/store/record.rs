//! Crash-safe record framing for the experiment store.
//!
//! A store file is [`MAGIC`] followed by a flat sequence of records:
//!
//! ```text
//! [payload len: u32 LE][CRC-32 of payload: u32 LE][payload bytes]
//! ```
//!
//! Appends are a single `write_all` of a fully assembled frame, so the
//! only states a crash can leave behind are "record absent" and "record
//! torn at the tail". [`scan`] recovers the longest valid prefix: the
//! first frame with a truncated header/payload, a zero or oversized
//! length, a checksum mismatch, or a payload the caller rejects ends the
//! scan, and everything after it is a torn tail the writer may truncate
//! away on its next append.

use std::io::{self, Read};

/// File signature; bump the trailing digit on incompatible layout changes.
pub const MAGIC: &[u8; 8] = b"AICSTOR1";

/// Upper bound on a single record payload (16 MiB). Lengths above this
/// are rejected *before* any buffer is allocated, so a flipped length
/// byte in a torn tail cannot make `open` allocate gigabytes.
pub const MAX_RECORD: u32 = 1 << 24;

const CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) lookup table.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 checksum of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Frame `payload` as one store record. Exposed so the fuzz tests can
/// craft byte-exact duplicate/conflicting records.
pub fn encode_record(payload: &[u8]) -> Vec<u8> {
    assert!(
        !payload.is_empty() && payload.len() <= MAX_RECORD as usize,
        "record payload must be 1..={MAX_RECORD} bytes"
    );
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// One checksum-valid frame recovered by [`scan`].
pub struct Frame {
    /// Byte offset of the frame header within the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u32,
    /// Stored (and verified) payload checksum.
    pub crc: u32,
    /// The payload bytes.
    pub payload: Vec<u8>,
}

/// Scan records sequentially from `r`, which must be positioned at byte
/// offset `start` of the file (just past the magic). `sink` is called per
/// checksum-valid frame and returns whether the payload is semantically
/// acceptable; a rejected frame ends the valid prefix exactly like a torn
/// one. Returns the byte offset one past the last accepted frame.
pub fn scan<R: Read>(
    r: &mut R,
    start: u64,
    mut sink: impl FnMut(Frame) -> bool,
) -> io::Result<u64> {
    let mut offset = start;
    loop {
        let mut header = [0u8; 8];
        if !read_full(r, &mut header)? {
            return Ok(offset);
        }
        let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
        let crc = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
        if len == 0 || len > MAX_RECORD {
            return Ok(offset);
        }
        let mut payload = vec![0u8; len as usize];
        if !read_full(r, &mut payload)? {
            return Ok(offset);
        }
        if crc32(&payload) != crc {
            return Ok(offset);
        }
        let next = offset + 8 + len as u64;
        if !sink(Frame { offset, len, crc, payload }) {
            return Ok(offset);
        }
        offset = next;
    }
}

/// Fill `buf` from `r`; `Ok(false)` on EOF before the buffer is full
/// (clean end of file or torn tail — the caller cannot tell, and does
/// not need to).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_check_value() {
        // The CRC-32 check value from the IEEE 802.3 specification.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn scan_all(bytes: &[u8]) -> (Vec<Vec<u8>>, u64) {
        let mut frames = Vec::new();
        let end = scan(&mut &bytes[..], 0, |f| {
            frames.push(f.payload);
            true
        })
        .unwrap();
        (frames, end)
    }

    #[test]
    fn frames_round_trip() {
        let mut file = Vec::new();
        file.extend_from_slice(&encode_record(b"alpha"));
        file.extend_from_slice(&encode_record(b"beta"));
        let (frames, end) = scan_all(&file);
        assert_eq!(frames, vec![b"alpha".to_vec(), b"beta".to_vec()]);
        assert_eq!(end, file.len() as u64);
    }

    #[test]
    fn torn_tail_keeps_valid_prefix() {
        let first = encode_record(b"alpha");
        let mut file = first.clone();
        file.extend_from_slice(&encode_record(b"beta"));
        // Every truncation point inside the second record salvages only
        // the first; truncations inside the first salvage nothing.
        for cut in 0..file.len() {
            let (frames, end) = scan_all(&file[..cut]);
            if cut < first.len() {
                assert!(frames.is_empty(), "cut {cut}");
                assert_eq!(end, 0, "cut {cut}");
            } else if cut < file.len() {
                assert_eq!(frames.len(), 1, "cut {cut}");
                assert_eq!(end, first.len() as u64, "cut {cut}");
            }
        }
    }

    #[test]
    fn oversized_length_stops_without_allocating() {
        let mut file = encode_record(b"alpha");
        let tail_at = file.len() as u64;
        file.extend_from_slice(&u32::MAX.to_le_bytes());
        file.extend_from_slice(&[0u8; 4]);
        let (frames, end) = scan_all(&file);
        assert_eq!(frames.len(), 1);
        assert_eq!(end, tail_at);
    }

    #[test]
    fn checksum_mismatch_stops_scan() {
        let mut file = encode_record(b"alpha");
        let second_payload_at = file.len() + 8;
        file.extend_from_slice(&encode_record(b"beta"));
        file[second_payload_at] ^= 0x40;
        let (frames, end) = scan_all(&file);
        assert_eq!(frames, vec![b"alpha".to_vec()]);
        assert_eq!(end, 13);
    }

    #[test]
    fn rejected_payload_ends_prefix() {
        let mut file = encode_record(b"good");
        file.extend_from_slice(&encode_record(b"bad"));
        let mut seen = 0;
        let end = scan(&mut &file[..], 0, |f| {
            seen += 1;
            f.payload != b"bad"
        })
        .unwrap();
        assert_eq!(seen, 2);
        assert_eq!(end, (8 + 4) as u64);
    }
}
