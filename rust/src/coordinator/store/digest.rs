//! Per-cell result digests.
//!
//! A [`CellDigest`] is the summary of one campaign cell that the
//! streaming accumulators fold and the experiment store persists. It is
//! computed inside the fleet worker — the full `Campaign` (every round's
//! `RoundResult`) is dropped there, which is what makes streamed sweep
//! memory independent of cell count.
//!
//! Every numeric field is chosen so the accumulators reproduce the batch
//! `SweepRun` projections *bitwise*:
//!
//! * counts and integer sums (emitted rounds, steps, latency cycles) are
//!   exact in `u64` and far below 2^53, so re-deriving a mean as
//!   `sum as f64 / count as f64` equals the batch left-to-right fold over
//!   the same integers;
//! * latency histogram bins are taken from [`metrics::latency_histogram`]
//!   — the *same* float-binning code path the batch uses — and summed as
//!   integers;
//! * coherence needs cross-cell round alignment, so HAR digests keep the
//!   `(slot, prediction)` sequence of emitted rounds when the projection
//!   asks for it ([`Needs::slots`]).

use crate::audio::app::AudioOutput;
use crate::coordinator::metrics;
use crate::coordinator::scenario::{Projection, LATENCY_CYCLES};
use crate::exec::Campaign;
use crate::har::app::HarOutput;
use crate::imgproc::app::CornerOutput;
use crate::imgproc::equivalence::equivalent;
use crate::imgproc::images::{Picture, EVAL_SIZE};
use crate::util::json::Value;

/// Which optional digest payloads a projection folds. Encoded into the
/// experiment hash, so records are only reused by runs that stored the
/// fields they need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Needs {
    /// Per-round `(slot, prediction)` pairs for coherence alignment.
    pub slots: bool,
    /// Pooled latency histogram bins.
    pub latency: bool,
    /// Per-picture equivalence counts.
    pub pictures: bool,
    /// Fleet sync counters (convergence, bytes, propagation latency).
    pub fleet: bool,
}

impl Needs {
    pub fn for_projection(p: Projection) -> Needs {
        Needs {
            slots: matches!(
                p,
                Projection::PolicyCoherence | Projection::PolicyVsChinchilla
            ),
            latency: matches!(
                p,
                Projection::LatencyEmulation | Projection::LatencyRealWorld
            ),
            pictures: matches!(p, Projection::ImgEquivalence),
            fleet: matches!(
                p,
                Projection::FleetLatency
                    | Projection::FleetConvergence
                    | Projection::FleetBytes
            ),
        }
    }

    pub fn none() -> Needs {
        Needs { slots: false, latency: false, pictures: false, fleet: false }
    }
}

/// Pooled latency histogram payload (bins are power-cycle counts; rounds
/// at `LATENCY_CYCLES` or beyond land in `overflow`).
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyBins {
    pub bins: Vec<u64>,
    pub overflow: u64,
}

/// The summary of one fleet-sync cell: N devices, opportunistic
/// changed-column exchanges, convergence and wire-cost accounting.
/// Attached to [`CellDigest::fleet`] so fleet cells stream, dedup, and
/// resume through the same store machinery as every other campaign.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetDigest {
    /// Fleet size.
    pub devices: u64,
    /// Rendezvous where both endpoints were powered.
    pub meetings: u64,
    /// Powered rendezvous lost to the drop-out / overlap draw.
    pub dropped: u64,
    /// Rendezvous that actually exchanged deltas.
    pub exchanges: u64,
    /// Modelled wire bytes across all exchanges.
    pub bytes: u64,
    /// Detection events recorded fleet-wide.
    pub detections: u64,
    /// Detections that reached every replica within the horizon.
    pub propagated: u64,
    /// Sum of full-propagation latencies, seconds (over `propagated`).
    pub latency_sum: f64,
    /// Sum of per-device powered-time fractions (0..=devices).
    pub duty_sum: f64,
    /// All replicas bitwise-identical at the horizon?
    pub converged: bool,
    /// Time of the last state-changing exchange (horizon when not
    /// converged).
    pub converged_at: f64,
    /// Retransmission-log entries retired by coordination-free GC.
    pub gc_pruned: u64,
}

impl FleetDigest {
    /// Fraction of detections known fleet-wide by the horizon.
    pub fn coverage(&self) -> f64 {
        if self.detections == 0 {
            0.0
        } else {
            self.propagated as f64 / self.detections as f64
        }
    }

    /// Mean detection-to-fleet-wide latency, seconds.
    pub fn mean_latency(&self) -> f64 {
        if self.propagated == 0 {
            0.0
        } else {
            self.latency_sum / self.propagated as f64
        }
    }

    /// Mean per-device powered-time fraction.
    pub fn duty_cycle(&self) -> f64 {
        if self.devices == 0 {
            0.0
        } else {
            self.duty_sum / self.devices as f64
        }
    }

    /// Mean wire bytes per realised exchange.
    pub fn bytes_per_exchange(&self) -> f64 {
        if self.exchanges == 0 {
            0.0
        } else {
            self.bytes as f64 / self.exchanges as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("dev", (self.devices as f64).into()),
            ("meet", (self.meetings as f64).into()),
            ("drop", (self.dropped as f64).into()),
            ("exch", (self.exchanges as f64).into()),
            ("bytes", (self.bytes as f64).into()),
            ("det", (self.detections as f64).into()),
            ("prop", (self.propagated as f64).into()),
            ("lat_s", self.latency_sum.into()),
            ("duty", self.duty_sum.into()),
            ("conv", self.converged.into()),
            ("conv_at", self.converged_at.into()),
            ("gc", (self.gc_pruned as f64).into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<FleetDigest, String> {
        let o = v.as_obj().ok_or("fleet digest must be a JSON object")?;
        let num = |k: &str| -> Result<f64, String> {
            o.get(k)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("fleet digest missing numeric field '{k}'"))
        };
        let uint = |k: &str| -> Result<u64, String> {
            o.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("fleet digest missing integer field '{k}'"))
        };
        Ok(FleetDigest {
            devices: uint("dev")?,
            meetings: uint("meet")?,
            dropped: uint("drop")?,
            exchanges: uint("exch")?,
            bytes: uint("bytes")?,
            detections: uint("det")?,
            propagated: uint("prop")?,
            latency_sum: num("lat_s")?,
            duty_sum: num("duty")?,
            converged: o
                .get("conv")
                .and_then(Value::as_bool)
                .ok_or("fleet digest missing boolean field 'conv'")?,
            converged_at: num("conv_at")?,
            gc_pruned: uint("gc")?,
        })
    }
}

/// The persistent summary of one campaign cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CellDigest {
    /// Emitted (delivered) rounds.
    pub emitted: u64,
    /// Campaign duration, seconds.
    pub duration: f64,
    pub power_cycles: u64,
    pub power_failures: u64,
    pub app_energy: f64,
    pub state_energy: f64,
    /// Quality numerator/denominator over emitted rounds with an output:
    /// correct classifications (HAR/audio) or equivalent corner maps
    /// (imaging).
    pub quality_ok: u64,
    pub quality_total: u64,
    /// Emitted rounds delivered in the acquisition power cycle.
    pub same_cycle: u64,
    /// Sum of `steps_executed` over emitted rounds.
    pub steps_sum: u64,
    /// Sum of `latency_cycles` over emitted rounds.
    pub latency_sum: u64,
    /// Latency histogram (when [`Needs::latency`]).
    pub latency_bins: Option<LatencyBins>,
    /// `(sampling slot, predicted class)` per emitted round with an
    /// output, in round order (when [`Needs::slots`]).
    pub slots: Option<Vec<(i64, u64)>>,
    /// Per-picture `(equivalent, total)` counts in `Picture::ALL` order
    /// (when [`Needs::pictures`]).
    pub pictures: Option<Vec<(u64, u64)>>,
    /// Fleet-sync counters (when [`Needs::fleet`]).
    pub fleet: Option<FleetDigest>,
}

/// The scalar core shared by every workload's digest.
fn base<O>(c: &Campaign<O>) -> CellDigest {
    let mut emitted = 0u64;
    let mut same_cycle = 0u64;
    let mut steps_sum = 0u64;
    let mut latency_sum = 0u64;
    for r in c.emitted() {
        emitted += 1;
        if r.latency_cycles == 0 {
            same_cycle += 1;
        }
        steps_sum += r.steps_executed as u64;
        latency_sum += r.latency_cycles;
    }
    CellDigest {
        emitted,
        duration: c.duration,
        power_cycles: c.power_cycles,
        power_failures: c.power_failures,
        app_energy: c.app_energy,
        state_energy: c.state_energy,
        quality_ok: 0,
        quality_total: 0,
        same_cycle,
        steps_sum,
        latency_sum,
        latency_bins: None,
        slots: None,
        pictures: None,
        fleet: None,
    }
}

fn latency_bins<O>(c: &Campaign<O>) -> LatencyBins {
    // Same code path as the batch histograms: float binning on integer
    // latencies is not safely re-derivable by integer arithmetic.
    let h = metrics::latency_histogram(c, LATENCY_CYCLES);
    LatencyBins { bins: h.bins, overflow: h.overflow }
}

impl CellDigest {
    /// Digest a HAR campaign. `period` is the resolved scenario's
    /// sampling period (slot alignment for coherence).
    pub fn of_har(c: &Campaign<HarOutput>, period: f64, needs: Needs) -> CellDigest {
        let mut d = base(c);
        let mut slots = needs.slots.then(Vec::new);
        for r in c.emitted() {
            if let Some(out) = &r.output {
                d.quality_total += 1;
                if out.predicted == out.truth as usize {
                    d.quality_ok += 1;
                }
                if let Some(slots) = &mut slots {
                    slots.push(((r.acquired_at / period).floor() as i64, out.predicted as u64));
                }
            }
        }
        d.slots = slots;
        if needs.latency {
            d.latency_bins = Some(latency_bins(c));
        }
        d
    }

    /// Digest an imaging campaign (quality = §6.3 corner equivalence
    /// against the memoised full-precision reference).
    pub fn of_img(c: &Campaign<CornerOutput>, needs: Needs) -> CellDigest {
        let mut d = base(c);
        let mut pictures = needs.pictures.then(|| vec![(0u64, 0u64); Picture::ALL.len()]);
        for r in c.emitted() {
            if let Some(out) = &r.output {
                d.quality_total += 1;
                let reference = metrics::harris_reference(out.picture, out.picture_seed, EVAL_SIZE);
                let ok = equivalent(&reference, &out.corners);
                if ok {
                    d.quality_ok += 1;
                }
                if let Some(pics) = &mut pictures {
                    if let Some(pi) =
                        Picture::ALL.iter().position(|p| p.name() == out.picture.name())
                    {
                        pics[pi].1 += 1;
                        if ok {
                            pics[pi].0 += 1;
                        }
                    }
                }
            }
        }
        d.pictures = pictures;
        if needs.latency {
            d.latency_bins = Some(latency_bins(c));
        }
        d
    }

    /// Digest a fleet-sync cell. The scalar core is mapped so the plain
    /// `cells` projection stays meaningful on fleet grids: emitted =
    /// detections, power cycles = powered rendezvous, failures = dropped
    /// rendezvous, quality = fleet-wide propagation coverage, steps =
    /// realised exchanges. The full [`FleetDigest`] rides along for the
    /// fleet projections.
    pub fn of_fleet(f: &FleetDigest, horizon: f64) -> CellDigest {
        CellDigest {
            emitted: f.detections,
            duration: horizon,
            power_cycles: f.meetings,
            power_failures: f.dropped,
            app_energy: 0.0,
            state_energy: 0.0,
            quality_ok: f.propagated,
            quality_total: f.detections,
            same_cycle: 0,
            steps_sum: f.exchanges,
            latency_sum: 0,
            latency_bins: None,
            slots: None,
            pictures: None,
            fleet: Some(*f),
        }
    }

    /// Digest an audio campaign.
    pub fn of_audio(c: &Campaign<AudioOutput>, needs: Needs) -> CellDigest {
        let mut d = base(c);
        for r in c.emitted() {
            if let Some(out) = &r.output {
                d.quality_total += 1;
                if out.predicted == out.truth {
                    d.quality_ok += 1;
                }
            }
        }
        if needs.latency {
            d.latency_bins = Some(latency_bins(c));
        }
        d
    }

    /// Quality as a fraction — exactly `emitted_fraction`'s arithmetic.
    pub fn quality(&self) -> f64 {
        if self.quality_total == 0 {
            0.0
        } else {
            self.quality_ok as f64 / self.quality_total as f64
        }
    }

    /// Same-cycle delivery fraction — exactly `same_cycle_fraction`.
    pub fn same_cycle_fraction(&self) -> f64 {
        if self.emitted == 0 {
            0.0
        } else {
            self.same_cycle as f64 / self.emitted as f64
        }
    }

    /// Emitted results per second — exactly `Campaign::throughput`.
    pub fn throughput(&self) -> f64 {
        if self.duration == 0.0 {
            return 0.0;
        }
        self.emitted as f64 / self.duration
    }

    /// Mean of an integer per-round quantity over emitted rounds —
    /// bitwise equal to the batch `mean(...)` fold because integer sums
    /// below 2^53 are exact in f64.
    pub fn mean_over_emitted(&self, sum: u64) -> f64 {
        if self.emitted == 0 {
            0.0
        } else {
            sum as f64 / self.emitted as f64
        }
    }

    /// Does this digest carry every payload `needs` asks for? A record
    /// that does not (foreign writer, conflicting format) is treated as
    /// absent rather than folded.
    pub fn satisfies(&self, needs: Needs) -> bool {
        (!needs.slots || self.slots.is_some())
            && (!needs.latency
                || self
                    .latency_bins
                    .as_ref()
                    .is_some_and(|lb| lb.bins.len() == LATENCY_CYCLES))
            && (!needs.pictures
                || self.pictures.as_ref().is_some_and(|p| p.len() == Picture::ALL.len()))
            && (!needs.fleet || self.fleet.is_some())
    }

    // -----------------------------------------------------------------
    // JSON (the store's record payload body).
    // -----------------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut fields: Vec<(&str, Value)> = vec![
            ("emitted", (self.emitted as f64).into()),
            ("duration", self.duration.into()),
            ("cycles", (self.power_cycles as f64).into()),
            ("failures", (self.power_failures as f64).into()),
            ("app", self.app_energy.into()),
            ("state", self.state_energy.into()),
            ("q_ok", (self.quality_ok as f64).into()),
            ("q_total", (self.quality_total as f64).into()),
            ("same", (self.same_cycle as f64).into()),
            ("steps", (self.steps_sum as f64).into()),
            ("lat", (self.latency_sum as f64).into()),
        ];
        if let Some(lb) = &self.latency_bins {
            fields.push(("bins", Value::u64s(&lb.bins)));
            fields.push(("overflow", (lb.overflow as f64).into()));
        }
        if let Some(slots) = &self.slots {
            let flat: Vec<f64> =
                slots.iter().flat_map(|&(s, p)| [s as f64, p as f64]).collect();
            fields.push(("slots", Value::nums(&flat)));
        }
        if let Some(pics) = &self.pictures {
            let flat: Vec<u64> = pics.iter().flat_map(|&(ok, t)| [ok, t]).collect();
            fields.push(("pics", Value::u64s(&flat)));
        }
        if let Some(f) = &self.fleet {
            fields.push(("fleet", f.to_json()));
        }
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<CellDigest, String> {
        let o = v.as_obj().ok_or("cell digest must be a JSON object")?;
        let num = |k: &str| -> Result<f64, String> {
            o.get(k)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("digest missing numeric field '{k}'"))
        };
        let uint = |k: &str| -> Result<u64, String> {
            o.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| format!("digest missing integer field '{k}'"))
        };
        let latency_bins = match o.get("bins") {
            Some(v) => Some(LatencyBins {
                bins: v
                    .as_u64s()
                    .ok_or("digest 'bins' must be a non-negative integer array")?,
                overflow: uint("overflow")?,
            }),
            None => None,
        };
        let slots = match o.get("slots") {
            Some(v) => Some(
                pair_list(v, |s, p| Some((as_i64(s)?, p.as_u64()?)))
                    .ok_or("digest 'slots' must be an even array of integers")?,
            ),
            None => None,
        };
        let pictures = match o.get("pics") {
            Some(v) => Some(
                pair_list(v, |ok, t| Some((ok.as_u64()?, t.as_u64()?)))
                    .ok_or("digest 'pics' must be an even array of counts")?,
            ),
            None => None,
        };
        let fleet = match o.get("fleet") {
            Some(v) => Some(FleetDigest::from_json(v)?),
            None => None,
        };
        Ok(CellDigest {
            emitted: uint("emitted")?,
            duration: num("duration")?,
            power_cycles: uint("cycles")?,
            power_failures: uint("failures")?,
            app_energy: num("app")?,
            state_energy: num("state")?,
            quality_ok: uint("q_ok")?,
            quality_total: uint("q_total")?,
            same_cycle: uint("same")?,
            steps_sum: uint("steps")?,
            latency_sum: uint("lat")?,
            latency_bins,
            slots,
            pictures,
            fleet,
        })
    }
}

fn as_i64(v: &Value) -> Option<i64> {
    let f = v.as_f64()?;
    (f.fract() == 0.0 && f.abs() <= 9.0e15).then_some(f as i64)
}

fn pair_list<T>(v: &Value, f: impl Fn(&Value, &Value) -> Option<T>) -> Option<Vec<T>> {
    let arr = v.as_arr()?;
    if arr.len() % 2 != 0 {
        return None;
    }
    arr.chunks(2).map(|c| f(&c[0], &c[1])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_fleet() -> FleetDigest {
        FleetDigest {
            devices: 4,
            meetings: 120,
            dropped: 12,
            exchanges: 108,
            bytes: 86_400,
            detections: 40,
            propagated: 36,
            latency_sum: 512.25,
            duty_sum: 2.75,
            converged: true,
            converged_at: 3_420.5,
            gc_pruned: 96,
        }
    }

    fn sample(needs: Needs) -> CellDigest {
        CellDigest {
            emitted: 12,
            duration: 900.0,
            power_cycles: 34,
            power_failures: 33,
            app_energy: 1.25e-3,
            state_energy: 2.5e-4,
            quality_ok: 10,
            quality_total: 12,
            same_cycle: 9,
            steps_sum: 840,
            latency_sum: 17,
            latency_bins: needs.latency.then(|| LatencyBins {
                bins: vec![0; LATENCY_CYCLES],
                overflow: 2,
            }),
            slots: needs.slots.then(|| vec![(0, 3), (1, 3), (5, 0)]),
            pictures: needs
                .pictures
                .then(|| vec![(1u64, 2u64); Picture::ALL.len()]),
            fleet: needs.fleet.then(sample_fleet),
        }
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        for needs in [
            Needs::none(),
            Needs { slots: true, latency: false, pictures: false, fleet: false },
            Needs { slots: false, latency: true, pictures: false, fleet: false },
            Needs { slots: false, latency: false, pictures: true, fleet: false },
            Needs { slots: false, latency: false, pictures: false, fleet: true },
            Needs { slots: true, latency: true, pictures: true, fleet: true },
        ] {
            let d = sample(needs);
            let text = json::to_string(&d.to_json());
            let back = CellDigest::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, d);
            assert!(back.satisfies(needs));
        }
    }

    #[test]
    fn satisfies_rejects_missing_or_misshapen_payloads() {
        let d = sample(Needs::none());
        assert!(d.satisfies(Needs::none()));
        assert!(!d.satisfies(Needs { slots: true, ..Needs::none() }));
        assert!(!d.satisfies(Needs { fleet: true, ..Needs::none() }));
        let fleet_needs = Needs { fleet: true, ..Needs::none() };
        assert!(sample(fleet_needs).satisfies(fleet_needs));
        let lat_needs = Needs { latency: true, ..Needs::none() };
        let mut short = sample(lat_needs);
        short.latency_bins.as_mut().unwrap().bins.pop();
        assert!(!short.satisfies(lat_needs));
    }

    #[test]
    fn fleet_digest_round_trips_and_rejects_malformed_payloads() {
        let f = sample_fleet();
        let text = json::to_string(&f.to_json());
        let back = FleetDigest::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, f);
        for text in [
            "{}",
            "7",
            r#"{"dev":4,"meet":1,"drop":0,"exch":1,"bytes":64,"det":0,"prop":0,
                "lat_s":0.0,"duty":1.0,"conv":1,"conv_at":0.0,"gc":0}"#,
            r#"{"dev":-4,"meet":1,"drop":0,"exch":1,"bytes":64,"det":0,"prop":0,
                "lat_s":0.0,"duty":1.0,"conv":true,"conv_at":0.0,"gc":0}"#,
        ] {
            let v = json::parse(text).unwrap();
            assert!(FleetDigest::from_json(&v).is_err(), "{text}");
        }
    }

    #[test]
    fn fleet_derivations_are_zero_guarded() {
        let f = sample_fleet();
        assert_eq!(f.coverage(), 36.0 / 40.0);
        assert_eq!(f.mean_latency(), 512.25 / 36.0);
        assert_eq!(f.duty_cycle(), 2.75 / 4.0);
        assert_eq!(f.bytes_per_exchange(), 86_400.0 / 108.0);
        let empty = FleetDigest {
            detections: 0,
            propagated: 0,
            exchanges: 0,
            devices: 0,
            ..sample_fleet()
        };
        assert_eq!(empty.coverage(), 0.0);
        assert_eq!(empty.mean_latency(), 0.0);
        assert_eq!(empty.duty_cycle(), 0.0);
        assert_eq!(empty.bytes_per_exchange(), 0.0);
    }

    #[test]
    fn fleet_scalar_core_maps_the_cells_projection() {
        let f = sample_fleet();
        let d = CellDigest::of_fleet(&f, 3600.0);
        assert_eq!(d.emitted, f.detections);
        assert_eq!(d.duration, 3600.0);
        assert_eq!(d.power_cycles, f.meetings);
        assert_eq!(d.power_failures, f.dropped);
        assert_eq!(d.quality(), f.coverage());
        assert_eq!(d.steps_sum, f.exchanges);
        assert_eq!(d.fleet, Some(f));
        assert!(d.satisfies(Needs { fleet: true, ..Needs::none() }));
        // And it survives the store's JSON framing.
        let text = json::to_string(&d.to_json());
        let back = CellDigest::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn from_json_rejects_malformed_digests() {
        for text in [
            "{}",
            "[1,2]",
            r#"{"emitted":-1}"#,
            r#"{"emitted":1,"duration":1.0,"cycles":1,"failures":0,"app":0,"state":0,
                "q_ok":1,"q_total":1,"same":1,"steps":1,"lat":0,"slots":[1]}"#,
        ] {
            let v = json::parse(text).unwrap();
            assert!(CellDigest::from_json(&v).is_err(), "{text}");
        }
    }

    #[test]
    fn derived_fractions_match_metric_arithmetic() {
        let d = sample(Needs::none());
        assert_eq!(d.quality(), 10.0 / 12.0);
        assert_eq!(d.same_cycle_fraction(), 9.0 / 12.0);
        assert_eq!(d.throughput(), 12.0 / 900.0);
        assert_eq!(d.mean_over_emitted(d.steps_sum), 840.0 / 12.0);
        let empty = CellDigest { emitted: 0, quality_total: 0, ..sample(Needs::none()) };
        assert_eq!(empty.same_cycle_fraction(), 0.0);
        assert_eq!(empty.quality(), 0.0);
        assert_eq!(empty.mean_over_emitted(0), 0.0);
    }
}
