//! The persistent, resumable experiment store.
//!
//! A store is **one append-only file** of checksummed records (see
//! [`record`]) holding labelled experiments and their per-cell result
//! digests — the shape `bsdinis/bencher` gives benchmark campaigns
//! (labelled experiments, status/table/export views, dedup on re-run),
//! rebuilt dependency-free so tier-1 keeps building with zero crates.
//! An optional `sqlite` feature (see `Cargo.toml`) can push a dump into
//! rusqlite; the built-in `export --format sql` emits the same schema as
//! plain SQL text for `sqlite3 runs.db < runs.sql`.
//!
//! Two record kinds, both JSON payloads:
//!
//! * `{"k":"exp","hash":h,"label":l,"scenario":{...}}` — registers a
//!   campaign grid: `hash` identifies the resolved scenario (see
//!   [`grid_hash`]) and `scenario` is its full JSON, kept so `aic store
//!   table` can reconstruct cell identities without the original file.
//! * `{"k":"cell","hash":h,"idx":i,"d":{...}}` — the digest of grid cell
//!   `i` (plan order) of experiment `h`.
//!
//! **Dedup key:** `(hash, idx)`. The first committed record for a key
//! wins; a byte-identical re-append counts as a duplicate, a differing
//! one as a conflict — neither is ever double-counted. Resume falls out
//! of dedup: a re-run skips every cell whose key is already committed.
//!
//! **Crash safety:** appends are one `write_all` of a length-prefixed,
//! CRC-checked frame. `open` tolerates a torn tail (and any garbage
//! after the valid prefix): it indexes the longest valid prefix and the
//! next append truncates the tail away. Only digest *offsets* are
//! indexed — digests are re-read lazily — so open cost is one sequential
//! scan and resident state is O(cells) keys, not O(file).

pub mod digest;
pub mod record;

// `sqlite` is a declared-but-empty feature by the same policy as `pjrt`
// (see Cargo.toml): enabling it requires adding the rusqlite dependency
// locally, which offline tier-1 builds must never resolve.
#[cfg(feature = "sqlite")]
pub mod sqlite;

pub use digest::{CellDigest, LatencyBins, Needs};
pub use record::{encode_record, MAGIC, MAX_RECORD};

use crate::coordinator::scenario::{self, Scenario};
use crate::coordinator::sink::TableData;
use crate::util::json::{self, Value};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// FNV-1a 64-bit hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Identity hash of a **resolved** scenario's campaign grid.
///
/// Hashes the canonical (sorted-key, compact) JSON of everything that
/// determines cell results — workload, axes, horizon, period, training —
/// plus the *effective* engine kind per device (the `AIC_ENGINE`
/// fallback changes results without appearing in the scenario JSON) and
/// the digest payload shape ([`Needs`], so records are only reused by
/// projections they can serve). Presentation-only fields (`name`,
/// `title`, `projection`) and the already-applied `fast` block are
/// excluded: renaming a scenario must not orphan its committed cells.
pub fn grid_hash(s: &Scenario, needs: Needs) -> u64 {
    let Value::Obj(mut doc) = s.to_json() else {
        unreachable!("Scenario::to_json always returns an object");
    };
    for k in ["name", "title", "projection", "fast"] {
        doc.remove(k);
    }
    doc.insert(
        "engines".into(),
        Value::Arr(
            s.devices
                .iter()
                .map(|d| Value::Str(d.engine_config(s.horizon).kind.label().to_string()))
                .collect(),
        ),
    );
    let mut needs_fields = vec![
        ("slots", needs.slots.into()),
        ("latency", needs.latency.into()),
        ("pictures", needs.pictures.into()),
    ];
    // Only present when set: pre-fleet stores hashed a three-key needs
    // object, and an unconditional fourth key would orphan every
    // committed cell of every existing experiment.
    if needs.fleet {
        needs_fields.push(("fleet", true.into()));
    }
    doc.insert("needs".into(), Value::obj(needs_fields));
    doc.insert("store_format".into(), Value::Num(1.0));
    fnv1a(json::to_string(&Value::Obj(doc)).as_bytes())
}

/// One registered experiment (campaign grid) in a store.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub label: String,
    pub hash: u64,
    /// The resolved scenario's JSON as committed.
    pub scenario: Value,
}

#[derive(Clone, Copy, Debug)]
struct CellLoc {
    offset: u64,
    len: u32,
    crc: u32,
}

/// An open experiment store.
pub struct Store {
    path: PathBuf,
    file: File,
    /// Logical end of file: one past the last valid record.
    end: u64,
    /// Physical bytes past `end` left by a torn tail (diagnostic; the
    /// next append truncates them).
    salvaged_bytes: u64,
    needs_truncate: bool,
    index: HashMap<(u64, u32), CellLoc>,
    experiments: Vec<Experiment>,
    duplicates: u64,
    conflicts: u64,
}

impl Store {
    /// Open (or create) the store at `path`, indexing the longest valid
    /// record prefix. A file that exists but does not start with the
    /// store magic is refused — never silently clobbered.
    pub fn open(path: &Path) -> io::Result<Store> {
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let file_len = file.metadata()?.len();
        if file_len == 0 {
            file.write_all(MAGIC)?;
            return Ok(Store {
                path: path.to_path_buf(),
                file,
                end: MAGIC.len() as u64,
                salvaged_bytes: 0,
                needs_truncate: false,
                index: HashMap::new(),
                experiments: Vec::new(),
                duplicates: 0,
                conflicts: 0,
            });
        }
        if file_len < MAGIC.len() as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: not an aic store (short magic)", path.display()),
            ));
        }
        let mut magic = [0u8; 8];
        file.seek(SeekFrom::Start(0))?;
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: not an aic store (bad magic)", path.display()),
            ));
        }
        let mut index: HashMap<(u64, u32), CellLoc> = HashMap::new();
        let mut experiments: Vec<Experiment> = Vec::new();
        let mut duplicates = 0u64;
        let mut conflicts = 0u64;
        let end = {
            let mut reader = BufReader::new(&mut file);
            record::scan(&mut reader, MAGIC.len() as u64, |frame| {
                let Ok(text) = std::str::from_utf8(&frame.payload) else {
                    return false;
                };
                let Ok(v) = json::parse(text) else { return false };
                let Some(o) = v.as_obj() else { return false };
                match o.get("k").and_then(Value::as_str) {
                    Some("exp") => {
                        let hash = o.get("hash").and_then(Value::as_str).and_then(parse_hash);
                        let label = o.get("label").and_then(Value::as_str);
                        let scenario = o.get("scenario");
                        let (Some(hash), Some(label), Some(scenario)) =
                            (hash, label, scenario)
                        else {
                            return false;
                        };
                        if !experiments.iter().any(|e| e.hash == hash) {
                            experiments.push(Experiment {
                                label: label.to_string(),
                                hash,
                                scenario: scenario.clone(),
                            });
                        }
                        true
                    }
                    Some("cell") => {
                        let hash = o.get("hash").and_then(Value::as_str).and_then(parse_hash);
                        let idx = o
                            .get("idx")
                            .and_then(Value::as_u64)
                            .filter(|&i| i <= u32::MAX as u64);
                        let (Some(hash), Some(idx)) = (hash, idx) else { return false };
                        if o.get("d").and_then(Value::as_obj).is_none() {
                            return false;
                        }
                        match index.entry((hash, idx as u32)) {
                            Entry::Vacant(e) => {
                                e.insert(CellLoc {
                                    offset: frame.offset,
                                    len: frame.len,
                                    crc: frame.crc,
                                });
                            }
                            Entry::Occupied(prev) => {
                                // First record wins, always: a re-run
                                // must never double-count a cell.
                                let p = prev.get();
                                if p.len == frame.len && p.crc == frame.crc {
                                    duplicates += 1;
                                } else {
                                    conflicts += 1;
                                }
                            }
                        }
                        true
                    }
                    // Unknown record kind (newer writer): skip, keep
                    // scanning — the checksum already vouched for it.
                    _ => true,
                }
            })?
        };
        let salvaged_bytes = file_len - end;
        Ok(Store {
            path: path.to_path_buf(),
            file,
            end,
            salvaged_bytes,
            needs_truncate: salvaged_bytes > 0,
            index,
            experiments,
            duplicates,
            conflicts,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Experiments in commit order.
    pub fn experiments(&self) -> &[Experiment] {
        &self.experiments
    }

    /// Total committed cell records (across experiments).
    pub fn cell_count(&self) -> usize {
        self.index.len()
    }

    /// Committed cell records for one experiment.
    pub fn cell_count_for(&self, hash: u64) -> usize {
        self.index.keys().filter(|(h, _)| *h == hash).count()
    }

    /// Sorted committed cell indices for one experiment.
    pub fn cell_indices(&self, hash: u64) -> Vec<u32> {
        let mut out: Vec<u32> =
            self.index.keys().filter(|(h, _)| *h == hash).map(|(_, i)| *i).collect();
        out.sort_unstable();
        out
    }

    /// Byte-identical re-appends observed on open (idempotent writers).
    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    /// Differing records for an already-committed key observed on open
    /// (the first record stayed authoritative).
    pub fn conflicts(&self) -> u64 {
        self.conflicts
    }

    /// Torn-tail bytes past the valid prefix found on open.
    pub fn salvaged_bytes(&self) -> u64 {
        self.salvaged_bytes
    }

    pub fn has_cell(&self, hash: u64, idx: u32) -> bool {
        self.index.contains_key(&(hash, idx))
    }

    /// Read one committed digest (seek + re-parse; digests are not kept
    /// resident).
    pub fn read_cell(&mut self, hash: u64, idx: u32) -> io::Result<Option<CellDigest>> {
        let Some(loc) = self.index.get(&(hash, idx)).copied() else {
            return Ok(None);
        };
        self.file.seek(SeekFrom::Start(loc.offset + 8))?;
        let mut payload = vec![0u8; loc.len as usize];
        self.file.read_exact(&mut payload)?;
        // The frame was checksum-valid on open; failing here means the
        // file changed underneath us.
        let invalid = |m: String| io::Error::new(io::ErrorKind::InvalidData, m);
        let text = std::str::from_utf8(&payload)
            .map_err(|e| invalid(format!("store record not UTF-8: {e}")))?;
        let v = json::parse(text).map_err(|e| invalid(format!("store record: {e:?}")))?;
        CellDigest::from_json(v.get("d")).map(Some).map_err(invalid)
    }

    /// Register an experiment (no-op if `hash` is already present; the
    /// first label sticks).
    pub fn ensure_experiment(
        &mut self,
        label: &str,
        hash: u64,
        scenario: &Scenario,
    ) -> io::Result<()> {
        if self.experiments.iter().any(|e| e.hash == hash) {
            return Ok(());
        }
        let scenario_json = scenario.to_json();
        let payload = Value::obj(vec![
            ("k", "exp".into()),
            ("hash", format!("{hash:016x}").as_str().into()),
            ("label", label.into()),
            ("scenario", scenario_json.clone()),
        ]);
        self.append_payload(&payload)?;
        self.experiments.push(Experiment {
            label: label.to_string(),
            hash,
            scenario: scenario_json,
        });
        Ok(())
    }

    /// Commit one cell digest. Returns `false` (writing nothing) when
    /// the key is already committed — the resume/dedup path.
    pub fn append_cell(
        &mut self,
        hash: u64,
        idx: u32,
        digest: &CellDigest,
    ) -> io::Result<bool> {
        if self.has_cell(hash, idx) {
            return Ok(false);
        }
        let payload = Value::obj(vec![
            ("k", "cell".into()),
            ("hash", format!("{hash:016x}").as_str().into()),
            ("idx", (idx as f64).into()),
            ("d", digest.to_json()),
        ]);
        let loc = self.append_payload(&payload)?;
        self.index.insert((hash, idx), loc);
        Ok(true)
    }

    fn append_payload(&mut self, payload: &Value) -> io::Result<CellLoc> {
        if self.needs_truncate {
            // Self-heal: drop the torn tail before the first new record.
            self.file.set_len(self.end)?;
            self.needs_truncate = false;
        }
        let bytes = json::to_string(payload).into_bytes();
        let frame = encode_record(&bytes);
        let crc = record::crc32(&bytes);
        self.file.seek(SeekFrom::Start(self.end))?;
        self.file.write_all(&frame)?;
        let loc = CellLoc { offset: self.end, len: bytes.len() as u32, crc };
        self.end += frame.len() as u64;
        Ok(loc)
    }

    /// Flush committed records to stable storage.
    pub fn sync(&mut self) -> io::Result<()> {
        self.file.sync_data()
    }

    // -----------------------------------------------------------------
    // Views (`aic store status|table|export`).
    // -----------------------------------------------------------------

    /// The status view: one row per experiment, plus a file-integrity
    /// table.
    pub fn status_tables(&self) -> Vec<TableData> {
        let mut exps = TableData::new(
            "store_status",
            &format!("experiments in {}", self.path.display()),
            &["label", "hash", "scenario", "cells", "grid"],
        );
        for e in &self.experiments {
            let name = e
                .scenario
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("?")
                .to_string();
            let grid = Scenario::from_json(&e.scenario)
                .map(|s| s.campaign_cell_count().to_string())
                .unwrap_or_else(|_| "?".into());
            exps.push(vec![
                e.label.clone(),
                format!("{:016x}", e.hash),
                name,
                self.cell_count_for(e.hash).to_string(),
                grid,
            ]);
        }
        let mut integrity = TableData::new(
            "store_integrity",
            "store file integrity",
            &["bytes", "experiments", "cells", "duplicates", "conflicts", "salvaged bytes"],
        );
        integrity.push(vec![
            self.end.to_string(),
            self.experiments.len().to_string(),
            self.index.len().to_string(),
            self.duplicates.to_string(),
            self.conflicts.to_string(),
            self.salvaged_bytes.to_string(),
        ]);
        vec![exps, integrity]
    }

    /// Resolve `selector` (label, full hash, or hash prefix) to an
    /// experiment; with no selector the store must hold exactly one.
    pub fn find_experiment(&self, selector: Option<&str>) -> Result<&Experiment, String> {
        match selector {
            None => match self.experiments.len() {
                0 => Err("store holds no experiments".into()),
                1 => Ok(&self.experiments[0]),
                n => Err(format!(
                    "store holds {n} experiments — select one with --label \
                     ({})",
                    self.experiments
                        .iter()
                        .map(|e| e.label.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )),
            },
            Some(sel) => self
                .experiments
                .iter()
                .find(|e| e.label == sel || format!("{:016x}", e.hash).starts_with(sel))
                .ok_or_else(|| format!("no experiment labelled or hashed '{sel}'")),
        }
    }

    /// The per-cell table of one experiment — same columns (and, for a
    /// fully committed `cells`-projection grid, the same bytes) as the
    /// sweep's own cells table. Missing cells are simply absent rows.
    pub fn cells_table(&mut self, selector: Option<&str>) -> Result<TableData, String> {
        let (hash, sc) = {
            let exp = self.find_experiment(selector)?;
            (exp.hash, Scenario::from_json(&exp.scenario)?)
        };
        let mut t = TableData::new(&sc.name, &sc.title, &scenario::CELLS_HEADER);
        let grid = sc.campaign_cell_count();
        for idx in self.cell_indices(hash) {
            if idx as usize >= grid {
                continue; // foreign record beyond this grid
            }
            let d = self
                .read_cell(hash, idx)
                .map_err(|e| format!("cell {idx}: {e}"))?
                .expect("indexed cell must read back");
            let cell = sc.cell_at(idx as usize);
            t.push(scenario::cells_row(
                &cell,
                d.emitted,
                d.power_cycles,
                d.power_failures,
                d.quality(),
                d.same_cycle_fraction(),
                d.app_energy,
                d.state_energy,
            ));
        }
        Ok(t)
    }

    /// A plain-SQL dump of the whole store (schema + rows), loadable
    /// with `sqlite3 runs.db < runs.sql` — the dependency-free half of
    /// the bencher-style export; the `sqlite` feature can ingest the
    /// same schema natively.
    pub fn sql_dump(&mut self) -> io::Result<String> {
        let mut out = String::new();
        out.push_str("-- aic experiment store dump; load with: sqlite3 runs.db < dump.sql\n");
        out.push_str("BEGIN;\n");
        out.push_str(
            "CREATE TABLE IF NOT EXISTS experiments (\
             hash TEXT PRIMARY KEY, label TEXT, scenario TEXT);\n",
        );
        out.push_str(
            "CREATE TABLE IF NOT EXISTS cells (\
             hash TEXT, idx INTEGER, digest TEXT, PRIMARY KEY (hash, idx));\n",
        );
        for e in &self.experiments {
            out.push_str(&format!(
                "INSERT OR IGNORE INTO experiments VALUES ('{:016x}', '{}', '{}');\n",
                e.hash,
                sql_escape(&e.label),
                sql_escape(&json::to_string(&e.scenario)),
            ));
        }
        let mut keys: Vec<(u64, u32)> = self.index.keys().copied().collect();
        keys.sort_unstable();
        for (hash, idx) in keys {
            let d = self
                .read_cell(hash, idx)?
                .expect("indexed cell must read back");
            out.push_str(&format!(
                "INSERT OR IGNORE INTO cells VALUES ('{hash:016x}', {idx}, '{}');\n",
                sql_escape(&json::to_string(&d.to_json())),
            ));
        }
        out.push_str("COMMIT;\n");
        Ok(out)
    }
}

fn parse_hash(s: &str) -> Option<u64> {
    (s.len() == 16).then(|| u64::from_str_radix(s, 16).ok()).flatten()
}

fn sql_escape(s: &str) -> String {
    s.replace('\'', "''")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::Projection;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("aic_store_{tag}_{}.aic", std::process::id()))
    }

    fn digest(seed: u64) -> CellDigest {
        CellDigest {
            emitted: 10 + seed,
            duration: 900.0,
            power_cycles: 3 * seed,
            power_failures: seed,
            app_energy: 1e-3 * seed as f64,
            state_energy: 1e-4,
            quality_ok: seed,
            quality_total: 10 + seed,
            same_cycle: seed,
            steps_sum: 100 * seed,
            latency_sum: seed,
            latency_bins: None,
            slots: None,
            pictures: None,
            fleet: None,
        }
    }

    #[test]
    fn round_trips_experiments_and_cells_across_reopen() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let sc = Scenario::new("t", crate::coordinator::scenario::WorkloadSpec::Audio);
        let hash = grid_hash(&sc, Needs::none());
        {
            let mut st = Store::open(&path).unwrap();
            st.ensure_experiment("first", hash, &sc).unwrap();
            assert!(st.append_cell(hash, 0, &digest(1)).unwrap());
            assert!(st.append_cell(hash, 2, &digest(2)).unwrap());
            // Dedup: second append of a committed key writes nothing.
            assert!(!st.append_cell(hash, 0, &digest(9)).unwrap());
            st.sync().unwrap();
        }
        let mut st = Store::open(&path).unwrap();
        assert_eq!(st.experiments().len(), 1);
        assert_eq!(st.experiments()[0].label, "first");
        assert_eq!(st.cell_count_for(hash), 2);
        assert_eq!(st.cell_indices(hash), vec![0, 2]);
        assert_eq!(st.salvaged_bytes(), 0);
        // First record stays authoritative.
        assert_eq!(st.read_cell(hash, 0).unwrap().unwrap(), digest(1));
        assert_eq!(st.read_cell(hash, 2).unwrap().unwrap(), digest(2));
        assert_eq!(st.read_cell(hash, 1).unwrap(), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_is_salvaged_and_truncated_on_next_append() {
        let path = temp_path("torn");
        let _ = std::fs::remove_file(&path);
        let sc = Scenario::new("t", crate::coordinator::scenario::WorkloadSpec::Audio);
        let hash = grid_hash(&sc, Needs::none());
        {
            let mut st = Store::open(&path).unwrap();
            st.ensure_experiment("x", hash, &sc).unwrap();
            st.append_cell(hash, 0, &digest(1)).unwrap();
        }
        // A crash mid-append leaves a torn frame.
        use std::io::Write as _;
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0x55; 13]).unwrap();
        drop(f);
        {
            let mut st = Store::open(&path).unwrap();
            assert_eq!(st.salvaged_bytes(), 13);
            assert_eq!(st.cell_count_for(hash), 1);
            st.append_cell(hash, 1, &digest(2)).unwrap();
        }
        let mut st = Store::open(&path).unwrap();
        assert_eq!(st.salvaged_bytes(), 0, "append must truncate the torn tail");
        assert_eq!(st.cell_indices(hash), vec![0, 1]);
        assert_eq!(st.read_cell(hash, 1).unwrap().unwrap(), digest(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn refuses_files_with_foreign_magic() {
        let path = temp_path("magic");
        std::fs::write(&path, b"NOTASTORE-AT-ALL").unwrap();
        assert!(Store::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn grid_hash_tracks_identity_not_presentation() {
        let sc = Scenario::new("a", crate::coordinator::scenario::WorkloadSpec::Audio);
        let base = grid_hash(&sc, Needs::none());
        let renamed = sc.clone().with_title("pretty").with_projection(Projection::Cells);
        assert_eq!(grid_hash(&renamed, Needs::none()), base);
        let other = sc.clone().with_seeds(vec![1, 2]);
        assert_ne!(grid_hash(&other, Needs::none()), base);
        assert_ne!(
            grid_hash(&sc, Needs { slots: true, ..Needs::none() }),
            base
        );
        // The fleet needs key is only hashed when set, so pre-fleet
        // grids keep their committed identity.
        assert_ne!(grid_hash(&sc, Needs { fleet: true, ..Needs::none() }), base);
    }
}
