//! Optional SQLite mirror of an experiment store (bencher-style).
//!
//! The append-only `.aic` file is the source of truth; this module only
//! *mirrors* it into a relational database for ad-hoc querying, exactly
//! like `bencher` keeps its runs in SQLite. It is compiled behind the
//! `sqlite` cargo feature, which — like the accelerator backends — is
//! declared with an empty dependency list so the default (offline,
//! dependency-free) build never resolves `rusqlite`. To actually use
//! it, add `rusqlite` to `[dependencies]` locally and build with
//! `--features sqlite`; without the crate, enabling the feature is a
//! compile error by design rather than a silent network fetch.
//!
//! Everything the mirror writes is also reachable without the feature:
//! `aic store export --format sql` emits the identical schema as a SQL
//! text dump for `sqlite3 runs.db < runs.sql`.

use crate::coordinator::store::Store;
use std::io;

/// Mirror `store` into a SQLite database at `db_path` using the same
/// schema as [`Store::sql_dump`]: an `experiments(hash, label,
/// scenario)` table and a `cells(hash, idx, digest)` table keyed by the
/// dedup key. Existing rows are kept (`INSERT OR IGNORE`), so mirroring
/// is idempotent and incremental re-mirrors are cheap.
pub fn mirror(store: &mut Store, db_path: &str) -> io::Result<()> {
    let dump = store.sql_dump()?;
    let conn = rusqlite::Connection::open(db_path)
        .map_err(|e| io::Error::new(io::ErrorKind::Other, e.to_string()))?;
    conn.execute_batch(&dump)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}
