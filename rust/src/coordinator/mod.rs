//! Experiment coordination: the layer that reproduces the paper's
//! evaluation.
//!
//! * [`metrics`] — accuracy, coherence (the §5.3 alignment rule),
//!   throughput ratios and latency distributions over campaigns.
//! * [`experiment`] — the per-figure experiment definitions: HAR contexts
//!   (corpus → training → Eq. 7 tables → kinetic-powered campaigns) and
//!   imaging campaigns over the five energy traces.
//! * [`fleet`] — multi-device / multi-volunteer orchestration on OS
//!   threads (the paper's 12 prototypes and 15 volunteers).
//! * [`report`] — figure data as markdown tables + CSV under `out/`.

pub mod experiment;
pub mod fleet;
pub mod metrics;
pub mod report;
