//! Experiment coordination: the layer that reproduces — and generalises
//! — the paper's evaluation.
//!
//! * [`metrics`] — accuracy, coherence (the §5.3 alignment rule),
//!   throughput ratios and latency distributions over campaigns.
//! * [`experiment`] — the [`experiment::Workload`] abstraction (how a
//!   workload builds its program, harvester, and SMART table) and the
//!   generic [`experiment::run_campaign_on`] driver behind every grid
//!   cell, plus the HAR/imaging/audio workloads and the HAR training
//!   context.
//! * [`scenario`] — the declarative sweep API: a serialisable
//!   [`scenario::Scenario`] (workload × harvesters × devices × policies
//!   × seeds + projection) expands into a deterministic job plan; every
//!   paper figure is a named built-in scenario, and `aic sweep` runs
//!   arbitrary grids from JSON files.
//! * [`fleet`] — workload-generic multi-device orchestration (the
//!   paper's 12 prototypes and 15 volunteers) on a bounded worker pool
//!   with deterministic, job-ordered results.
//! * [`sink`] — where tables go: markdown/CSV/JSON streaming sinks and
//!   in-memory capture.
//! * [`store`] — the persistent experiment store: an append-only,
//!   checksummed record file of per-cell digests keyed by grid identity,
//!   tolerant of torn tails (the coordinator survives power failures the
//!   way the paper's devices do).
//! * [`stream`] — streaming sweeps: lazy chunked cells through the fleet
//!   pool into O(1)-memory incremental projections, bitwise-identical to
//!   the batch path and resumable from a [`store::Store`].
//! * [`sync`] — simulated multi-device fleets with coordination-free
//!   delta sync: per-column-versioned replicas exchanging only changed
//!   columns at deterministic powered-overlap rendezvous, with symmetric
//!   tiebreakers and gossip-acked GC (merge order never changes the
//!   converged state).

pub mod experiment;
pub mod fleet;
pub mod metrics;
pub mod scenario;
pub mod sink;
pub mod store;
pub mod stream;
pub mod sync;
