//! Experiment coordination: the layer that reproduces the paper's
//! evaluation.
//!
//! * [`metrics`] — accuracy, coherence (the §5.3 alignment rule),
//!   throughput ratios and latency distributions over campaigns.
//! * [`experiment`] — the [`experiment::Workload`] abstraction (how a
//!   workload builds its program, harvester, and SMART table), the
//!   generic [`experiment::run_campaign`] driver, and the per-figure
//!   experiment definitions: HAR contexts (corpus → training → Eq. 7
//!   tables → kinetic-powered campaigns) and imaging campaigns over the
//!   five energy traces.
//! * [`fleet`] — workload-generic multi-device orchestration (the
//!   paper's 12 prototypes and 15 volunteers) on a bounded worker pool
//!   with deterministic, job-ordered results.
//! * [`report`] — figure data as markdown tables + CSV under `out/`.

pub mod experiment;
pub mod fleet;
pub mod metrics;
pub mod report;
