//! Figure-data rendering: markdown tables to stdout, CSV + JSON to `out/`.

use crate::util::json::{self, Value};
use std::io::Write;
use std::path::Path;

/// A figure's tabular data.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s += &format!("| {} |\n", self.header.join(" | "));
        s += &format!("|{}|\n", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            s += &format!("| {} |\n", row.join(" | "));
        }
        s
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |cell: &str| -> String {
            if cell.contains(',') || cell.contains('"') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        let mut s = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",") + "\n";
        for row in &self.rows {
            s += &(row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",") + "\n");
        }
        s
    }

    /// As a JSON value (for machine consumption).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", self.title.as_str().into()),
            (
                "header",
                Value::Arr(self.header.iter().map(|h| h.as_str().into()).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| Value::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Print to stdout and persist CSV + JSON under `out_dir/<stem>.*`.
    pub fn emit(&self, out_dir: &str, stem: &str) -> std::io::Result<()> {
        println!("{}", self.to_markdown());
        let dir = Path::new(out_dir);
        std::fs::create_dir_all(dir)?;
        let mut csv = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        csv.write_all(self.to_csv().as_bytes())?;
        let mut js = std::fs::File::create(dir.join(format!("{stem}.json")))?;
        js.write_all(json::to_string_pretty(&self.to_json()).as_bytes())?;
        Ok(())
    }
}

/// Format helpers used by the figure benches.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("fig-test", &["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t
    }

    #[test]
    fn markdown_and_csv_render() {
        let t = table();
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | x,y |"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn json_roundtrip() {
        let t = table();
        let v = t.to_json();
        assert_eq!(v.get("title").as_str(), Some("fig-test"));
        assert_eq!(v.get("rows").at(0).at(1).as_str(), Some("x,y"));
    }

    #[test]
    fn emit_writes_files() {
        let t = table();
        let dir = std::env::temp_dir().join("aic_report_test");
        let dir_s = dir.to_str().unwrap();
        t.emit(dir_s, "fig_test").unwrap();
        assert!(dir.join("fig_test.csv").exists());
        assert!(dir.join("fig_test.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.834), "83.4%");
        assert_eq!(ratio(7.0), "7.00x");
        assert_eq!(f2(1.234), "1.23");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_enforced() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push(vec!["only-one".into()]);
    }
}
