//! The workload layer: what it takes to campaign an application.
//!
//! This module owns the generic campaign machinery the scenario API
//! builds on — the [`Workload`] trait, the single [`run_campaign_on`]
//! driver, and the two paper applications ([`HarWorkload`],
//! [`ImgWorkload`]) with their shared training context. Figure
//! definitions live in `coordinator/scenario.rs` as declarative
//! [`Scenario`](crate::coordinator::scenario::Scenario) specs; the
//! per-figure functions that used to live here are gone.

use crate::audio::app::{self as audio_app, AudioOutput, AudioProgram, AudioSource};
use crate::audio::detector::SpectralDetector;
use crate::audio::stream::AudioScript;
use crate::coordinator::scenario::{DeviceSpec, HarvesterSpec};
use crate::energy::booster::Booster;
use crate::energy::estimator::{EnergyProfile, SmartTable};
use crate::energy::harvester::Harvester;
use crate::energy::mcu::{McuModel, OpCost};
use crate::energy::traces::TraceKind;
use crate::exec::engine::{Engine, SharedSupply};
use crate::exec::{Campaign, Policy, Runtime, RuntimeSpec, StepProgram};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use crate::har::app::{smart_table, HarOutput, HarProgram, WindowSource};
use crate::har::dataset::{ActivityScript, Corpus, CorpusSpec};
use crate::har::NUM_FEATURES;
use crate::imgproc::app::{CornerOutput, CornerProgram};
use crate::svm::analysis::ClassFeatureModel;
use crate::svm::anytime::AnytimeSvm;
use crate::svm::train::{train_ovr, TrainConfig};

/// Everything the HAR experiments share: corpus, trained anytime SVM,
/// fitted class model, measured full accuracy.
///
/// Training the OVR SVM is the expensive part of a sweep, and the
/// result is identical for every grid cell — so build the context
/// **once per sweep** and share it read-only (`&ctx`) across all fleet
/// jobs (`aic all` shares one context across figs. 4-9; determinism
/// under sharing is asserted by `tests/policy_matrix.rs`).
pub struct HarContext {
    pub asvm: AnytimeSvm,
    pub class_model: ClassFeatureModel,
    pub corpus: Corpus,
    pub full_accuracy: f64,
}

impl HarContext {
    /// Build a context (train on the synthetic corpus) from a seed.
    pub fn build(seed: u64) -> HarContext {
        HarContext::build_with(&CorpusSpec::default(), seed)
    }

    pub fn build_with(spec: &CorpusSpec, seed: u64) -> HarContext {
        let corpus = Corpus::generate(spec, seed);
        let (rows, labels) = Corpus::features(&corpus.train);
        let svm = train_ovr(&rows, &labels, 6, &TrainConfig::default());
        let asvm = AnytimeSvm::by_coefficient_magnitude(svm);
        let scaled: Vec<Vec<f64>> =
            rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
        let class_model = ClassFeatureModel::fit(&scaled, &labels, 6);
        let (test_rows, test_labels) = Corpus::features(&corpus.test);
        let full_accuracy = asvm.svm.accuracy(&test_rows, &test_labels);
        HarContext { asvm, class_model, corpus, full_accuracy }
    }
}

/// Parameters of one HAR device campaign.
#[derive(Clone, Debug)]
pub struct HarRunSpec {
    /// Campaign horizon, seconds.
    pub horizon: f64,
    /// Sampling period (paper: one minute).
    pub sample_period: f64,
    /// Seed for the volunteer's activity script (also powers the device
    /// when the supply is kinetic).
    pub script_seed: u64,
}

impl Default for HarRunSpec {
    fn default() -> HarRunSpec {
        HarRunSpec { horizon: 4.0 * 3600.0, sample_period: 60.0, script_seed: 1 }
    }
}

/// Shares resolved supplies across the cells of a sweep.
///
/// A grid of P policies × D devices over one harvester seed resolves to
/// one identical supply, yet the naive path materialises the
/// [`Harvester`] (for a synth family, the full run-length-coalesced
/// `Piecewise` composition) and builds the analytic stepping table P×D
/// times. The cache keys on the *resolved supply identity* —
/// [`HarvesterSpec`] + horizon + environment seed + booster config — and
/// hands every matching cell the same [`SharedSupply`], so the harvester
/// is materialised once and the [`SupplyTable`](crate::exec::engine::SupplyTable)
/// is built once (lazily, by the first analytic engine), whatever the
/// cell count or `AIC_WORKERS`.
///
/// Sharing is sound because the table is immutable and each engine walks
/// it through a private cursor; `tests/policy_matrix.rs` asserts cached
/// and uncached sweeps are bitwise-identical for any worker count.
///
/// The `AIC_SUPPLY_CACHE=off` escape hatch (honoured by
/// [`SupplyCache::from_env`], which the scenario runner uses) disables
/// sharing for A/B timing and bisection; tests needing a specific mode
/// construct [`SupplyCache::new`] / [`SupplyCache::disabled`] directly
/// instead of mutating the process environment.
///
/// The cache is **bounded**: streaming sweeps walk seeds in the
/// innermost plan position, so an unbounded map would retain one
/// resolved supply per (harvester, seed) — O(grid) memory on the
/// 100k-cell grids the store targets. Once `cap` distinct identities
/// are held, the oldest entry is evicted FIFO. Plan order finishes all
/// cells of one seed before moving on, so any cap above one plan row's
/// working set keeps the hit rate of the unbounded cache; the default
/// (1024, override via `AIC_SUPPLY_CACHE_CAP`) is far above that.
pub struct SupplyCache {
    enabled: bool,
    /// Maximum distinct supplies held at once (≥ 1).
    cap: usize,
    inner: RwLock<CacheInner>,
    /// Instrumentation: how many `SharedSupply` values this cache has
    /// materialised. With sharing enabled this equals the number of
    /// *distinct* supplies resolved, not the number of cells (modulo
    /// rebuilds after eviction).
    builds: AtomicU64,
}

#[derive(Default)]
struct CacheInner {
    map: HashMap<String, Arc<SharedSupply>>,
    /// Insertion order of the keys in `map` — the FIFO eviction queue.
    order: VecDeque<String>,
}

/// Default [`SupplyCache`] capacity when `AIC_SUPPLY_CACHE_CAP` is unset.
pub const SUPPLY_CACHE_CAP: usize = 1024;

impl SupplyCache {
    /// A fresh, enabled cache (one per sweep is the intended scope).
    pub fn new() -> SupplyCache {
        SupplyCache::with_cap(SUPPLY_CACHE_CAP)
    }

    /// An enabled cache holding at most `cap` distinct supplies.
    pub fn with_cap(cap: usize) -> SupplyCache {
        SupplyCache {
            enabled: true,
            cap: cap.max(1),
            inner: RwLock::new(CacheInner::default()),
            builds: AtomicU64::new(0),
        }
    }

    /// A cache that never shares: every [`SupplyCache::resolve`] call
    /// materialises a fresh supply (the pre-cache behaviour).
    pub fn disabled() -> SupplyCache {
        SupplyCache { enabled: false, ..SupplyCache::new() }
    }

    /// Honour the environment: `AIC_SUPPLY_CACHE` set to `off`, `0` or
    /// `false` disables sharing; `AIC_SUPPLY_CACHE_CAP=<n>` bounds the
    /// number of supplies held at once (default [`SUPPLY_CACHE_CAP`]).
    pub fn from_env() -> SupplyCache {
        match std::env::var("AIC_SUPPLY_CACHE") {
            Ok(s) if matches!(s.as_str(), "off" | "0" | "false") => SupplyCache::disabled(),
            _ => {
                let cap = std::env::var("AIC_SUPPLY_CACHE_CAP")
                    .ok()
                    .and_then(|s| s.parse::<usize>().ok())
                    .unwrap_or(SUPPLY_CACHE_CAP);
                SupplyCache::with_cap(cap)
            }
        }
    }

    /// The eviction bound this cache was built with.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Whether this cache shares supplies at all.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// How many supplies this cache has materialised so far.
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::SeqCst)
    }

    /// How many distinct supplies the cache currently holds.
    pub fn len(&self) -> usize {
        self.inner.read().expect("supply cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The full identity a supply is shared under. `Debug` on f64 prints
    /// the shortest exact round-trip form, so distinct parameter values
    /// always yield distinct keys.
    fn key(spec: &HarvesterSpec, horizon: f64, seed: u64, booster: &Booster) -> String {
        format!(
            "{spec:?}|h={:x}|s={seed}|b={:x},{:x},{:x},{:x},{:x}",
            horizon.to_bits(),
            booster.eta_max.to_bits(),
            booster.knee_power.to_bits(),
            booster.eta_min.to_bits(),
            booster.quiescent.to_bits(),
            booster.cold_start_power.to_bits(),
        )
    }

    fn build(&self, spec: &HarvesterSpec, horizon: f64, seed: u64) -> Arc<SharedSupply> {
        self.builds.fetch_add(1, Ordering::SeqCst);
        Arc::new(SharedSupply::new(spec.build(horizon, seed)))
    }

    /// The shared supply for one resolved identity, materialising it on
    /// first request. Post-population lookups take only the shared read
    /// lock, so fleet workers resolving a warm cache never serialise;
    /// a miss re-checks under the write lock, so concurrent workers
    /// racing on a cold key still build exactly once.
    pub fn resolve(
        &self,
        spec: &HarvesterSpec,
        horizon: f64,
        seed: u64,
        booster: &Booster,
    ) -> Arc<SharedSupply> {
        if !self.enabled {
            return self.build(spec, horizon, seed);
        }
        let key = SupplyCache::key(spec, horizon, seed, booster);
        {
            let inner = self.inner.read().expect("supply cache poisoned");
            if let Some(shared) = inner.map.get(&key) {
                return Arc::clone(shared);
            }
        }
        let mut inner = self.inner.write().expect("supply cache poisoned");
        if let Some(shared) = inner.map.get(&key) {
            return Arc::clone(shared);
        }
        let shared = self.build(spec, horizon, seed);
        // FIFO-evict before inserting so the map never exceeds `cap`.
        // Outstanding `Arc`s keep an evicted supply alive for the cells
        // already using it; the cache just stops handing it out.
        while inner.map.len() >= self.cap {
            let oldest = inner.order.pop_front().expect("order tracks map");
            inner.map.remove(&oldest);
        }
        inner.map.insert(key.clone(), Arc::clone(&shared));
        inner.order.push_back(key);
        shared
    }
}

impl Default for SupplyCache {
    fn default() -> SupplyCache {
        SupplyCache::new()
    }
}

/// A simulated application the coordinator can campaign with: how to
/// build the program, the harvester powering the device, and the knobs
/// the runtimes need. Implementing this — nothing else — is what it
/// takes to give a new application the full fleet/scenario machinery.
pub trait Workload: Sync {
    type Prog: StepProgram;

    /// Seconds between sampling slots.
    fn sample_period(&self) -> f64;

    /// Campaign horizon, seconds.
    fn horizon(&self) -> f64;

    /// Build the step program for one device (deterministic in `seed`).
    fn program(&self, seed: u64) -> Self::Prog;

    /// Build the energy harvester for one device (deterministic in
    /// `seed`). Not called for `Policy::Continuous` devices.
    fn harvester(&self, seed: u64) -> Harvester;

    /// The declarative identity of this workload's supply, when it has
    /// one — what a [`SupplyCache`] keys sharing on. Returning `Some`
    /// promises that [`Workload::harvester`] equals
    /// `spec.build(self.horizon(), seed)` for every seed; workloads whose
    /// supply has no spec form return `None` and opt out of sharing.
    fn supply_spec(&self) -> Option<&HarvesterSpec> {
        None
    }

    /// The offline lookup table for the device built from `seed`
    /// (it must price the same program [`Workload::program`] returns).
    /// Only consulted for `Policy::Smart` and `Policy::Adaptive`
    /// devices; workloads that cannot provision one return `None` and
    /// campaigns needing it on them panic loudly.
    fn smart_table(&self, seed: u64) -> Option<SmartTable> {
        let _ = seed;
        None
    }
}

/// Run one campaign of `workload` under `policy` on the device `device`
/// describes — the single generic driver behind every scenario cell.
/// Continuous devices run on a battery ([`Engine::powered`], which the
/// device knobs cannot brown out); everything else harvests through the
/// workload's supply on the spec'd capacitor and integrator.
pub fn run_campaign_on<W: Workload>(
    workload: &W,
    seed: u64,
    policy: Policy,
    device: &DeviceSpec,
) -> Campaign<<W::Prog as StepProgram>::Output> {
    run_campaign_cached(workload, seed, policy, device, &SupplyCache::disabled())
}

/// [`run_campaign_on`] resolving the supply through a [`SupplyCache`]:
/// grid cells handed the same (enabled) cache share one harvester and
/// one analytic stepping table per distinct supply. Continuous devices
/// run on a battery and touch neither the cache nor a supply.
pub fn run_campaign_cached<W: Workload>(
    workload: &W,
    seed: u64,
    policy: Policy,
    device: &DeviceSpec,
    cache: &SupplyCache,
) -> Campaign<<W::Prog as StepProgram>::Output> {
    let mut program = workload.program(seed);
    let mut engine = match policy {
        Policy::Continuous => Engine::powered(McuModel::paper_default(), workload.horizon()),
        _ => {
            let cfg = device.engine_config(workload.horizon());
            match workload.supply_spec() {
                Some(spec) => {
                    let shared = cache.resolve(spec, workload.horizon(), seed, &cfg.booster);
                    Engine::from_shared(cfg, &shared)
                }
                // No declarative supply identity: build an owning engine
                // (nothing to share under).
                None => Engine::new(cfg, workload.harvester(seed)),
            }
        }
    };
    let mut spec = RuntimeSpec::new(workload.sample_period());
    // Both table-consulting runtimes: SMART gates on the offline
    // expected-accuracy bound; ADAPTIVE prices its depth menu with the
    // same cumulative-energy column.
    if matches!(policy, Policy::Smart { .. } | Policy::Adaptive { .. }) {
        spec.smart_table = workload.smart_table(seed);
    }
    policy.runtime::<W::Prog>(&spec).run(&mut program, &mut engine)
}

/// [`run_campaign_on`] with the paper-default device.
pub fn run_campaign<W: Workload>(
    workload: &W,
    seed: u64,
    policy: Policy,
) -> Campaign<<W::Prog as StepProgram>::Output> {
    run_campaign_on(workload, seed, policy, &DeviceSpec::default())
}

/// The HAR workload: by default the device is powered by the kinetic
/// energy of the same wrist motion that produces the sensor windows;
/// `seed` selects the volunteer's activity script. The scenario API can
/// swap the supply for an ambient trace without touching the program.
pub struct HarWorkload<'a> {
    pub ctx: &'a HarContext,
    pub spec: HarRunSpec,
    pub harvester: HarvesterSpec,
}

impl Workload for HarWorkload<'_> {
    type Prog = HarProgram;

    fn sample_period(&self) -> f64 {
        self.spec.sample_period
    }

    fn horizon(&self) -> f64 {
        self.spec.horizon
    }

    fn program(&self, seed: u64) -> HarProgram {
        let script = ActivityScript::generate(self.spec.horizon, seed);
        HarProgram::new(self.ctx.asvm.clone(), WindowSource::Script(script))
    }

    fn harvester(&self, seed: u64) -> Harvester {
        // On the kinetic supply the same deterministic script that feeds
        // the classifier also shakes the harvester; an ambient spec swaps
        // the supply while the program keeps its script.
        self.harvester.build(self.spec.horizon, seed)
    }

    fn supply_spec(&self) -> Option<&HarvesterSpec> {
        Some(&self.harvester)
    }

    fn smart_table(&self, _seed: u64) -> Option<SmartTable> {
        // The table prices the anytime feature pipeline, which is the
        // same for every volunteer; the seed only varies the inputs.
        let mcu = McuModel::paper_default();
        Some(smart_table(
            &self.ctx.asvm,
            &self.ctx.class_model,
            self.ctx.full_accuracy,
            &mcu,
        ))
    }
}

/// Run one HAR campaign under `policy` on the given supply and device.
pub fn run_har_policy_on(
    ctx: &HarContext,
    spec: &HarRunSpec,
    harvester: HarvesterSpec,
    policy: Policy,
    device: &DeviceSpec,
) -> Campaign<HarOutput> {
    let workload = HarWorkload { ctx, spec: spec.clone(), harvester };
    run_campaign_on(&workload, spec.script_seed, policy, device)
}

/// Run one HAR campaign on the paper setup (kinetic wrist supply,
/// paper-default device). Thin wrapper over [`run_har_policy_on`].
pub fn run_har_policy(
    ctx: &HarContext,
    spec: &HarRunSpec,
    policy: Policy,
) -> Campaign<HarOutput> {
    run_har_policy_on(ctx, spec, HarvesterSpec::Kinetic, policy, &DeviceSpec::default())
}

/// Parameters of one imaging campaign.
#[derive(Clone, Debug)]
pub struct ImgRunSpec {
    pub horizon: f64,
    /// Timer between rounds when energy is left (paper: 30 s).
    pub sample_period: f64,
    pub trace_seed: u64,
}

impl Default for ImgRunSpec {
    fn default() -> ImgRunSpec {
        ImgRunSpec { horizon: 2.0 * 3600.0, sample_period: 30.0, trace_seed: 3 }
    }
}

/// The imaging workload: Harris corner detection over the synthetic
/// picture pool, powered by any [`HarvesterSpec`] supply (the paper's §6
/// uses the five ambient traces); `seed` selects the supply realisation
/// and the picture order.
pub struct ImgWorkload {
    pub spec: ImgRunSpec,
    pub harvester: HarvesterSpec,
}

impl Workload for ImgWorkload {
    type Prog = CornerProgram;

    fn sample_period(&self) -> f64 {
        self.spec.sample_period
    }

    fn horizon(&self) -> f64 {
        self.spec.horizon
    }

    fn program(&self, seed: u64) -> CornerProgram {
        CornerProgram::paper_default(seed ^ 0x1196)
    }

    fn harvester(&self, seed: u64) -> Harvester {
        self.harvester.build(self.spec.horizon, seed)
    }

    fn supply_spec(&self) -> Option<&HarvesterSpec> {
        Some(&self.harvester)
    }

    fn smart_table(&self, seed: u64) -> Option<SmartTable> {
        // SMART's "accuracy" proxy for imaging: the fraction of response
        // rows computed (Fig. 12 shows corner equivalence degrading
        // with the perforation rate, monotone in rows to first order).
        // Price the same program the campaign runs.
        let prog = self.program(seed);
        let mcu = McuModel::paper_default();
        let total = prog.num_steps();
        let costs: Vec<OpCost> = (0..total).map(|j| prog.step_cost(j)).collect();
        let profile = EnergyProfile::from_costs(&mcu, &costs);
        let acc: Vec<f64> = (0..=total).map(|p| p as f64 / total as f64).collect();
        let emit = mcu.energy(&prog.emit_cost());
        Some(SmartTable::new(acc, &profile, emit))
    }
}

/// Run one imaging campaign under `policy` on the given supply and
/// device.
pub fn run_img_policy_on(
    spec: &ImgRunSpec,
    harvester: HarvesterSpec,
    policy: Policy,
    device: &DeviceSpec,
) -> Campaign<CornerOutput> {
    let workload = ImgWorkload { spec: spec.clone(), harvester };
    run_campaign_on(&workload, spec.trace_seed, policy, device)
}

/// Run one imaging campaign on an ambient energy trace with the
/// paper-default device. Thin wrapper over [`run_img_policy_on`].
pub fn run_img_policy(
    spec: &ImgRunSpec,
    trace: TraceKind,
    policy: Policy,
) -> Campaign<CornerOutput> {
    run_img_policy_on(spec, HarvesterSpec::Ambient(trace), policy, &DeviceSpec::default())
}

/// Parameters of one acoustic-event campaign.
#[derive(Clone, Debug)]
pub struct AudioRunSpec {
    pub horizon: f64,
    /// Timer between listening slots (30 s, matching the imaging cadence).
    pub sample_period: f64,
    /// Seed for the device's event script.
    pub stream_seed: u64,
}

impl Default for AudioRunSpec {
    fn default() -> AudioRunSpec {
        AudioRunSpec { horizon: 2.0 * 3600.0, sample_period: 30.0, stream_seed: 5 }
    }
}

/// The audio workload: anytime acoustic event detection over a seeded
/// synthetic event stream, powered by any [`HarvesterSpec`] supply;
/// `seed` selects the event script and the supply realisation. No
/// training context is needed — the detector's refinement schedule is
/// fixed offline.
pub struct AudioWorkload {
    pub spec: AudioRunSpec,
    pub harvester: HarvesterSpec,
}

impl Workload for AudioWorkload {
    type Prog = AudioProgram;

    fn sample_period(&self) -> f64 {
        self.spec.sample_period
    }

    fn horizon(&self) -> f64 {
        self.spec.horizon
    }

    fn program(&self, seed: u64) -> AudioProgram {
        let script = AudioScript::generate(self.spec.horizon, seed);
        AudioProgram::new(SpectralDetector::paper_default(), AudioSource::Script(script))
    }

    fn harvester(&self, seed: u64) -> Harvester {
        self.harvester.build(self.spec.horizon, seed)
    }

    fn supply_spec(&self) -> Option<&HarvesterSpec> {
        Some(&self.harvester)
    }

    fn smart_table(&self, _seed: u64) -> Option<SmartTable> {
        // The table prices the refinement schedule, which is the same
        // for every device; the seed only varies the event stream.
        let mcu = McuModel::paper_default();
        Some(audio_app::smart_table(&SpectralDetector::paper_default(), &mcu))
    }
}

/// Run one audio campaign under `policy` on the given supply and device.
pub fn run_audio_policy_on(
    spec: &AudioRunSpec,
    harvester: HarvesterSpec,
    policy: Policy,
    device: &DeviceSpec,
) -> Campaign<AudioOutput> {
    let workload = AudioWorkload { spec: spec.clone(), harvester };
    run_campaign_on(&workload, spec.stream_seed, policy, device)
}

/// Run one audio campaign on an ambient energy trace with the
/// paper-default device. Thin wrapper over [`run_audio_policy_on`].
pub fn run_audio_policy(
    spec: &AudioRunSpec,
    trace: TraceKind,
    policy: Policy,
) -> Campaign<AudioOutput> {
    run_audio_policy_on(spec, HarvesterSpec::Ambient(trace), policy, &DeviceSpec::default())
}

/// A cheap smoke context for tests (small corpus, fast training). The
/// scenario equivalent is `Training::tiny()`.
pub fn test_context() -> HarContext {
    HarContext::build_with(
        &CorpusSpec {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 6,
        },
        7,
    )
}

/// Feature-count sanity for specs.
pub fn num_features() -> usize {
    NUM_FEATURES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::engine::EngineKind;

    #[test]
    fn greedy_har_campaign_emits_within_cycle() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 1800.0, ..Default::default() };
        let c = run_har_policy(&ctx, &spec, Policy::Greedy);
        assert!(c.emitted().count() > 0, "no results in 30 min");
        assert!((super::super::metrics::same_cycle_fraction(&c) - 1.0).abs() < 1e-9);
        assert_eq!(c.state_energy, 0.0, "approx must not manage state");
    }

    #[test]
    fn har_runs_on_ambient_supplies_too() {
        // The previously impossible grid point: HAR powered by an
        // ambient trace instead of the wrist motion. Same program, same
        // script — only the supply changes.
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 900.0, ..Default::default() };
        let kinetic = run_har_policy(&ctx, &spec, Policy::Greedy);
        let ambient = run_har_policy_on(
            &ctx,
            &spec,
            HarvesterSpec::Ambient(TraceKind::Som),
            Policy::Greedy,
            &DeviceSpec::default(),
        );
        // Both campaigns observe the same sampling slots...
        assert_eq!(
            kinetic.rounds.first().map(|r| r.sample_id),
            ambient.rounds.first().map(|r| r.sample_id),
        );
        // ...but run on different supplies (energy trajectories differ).
        assert!(ambient.power_cycles >= 1);
    }

    #[test]
    fn audio_workload_campaigns_like_the_others() {
        // The third workload slots into the same generic driver: GREEDY
        // emits within the acquisition cycle, manages no state, and the
        // supply is swappable without touching the program.
        let spec = AudioRunSpec { horizon: 900.0, ..Default::default() };
        let c = run_audio_policy(&spec, TraceKind::Som, Policy::Greedy);
        assert!(c.emitted().count() > 0, "no detections in 15 min");
        assert!((super::super::metrics::same_cycle_fraction(&c) - 1.0).abs() < 1e-9);
        assert_eq!(c.state_energy, 0.0, "approx must not manage state");
        let kinetic = run_audio_policy_on(
            &spec,
            HarvesterSpec::Kinetic,
            Policy::Greedy,
            &DeviceSpec::default(),
        );
        assert_eq!(
            c.rounds.first().map(|r| r.sample_id),
            kinetic.rounds.first().map(|r| r.sample_id),
        );
    }

    #[test]
    fn device_spec_reaches_the_engine() {
        // A 10x buffer changes the energy trajectory; the explicit
        // fixed-step override must also bypass AIC_ENGINE.
        let spec = ImgRunSpec { horizon: 600.0, ..Default::default() };
        let paper = run_img_policy(&spec, TraceKind::Som, Policy::Greedy);
        let big = run_img_policy_on(
            &spec,
            HarvesterSpec::Ambient(TraceKind::Som),
            Policy::Greedy,
            &DeviceSpec { capacitance: Some(14700e-6), ..DeviceSpec::default() },
        );
        assert!(
            big.power_cycles <= paper.power_cycles,
            "a 10x buffer should not cycle more ({} vs {})",
            big.power_cycles,
            paper.power_cycles
        );
        let stepped = run_img_policy_on(
            &spec,
            HarvesterSpec::Ambient(TraceKind::Som),
            Policy::Greedy,
            &DeviceSpec { engine: Some(EngineKind::FixedStep), ..DeviceSpec::default() },
        );
        // The reference integrator agrees on round structure (the
        // engine-equivalence suite holds it much tighter).
        assert_eq!(stepped.rounds.len(), paper.rounds.len());
    }

    #[test]
    fn supply_cache_shares_by_identity() {
        let cache = SupplyCache::new();
        let booster = Booster::paper_default();
        let spec = HarvesterSpec::Ambient(TraceKind::Som);
        let a = cache.resolve(&spec, 900.0, 1, &booster);
        let b = cache.resolve(&spec, 900.0, 1, &booster);
        assert!(Arc::ptr_eq(&a, &b), "identical identity must share");
        assert_eq!(cache.builds(), 1);
        // Any component of the identity diverging splits the entry.
        let c = cache.resolve(&spec, 900.0, 2, &booster);
        assert!(!Arc::ptr_eq(&a, &c), "a different seed is a different supply");
        let d = cache.resolve(&spec, 1800.0, 1, &booster);
        assert!(!Arc::ptr_eq(&a, &d), "a different horizon is a different supply");
        let e = cache.resolve(&HarvesterSpec::Ambient(TraceKind::Rf), 900.0, 1, &booster);
        assert!(!Arc::ptr_eq(&a, &e), "a different spec is a different supply");
        let mut other = booster;
        other.eta_max *= 0.99;
        let f = cache.resolve(&spec, 900.0, 1, &other);
        assert!(!Arc::ptr_eq(&a, &f), "a different booster is a different supply");
        assert_eq!(cache.builds(), 5);
        assert_eq!(cache.len(), 5);
    }

    #[test]
    fn bounded_cache_evicts_fifo() {
        let cache = SupplyCache::with_cap(2);
        let booster = Booster::paper_default();
        let spec = HarvesterSpec::Ambient(TraceKind::Som);
        let a = cache.resolve(&spec, 900.0, 1, &booster);
        let _b = cache.resolve(&spec, 900.0, 2, &booster);
        // Seed 3 overflows the cap and evicts the oldest entry (seed 1).
        let _c = cache.resolve(&spec, 900.0, 3, &booster);
        assert_eq!(cache.len(), 2, "cap bounds the held set");
        let a2 = cache.resolve(&spec, 900.0, 1, &booster);
        assert!(!Arc::ptr_eq(&a, &a2), "evicted identity is rebuilt");
        assert_eq!(cache.builds(), 4);
    }

    #[test]
    fn disabled_cache_never_shares() {
        let cache = SupplyCache::disabled();
        let booster = Booster::paper_default();
        let spec = HarvesterSpec::Ambient(TraceKind::Som);
        let a = cache.resolve(&spec, 900.0, 1, &booster);
        let b = cache.resolve(&spec, 900.0, 1, &booster);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.builds(), 2);
        assert!(cache.is_empty(), "a disabled cache retains nothing");
    }

    #[test]
    fn cached_campaign_is_bitwise_identical_to_uncached() {
        let cache = SupplyCache::new();
        let spec = AudioRunSpec { horizon: 900.0, ..Default::default() };
        let workload =
            AudioWorkload { spec: spec.clone(), harvester: HarvesterSpec::Ambient(TraceKind::Som) };
        let plain = run_campaign_on(&workload, spec.stream_seed, Policy::Greedy, &DeviceSpec::default());
        let cached = run_campaign_cached(
            &workload,
            spec.stream_seed,
            Policy::Greedy,
            &DeviceSpec::default(),
            &cache,
        );
        assert_eq!(cache.builds(), 1);
        assert_eq!(plain.rounds.len(), cached.rounds.len());
        assert_eq!(plain.app_energy, cached.app_energy);
        assert_eq!(plain.power_cycles, cached.power_cycles);
        for (p, c) in plain.rounds.iter().zip(&cached.rounds) {
            assert_eq!(p.emitted_at, c.emitted_at);
            assert_eq!(p.steps_executed, c.steps_executed);
            assert_eq!(p.output, c.output);
        }
    }
}
