//! Per-figure experiment definitions.
//!
//! Each paper figure maps to one function here returning structured rows;
//! the `aic` CLI and the `rust/benches/fig*` benches are thin wrappers.
//! See DESIGN.md §4 for the experiment index.

use crate::coordinator::fleet::run_fleet;
use crate::energy::estimator::{EnergyProfile, SmartTable};
use crate::energy::harvester::{kinetic_power_trace, Harvester, KineticConfig};
use crate::energy::mcu::{McuModel, OpCost};
use crate::energy::traces::{generate, TraceKind};
use crate::exec::engine::{Engine, EngineConfig};
use crate::exec::{Campaign, Policy, Runtime, RuntimeSpec, StepProgram};
use crate::har::app::{smart_table, HarOutput, HarProgram, WindowSource};
use crate::har::dataset::{ActivityScript, Corpus, CorpusSpec};
use crate::har::NUM_FEATURES;
use crate::imgproc::app::{CornerOutput, CornerProgram};
use crate::svm::analysis::{
    coherence_curve_model, expected_accuracy, ClassFeatureModel,
};
use crate::svm::anytime::AnytimeSvm;
use crate::svm::train::{train_ovr, TrainConfig};

/// Everything the HAR experiments share: corpus, trained anytime SVM,
/// fitted class model, measured full accuracy.
///
/// Training the OVR SVM is the expensive part of a figure sweep, and
/// the result is identical for every (policy, volunteer) cell — so
/// build the context **once per sweep** and share it read-only (`&ctx`)
/// across all fleet jobs (`aic all` does exactly this; determinism
/// under sharing is asserted by `tests/policy_matrix.rs`).
pub struct HarContext {
    pub asvm: AnytimeSvm,
    pub class_model: ClassFeatureModel,
    pub corpus: Corpus,
    pub full_accuracy: f64,
}

impl HarContext {
    /// Build a context (train on the synthetic corpus) from a seed.
    pub fn build(seed: u64) -> HarContext {
        HarContext::build_with(&CorpusSpec::default(), seed)
    }

    pub fn build_with(spec: &CorpusSpec, seed: u64) -> HarContext {
        let corpus = Corpus::generate(spec, seed);
        let (rows, labels) = Corpus::features(&corpus.train);
        let svm = train_ovr(&rows, &labels, 6, &TrainConfig::default());
        let asvm = AnytimeSvm::by_coefficient_magnitude(svm);
        let scaled: Vec<Vec<f64>> =
            rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
        let class_model = ClassFeatureModel::fit(&scaled, &labels, 6);
        let (test_rows, test_labels) = Corpus::features(&corpus.test);
        let full_accuracy = asvm.svm.accuracy(&test_rows, &test_labels);
        HarContext { asvm, class_model, corpus, full_accuracy }
    }
}

/// Parameters of one HAR device campaign.
#[derive(Clone, Debug)]
pub struct HarRunSpec {
    /// Campaign horizon, seconds.
    pub horizon: f64,
    /// Sampling period (paper: one minute).
    pub sample_period: f64,
    /// Seed for the volunteer's activity script (also powers the device).
    pub script_seed: u64,
}

impl Default for HarRunSpec {
    fn default() -> HarRunSpec {
        HarRunSpec { horizon: 4.0 * 3600.0, sample_period: 60.0, script_seed: 1 }
    }
}

/// A simulated application the coordinator can campaign with: how to
/// build the program, the harvester powering the device, and the knobs
/// the runtimes need. Implementing this — nothing else — is what it
/// takes to give a new application the full fleet/figure machinery.
pub trait Workload: Sync {
    type Prog: StepProgram;

    /// Seconds between sampling slots.
    fn sample_period(&self) -> f64;

    /// Campaign horizon, seconds.
    fn horizon(&self) -> f64;

    /// Build the step program for one device (deterministic in `seed`).
    fn program(&self, seed: u64) -> Self::Prog;

    /// Build the energy harvester for one device (deterministic in
    /// `seed`). Not called for `Policy::Continuous` devices.
    fn harvester(&self, seed: u64) -> Harvester;

    /// SMART's offline lookup table for the device built from `seed`
    /// (it must price the same program [`Workload::program`] returns).
    /// Only consulted for `Policy::Smart` devices; workloads that cannot
    /// provision one return `None` and SMART campaigns on them panic
    /// loudly.
    fn smart_table(&self, seed: u64) -> Option<SmartTable> {
        let _ = seed;
        None
    }
}

/// Run one campaign of `workload` under `policy` — the single generic
/// driver behind every HAR and imaging figure. Continuous devices run on
/// a battery ([`Engine::powered`]); everything else harvests through the
/// workload's supply.
pub fn run_campaign<W: Workload>(
    workload: &W,
    seed: u64,
    policy: Policy,
) -> Campaign<<W::Prog as StepProgram>::Output> {
    let mut program = workload.program(seed);
    let mut engine = match policy {
        Policy::Continuous => Engine::powered(McuModel::paper_default(), workload.horizon()),
        _ => Engine::new(
            EngineConfig::paper_default(workload.horizon()),
            workload.harvester(seed),
        ),
    };
    let mut spec = RuntimeSpec::new(workload.sample_period());
    if let Policy::Smart { .. } = policy {
        spec.smart_table = workload.smart_table(seed);
    }
    policy.runtime::<W::Prog>(&spec).run(&mut program, &mut engine)
}

/// The HAR workload: the device is powered by the kinetic energy of the
/// same wrist motion that produces the sensor windows; `seed` selects
/// the volunteer's activity script.
pub struct HarWorkload<'a> {
    pub ctx: &'a HarContext,
    pub spec: HarRunSpec,
}

impl Workload for HarWorkload<'_> {
    type Prog = HarProgram;

    fn sample_period(&self) -> f64 {
        self.spec.sample_period
    }

    fn horizon(&self) -> f64 {
        self.spec.horizon
    }

    fn program(&self, seed: u64) -> HarProgram {
        let script = ActivityScript::generate(self.spec.horizon, seed);
        HarProgram::new(self.ctx.asvm.clone(), WindowSource::Script(script))
    }

    fn harvester(&self, seed: u64) -> Harvester {
        // The same deterministic script that feeds the classifier also
        // shakes the harvester.
        let script = ActivityScript::generate(self.spec.horizon, seed);
        let accel = script.accel_magnitude(50.0);
        Harvester::Replay(kinetic_power_trace(&accel, 50.0, &KineticConfig::default()))
    }

    fn smart_table(&self, _seed: u64) -> Option<SmartTable> {
        // The table prices the anytime feature pipeline, which is the
        // same for every volunteer; the seed only varies the inputs.
        let mcu = McuModel::paper_default();
        Some(smart_table(
            &self.ctx.asvm,
            &self.ctx.class_model,
            self.ctx.full_accuracy,
            &mcu,
        ))
    }
}

/// Run one HAR campaign under `policy`. Thin wrapper over
/// [`run_campaign`] with [`HarWorkload`].
pub fn run_har_policy(
    ctx: &HarContext,
    spec: &HarRunSpec,
    policy: Policy,
) -> Campaign<HarOutput> {
    let workload = HarWorkload { ctx, spec: spec.clone() };
    run_campaign(&workload, spec.script_seed, policy)
}

/// Fig. 4 — expected vs measured accuracy as a function of `p`.
pub struct Fig4Row {
    pub p: usize,
    pub expected: f64,
    pub measured: f64,
}

pub fn fig4(ctx: &HarContext, ps: &[usize]) -> Vec<Fig4Row> {
    let coh = coherence_curve_model(&ctx.asvm, &ctx.class_model, ps, 3000, 0xF164);
    let expected = expected_accuracy(&coh, ctx.full_accuracy, 6);
    let (test_rows, test_labels) = Corpus::features(&ctx.corpus.test);
    let measured = ctx.asvm.accuracy_curve(&test_rows, &test_labels, ps);
    ps.iter()
        .enumerate()
        .map(|(i, &p)| Fig4Row { p, expected: expected[i], measured: measured[i] })
        .collect()
}

/// Figs. 5-9 — one row per policy: accuracy / coherence / throughput /
/// latency summary over a (multi-volunteer) campaign set.
pub struct PolicyRow {
    pub policy: Policy,
    pub accuracy: f64,
    pub coherence_vs_continuous: f64,
    pub coherence_vs_chinchilla: f64,
    pub throughput_vs_continuous: f64,
    pub throughput_vs_greedy: f64,
    pub throughput_vs_chinchilla: f64,
    pub same_cycle_fraction: f64,
    pub mean_features: f64,
    pub state_energy_fraction: f64,
}

/// The five intermittent policies of §5 plus the continuous ceiling:
/// both regular-intermittent baselines (checkpointing Chinchilla and
/// task-based Alpaca) and the approximate runtimes.
pub fn har_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Alpaca,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
        Policy::Smart { bound: 0.80 },
    ]
}

/// Run every policy on the same volunteers and summarise (figs. 5-8).
pub fn har_policy_comparison(
    ctx: &HarContext,
    spec: &HarRunSpec,
    volunteers: &[u64],
) -> Vec<PolicyRow> {
    // campaigns[policy][volunteer]; every (policy, volunteer) pair is one
    // independent simulated device, dispatched through the bounded fleet
    // pool (see EXPERIMENTS.md §Perf).
    let policies = har_policies();
    if volunteers.is_empty() {
        return Vec::new();
    }
    let jobs: Vec<(Policy, u64)> = policies
        .iter()
        .flat_map(|&p| volunteers.iter().map(move |&v| (p, v)))
        .collect();
    let flat: Vec<Campaign<HarOutput>> = run_fleet(&jobs, None, |&(p, v)| {
        let s = HarRunSpec { script_seed: v, ..spec.clone() };
        run_har_policy(ctx, &s, p)
    });
    let campaigns: Vec<Vec<Campaign<HarOutput>>> = flat
        .chunks(volunteers.len())
        .map(|c| c.to_vec())
        .collect();
    summarise_policies(&policies, &campaigns, spec.sample_period)
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    crate::util::stats::mean(&v)
}

fn summarise_policies(
    policies: &[Policy],
    campaigns: &[Vec<Campaign<HarOutput>>],
    period: f64,
) -> Vec<PolicyRow> {
    let idx_of = |p: Policy| policies.iter().position(|&q| q == p).unwrap();
    let cont = idx_of(Policy::Continuous);
    let chin = idx_of(Policy::Chinchilla);
    let greedy = idx_of(Policy::Greedy);
    policies
        .iter()
        .enumerate()
        .map(|(i, &policy)| {
            let n = campaigns[i].len();
            let per_volunteer = |f: &dyn Fn(usize) -> f64| mean((0..n).map(f));
            PolicyRow {
                policy,
                accuracy: per_volunteer(&|v| super::metrics::har_accuracy(&campaigns[i][v])),
                coherence_vs_continuous: per_volunteer(&|v| {
                    super::metrics::har_coherence(&campaigns[i][v], &campaigns[cont][v], period)
                }),
                coherence_vs_chinchilla: per_volunteer(&|v| {
                    super::metrics::har_coherence(&campaigns[i][v], &campaigns[chin][v], period)
                }),
                throughput_vs_continuous: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[cont][v])
                }),
                throughput_vs_greedy: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[greedy][v])
                }),
                throughput_vs_chinchilla: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[chin][v])
                }),
                same_cycle_fraction: per_volunteer(&|v| {
                    super::metrics::same_cycle_fraction(&campaigns[i][v])
                }),
                mean_features: per_volunteer(&|v| {
                    mean(
                        campaigns[i][v]
                            .emitted()
                            .map(|r| r.steps_executed as f64),
                    )
                }),
                state_energy_fraction: per_volunteer(&|v| {
                    let c = &campaigns[i][v];
                    let total = c.app_energy + c.state_energy;
                    if total == 0.0 {
                        0.0
                    } else {
                        c.state_energy / total
                    }
                }),
            }
        })
        .collect()
}

/// Latency distributions (figs. 6 and 9): per-policy histograms over
/// power-cycle latency.
pub fn har_latency_histograms(
    ctx: &HarContext,
    spec: &HarRunSpec,
    volunteers: &[u64],
    max_cycles: usize,
) -> Vec<(Policy, crate::util::stats::Histogram)> {
    let policies = [
        Policy::Greedy,
        Policy::Smart { bound: 0.80 },
        Policy::Chinchilla,
        Policy::Alpaca,
    ];
    if volunteers.is_empty() {
        return policies
            .iter()
            .map(|&p| {
                (p, crate::util::stats::Histogram::new(0.0, max_cycles as f64, max_cycles))
            })
            .collect();
    }
    let jobs: Vec<(Policy, u64)> = policies
        .iter()
        .flat_map(|&p| volunteers.iter().map(move |&v| (p, v)))
        .collect();
    let flat: Vec<Campaign<HarOutput>> = run_fleet(&jobs, None, |&(p, v)| {
        let s = HarRunSpec { script_seed: v, ..spec.clone() };
        run_har_policy(ctx, &s, p)
    });
    policies
        .iter()
        .zip(flat.chunks(volunteers.len()))
        .map(|(&policy, campaigns)| {
            let mut h = crate::util::stats::Histogram::new(0.0, max_cycles as f64, max_cycles);
            for c in campaigns {
                for r in c.emitted() {
                    h.add(r.latency_cycles as f64);
                }
            }
            (policy, h)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Imaging experiments (§6).
// ---------------------------------------------------------------------

/// Parameters of one imaging campaign.
#[derive(Clone, Debug)]
pub struct ImgRunSpec {
    pub horizon: f64,
    /// Timer between rounds when energy is left (paper: 30 s).
    pub sample_period: f64,
    pub trace_seed: u64,
}

impl Default for ImgRunSpec {
    fn default() -> ImgRunSpec {
        ImgRunSpec { horizon: 2.0 * 3600.0, sample_period: 30.0, trace_seed: 3 }
    }
}

/// The imaging workload: Harris corner detection over the synthetic
/// picture pool, powered by one of the §6 ambient energy traces; `seed`
/// selects the trace realisation and the picture order.
pub struct ImgWorkload {
    pub spec: ImgRunSpec,
    pub trace: TraceKind,
}

impl Workload for ImgWorkload {
    type Prog = CornerProgram;

    fn sample_period(&self) -> f64 {
        self.spec.sample_period
    }

    fn horizon(&self) -> f64 {
        self.spec.horizon
    }

    fn program(&self, seed: u64) -> CornerProgram {
        CornerProgram::paper_default(seed ^ 0x1196)
    }

    fn harvester(&self, seed: u64) -> Harvester {
        Harvester::Replay(generate(self.trace, self.spec.horizon.min(1800.0), 0.01, seed))
    }

    fn smart_table(&self, seed: u64) -> Option<SmartTable> {
        // SMART's "accuracy" proxy for imaging: the fraction of response
        // rows computed (Fig. 12 shows corner equivalence degrading
        // with the perforation rate, monotone in rows to first order).
        // Price the same program the campaign runs.
        let prog = self.program(seed);
        let mcu = McuModel::paper_default();
        let total = prog.num_steps();
        let costs: Vec<OpCost> = (0..total).map(|j| prog.step_cost(j)).collect();
        let profile = EnergyProfile::from_costs(&mcu, &costs);
        let acc: Vec<f64> = (0..=total).map(|p| p as f64 / total as f64).collect();
        let emit = mcu.energy(&prog.emit_cost());
        Some(SmartTable::new(acc, &profile, emit))
    }
}

/// Run one imaging campaign under `policy` on the given energy trace.
/// Thin wrapper over [`run_campaign`] with [`ImgWorkload`].
pub fn run_img_policy(
    spec: &ImgRunSpec,
    trace: TraceKind,
    policy: Policy,
) -> Campaign<CornerOutput> {
    let workload = ImgWorkload { spec: spec.clone(), trace };
    run_campaign(&workload, spec.trace_seed, policy)
}

/// Fig. 12 — corner output vs perforation rate per picture kind.
pub struct Fig12Row {
    pub picture: crate::imgproc::images::Picture,
    pub skip_fraction: f64,
    pub corners: usize,
    pub reference_corners: usize,
    pub equivalent: bool,
}

pub fn fig12(size: usize, skip_fractions: &[f64]) -> Vec<Fig12Row> {
    use crate::imgproc::equivalence::equivalent;
    use crate::imgproc::harris::{harris_full, harris_perforated, HarrisConfig};
    use crate::imgproc::images::{render, Picture};
    let cfg = HarrisConfig::default();
    let mut rows = Vec::new();
    for &picture in &Picture::ALL {
        let img = render(picture, size, size, 11);
        let reference = harris_full(&img, &cfg);
        for &skip in skip_fractions {
            let run_rows = ((1.0 - skip) * size as f64).round() as usize;
            let corners = harris_perforated(&img, &cfg, run_rows);
            rows.push(Fig12Row {
                picture,
                skip_fraction: skip,
                corners: corners.len(),
                reference_corners: reference.len(),
                equivalent: equivalent(&reference, &corners),
            });
        }
    }
    rows
}

/// Figs. 13-15 rows: per-trace comparison of AIC vs Chinchilla.
pub struct ImgTraceRow {
    pub trace: TraceKind,
    pub equivalence_aic: f64,
    pub throughput_aic_vs_continuous: f64,
    pub throughput_chinchilla_vs_continuous: f64,
    pub aic_same_cycle: f64,
    pub chinchilla_latency_mean: f64,
}

/// Fig. 13 proper: per-picture equivalence pooled over all five traces
/// (the paper reports "at least 84 %" per picture complexity).
pub fn fig13_by_picture(
    spec: &ImgRunSpec,
) -> Vec<(crate::imgproc::images::Picture, f64)> {
    let size = crate::imgproc::images::EVAL_SIZE;
    let campaigns: Vec<_> =
        run_fleet(&TraceKind::ALL, None, |&trace| run_img_policy(spec, trace, Policy::Greedy));
    let refs: Vec<&Campaign<CornerOutput>> = campaigns.iter().collect();
    super::metrics::corner_equivalence_by_picture(&refs, size)
}

pub fn img_trace_comparison(spec: &ImgRunSpec) -> Vec<ImgTraceRow> {
    let size = crate::imgproc::images::EVAL_SIZE;
    // One fleet job per (trace, policy) device, as in the HAR sweeps.
    let jobs: Vec<(TraceKind, Policy)> = TraceKind::ALL
        .iter()
        .flat_map(|&t| {
            [Policy::Continuous, Policy::Greedy, Policy::Chinchilla]
                .into_iter()
                .map(move |p| (t, p))
        })
        .collect();
    let runs: Vec<Campaign<CornerOutput>> =
        run_fleet(&jobs, None, |&(t, p)| run_img_policy(spec, t, p));
    TraceKind::ALL
        .iter()
        .enumerate()
        .map(|(i, &trace)| {
            let cont = &runs[i * 3];
            let aic = &runs[i * 3 + 1];
            let chin = &runs[i * 3 + 2];
            let lat = {
                let v: Vec<f64> =
                    chin.emitted().map(|r| r.latency_cycles as f64).collect();
                crate::util::stats::mean(&v)
            };
            ImgTraceRow {
                trace,
                equivalence_aic: super::metrics::corner_equivalence_fraction(&aic, size),
                throughput_aic_vs_continuous: super::metrics::throughput_ratio(&aic, &cont),
                throughput_chinchilla_vs_continuous: super::metrics::throughput_ratio(
                    &chin, &cont,
                ),
                aic_same_cycle: super::metrics::same_cycle_fraction(&aic),
                chinchilla_latency_mean: lat,
            }
        })
        .collect()
}

/// A cheap smoke context for tests (small corpus, fast training).
pub fn test_context() -> HarContext {
    HarContext::build_with(
        &CorpusSpec {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 6,
        },
        7,
    )
}

/// Feature-count sanity for specs.
pub fn num_features() -> usize {
    NUM_FEATURES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_curves_rise_to_ceiling() {
        let ctx = test_context();
        let rows = fig4(&ctx, &[0, 20, 60, 140]);
        assert_eq!(rows.len(), 4);
        // Chance at p=0 (~1/6 measured and modelled).
        assert!(rows[0].measured < 0.45, "p=0 measured {}", rows[0].measured);
        // Measured accuracy at p=140 equals the full accuracy.
        assert!((rows[3].measured - ctx.full_accuracy).abs() < 1e-9);
        // Expected tracks measured within the paper's visual delta.
        for r in &rows {
            assert!(
                (r.expected - r.measured).abs() < 0.22,
                "p={}: expected={} measured={}",
                r.p,
                r.expected,
                r.measured
            );
        }
        // Monotone-ish growth.
        assert!(rows[2].measured > rows[0].measured);
    }

    #[test]
    fn greedy_har_campaign_emits_within_cycle() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 1800.0, ..Default::default() };
        let c = run_har_policy(&ctx, &spec, Policy::Greedy);
        assert!(c.emitted().count() > 0, "no results in 30 min");
        assert!((super::super::metrics::same_cycle_fraction(&c) - 1.0).abs() < 1e-9);
        assert_eq!(c.state_energy, 0.0, "approx must not manage state");
    }

    #[test]
    fn fig12_degrades_gracefully() {
        let rows = fig12(64, &[0.0, 0.3, 0.8]);
        assert_eq!(rows.len(), 9);
        for chunk in rows.chunks(3) {
            // skip=0 is exactly the reference.
            assert!(chunk[0].equivalent);
            assert_eq!(chunk[0].corners, chunk[0].reference_corners);
            // skip=0.8 finds no more corners than skip=0.3.
            assert!(chunk[2].corners <= chunk[1].corners + 2);
        }
    }
}
