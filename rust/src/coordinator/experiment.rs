//! Per-figure experiment definitions.
//!
//! Each paper figure maps to one function here returning structured rows;
//! the `aic` CLI and the `rust/benches/fig*` benches are thin wrappers.
//! See DESIGN.md §4 for the experiment index.

use crate::energy::harvester::{kinetic_power_trace, Harvester, KineticConfig};
use crate::energy::mcu::McuModel;
use crate::energy::traces::{generate, TraceKind};
use crate::exec::approx::{run as run_approx, ApproxConfig};
use crate::exec::chinchilla::{run as run_chinchilla, ChinchillaConfig};
use crate::exec::continuous::run as run_continuous;
use crate::exec::engine::{Engine, EngineConfig};
use crate::exec::{Campaign, Policy};
use crate::har::app::{smart_table, HarOutput, HarProgram, WindowSource};
use crate::har::dataset::{ActivityScript, Corpus, CorpusSpec};
use crate::har::NUM_FEATURES;
use crate::imgproc::app::{CornerOutput, CornerProgram};
use crate::svm::analysis::{
    coherence_curve_model, expected_accuracy, ClassFeatureModel,
};
use crate::svm::anytime::AnytimeSvm;
use crate::svm::train::{train_ovr, TrainConfig};

/// Everything the HAR experiments share: corpus, trained anytime SVM,
/// fitted class model, measured full accuracy.
pub struct HarContext {
    pub asvm: AnytimeSvm,
    pub class_model: ClassFeatureModel,
    pub corpus: Corpus,
    pub full_accuracy: f64,
}

impl HarContext {
    /// Build a context (train on the synthetic corpus) from a seed.
    pub fn build(seed: u64) -> HarContext {
        HarContext::build_with(&CorpusSpec::default(), seed)
    }

    pub fn build_with(spec: &CorpusSpec, seed: u64) -> HarContext {
        let corpus = Corpus::generate(spec, seed);
        let (rows, labels) = Corpus::features(&corpus.train);
        let svm = train_ovr(&rows, &labels, 6, &TrainConfig::default());
        let asvm = AnytimeSvm::by_coefficient_magnitude(svm);
        let scaled: Vec<Vec<f64>> =
            rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
        let class_model = ClassFeatureModel::fit(&scaled, &labels, 6);
        let (test_rows, test_labels) = Corpus::features(&corpus.test);
        let full_accuracy = asvm.svm.accuracy(&test_rows, &test_labels);
        HarContext { asvm, class_model, corpus, full_accuracy }
    }
}

/// Parameters of one HAR device campaign.
#[derive(Clone, Debug)]
pub struct HarRunSpec {
    /// Campaign horizon, seconds.
    pub horizon: f64,
    /// Sampling period (paper: one minute).
    pub sample_period: f64,
    /// Seed for the volunteer's activity script (also powers the device).
    pub script_seed: u64,
}

impl Default for HarRunSpec {
    fn default() -> HarRunSpec {
        HarRunSpec { horizon: 4.0 * 3600.0, sample_period: 60.0, script_seed: 1 }
    }
}

/// Run one HAR campaign under `policy`, powered by the kinetic energy of
/// the same wrist motion that produces the sensor windows.
pub fn run_har_policy(
    ctx: &HarContext,
    spec: &HarRunSpec,
    policy: Policy,
) -> Campaign<HarOutput> {
    let script = ActivityScript::generate(spec.horizon, spec.script_seed);
    let mcu = McuModel::paper_default();
    let mut program =
        HarProgram::new(ctx.asvm.clone(), WindowSource::Script(script.clone()));
    match policy {
        Policy::Continuous => {
            run_continuous(&mut program, &mcu, spec.sample_period, spec.horizon)
        }
        _ => {
            let accel = script.accel_magnitude(50.0);
            let trace = kinetic_power_trace(&accel, 50.0, &KineticConfig::default());
            let engine_cfg = EngineConfig::paper_default(spec.horizon);
            let mut engine = Engine::new(engine_cfg, Harvester::Replay(trace));
            match policy {
                Policy::Chinchilla => {
                    let cfg = ChinchillaConfig {
                        sample_period: spec.sample_period,
                        ..Default::default()
                    };
                    run_chinchilla(&mut program, &mut engine, &cfg)
                }
                Policy::Greedy => {
                    run_approx(&mut program, &mut engine, &ApproxConfig::greedy(spec.sample_period))
                }
                Policy::Smart { bound } => {
                    let table =
                        smart_table(&ctx.asvm, &ctx.class_model, ctx.full_accuracy, &mcu);
                    run_approx(
                        &mut program,
                        &mut engine,
                        &ApproxConfig::smart(spec.sample_period, bound, table),
                    )
                }
                Policy::Continuous => unreachable!(),
            }
        }
    }
}

/// Fig. 4 — expected vs measured accuracy as a function of `p`.
pub struct Fig4Row {
    pub p: usize,
    pub expected: f64,
    pub measured: f64,
}

pub fn fig4(ctx: &HarContext, ps: &[usize]) -> Vec<Fig4Row> {
    let coh = coherence_curve_model(&ctx.asvm, &ctx.class_model, ps, 3000, 0xF164);
    let expected = expected_accuracy(&coh, ctx.full_accuracy, 6);
    let (test_rows, test_labels) = Corpus::features(&ctx.corpus.test);
    let measured = ctx.asvm.accuracy_curve(&test_rows, &test_labels, ps);
    ps.iter()
        .enumerate()
        .map(|(i, &p)| Fig4Row { p, expected: expected[i], measured: measured[i] })
        .collect()
}

/// Figs. 5-9 — one row per policy: accuracy / coherence / throughput /
/// latency summary over a (multi-volunteer) campaign set.
pub struct PolicyRow {
    pub policy: Policy,
    pub accuracy: f64,
    pub coherence_vs_continuous: f64,
    pub coherence_vs_chinchilla: f64,
    pub throughput_vs_continuous: f64,
    pub throughput_vs_greedy: f64,
    pub throughput_vs_chinchilla: f64,
    pub same_cycle_fraction: f64,
    pub mean_features: f64,
    pub state_energy_fraction: f64,
}

/// The four intermittent policies of §5 plus the continuous ceiling.
pub fn har_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
        Policy::Smart { bound: 0.80 },
    ]
}

/// Run every policy on the same volunteers and summarise (figs. 5-8).
pub fn har_policy_comparison(
    ctx: &HarContext,
    spec: &HarRunSpec,
    volunteers: &[u64],
) -> Vec<PolicyRow> {
    // campaigns[policy][volunteer]; all (policy, volunteer) devices run
    // in parallel on OS threads (see EXPERIMENTS.md §Perf — this is the
    // fleet pattern of coordinator::fleet applied to the figure sweeps).
    let policies = har_policies();
    let flat: Vec<Campaign<HarOutput>> = std::thread::scope(|scope| {
        let handles: Vec<_> = policies
            .iter()
            .flat_map(|&p| {
                volunteers.iter().map(move |&v| (p, v)).collect::<Vec<_>>()
            })
            .map(|(p, v)| {
                let s = HarRunSpec { script_seed: v, ..spec.clone() };
                scope.spawn(move || run_har_policy(ctx, &s, p))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("campaign thread")).collect()
    });
    let campaigns: Vec<Vec<Campaign<HarOutput>>> = flat
        .chunks(volunteers.len())
        .map(|c| c.to_vec())
        .collect();
    summarise_policies(&policies, &campaigns, spec.sample_period)
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    crate::util::stats::mean(&v)
}

fn summarise_policies(
    policies: &[Policy],
    campaigns: &[Vec<Campaign<HarOutput>>],
    period: f64,
) -> Vec<PolicyRow> {
    let idx_of = |p: Policy| policies.iter().position(|&q| q == p).unwrap();
    let cont = idx_of(Policy::Continuous);
    let chin = idx_of(Policy::Chinchilla);
    let greedy = idx_of(Policy::Greedy);
    policies
        .iter()
        .enumerate()
        .map(|(i, &policy)| {
            let n = campaigns[i].len();
            let per_volunteer = |f: &dyn Fn(usize) -> f64| mean((0..n).map(f));
            PolicyRow {
                policy,
                accuracy: per_volunteer(&|v| super::metrics::har_accuracy(&campaigns[i][v])),
                coherence_vs_continuous: per_volunteer(&|v| {
                    super::metrics::har_coherence(&campaigns[i][v], &campaigns[cont][v], period)
                }),
                coherence_vs_chinchilla: per_volunteer(&|v| {
                    super::metrics::har_coherence(&campaigns[i][v], &campaigns[chin][v], period)
                }),
                throughput_vs_continuous: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[cont][v])
                }),
                throughput_vs_greedy: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[greedy][v])
                }),
                throughput_vs_chinchilla: per_volunteer(&|v| {
                    super::metrics::throughput_ratio(&campaigns[i][v], &campaigns[chin][v])
                }),
                same_cycle_fraction: per_volunteer(&|v| {
                    super::metrics::same_cycle_fraction(&campaigns[i][v])
                }),
                mean_features: per_volunteer(&|v| {
                    mean(
                        campaigns[i][v]
                            .emitted()
                            .map(|r| r.steps_executed as f64),
                    )
                }),
                state_energy_fraction: per_volunteer(&|v| {
                    let c = &campaigns[i][v];
                    let total = c.app_energy + c.state_energy;
                    if total == 0.0 {
                        0.0
                    } else {
                        c.state_energy / total
                    }
                }),
            }
        })
        .collect()
}

/// Latency distributions (figs. 6 and 9): per-policy histograms over
/// power-cycle latency.
pub fn har_latency_histograms(
    ctx: &HarContext,
    spec: &HarRunSpec,
    volunteers: &[u64],
    max_cycles: usize,
) -> Vec<(Policy, crate::util::stats::Histogram)> {
    [Policy::Greedy, Policy::Smart { bound: 0.80 }, Policy::Chinchilla]
        .iter()
        .map(|&policy| {
            let mut h = crate::util::stats::Histogram::new(0.0, max_cycles as f64, max_cycles);
            for &v in volunteers {
                let s = HarRunSpec { script_seed: v, ..spec.clone() };
                let c = run_har_policy(ctx, &s, policy);
                for r in c.emitted() {
                    h.add(r.latency_cycles as f64);
                }
            }
            (policy, h)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Imaging experiments (§6).
// ---------------------------------------------------------------------

/// Parameters of one imaging campaign.
#[derive(Clone, Debug)]
pub struct ImgRunSpec {
    pub horizon: f64,
    /// Timer between rounds when energy is left (paper: 30 s).
    pub sample_period: f64,
    pub trace_seed: u64,
}

impl Default for ImgRunSpec {
    fn default() -> ImgRunSpec {
        ImgRunSpec { horizon: 2.0 * 3600.0, sample_period: 30.0, trace_seed: 3 }
    }
}

/// Run one imaging campaign under `policy` on the given energy trace.
pub fn run_img_policy(
    spec: &ImgRunSpec,
    trace: TraceKind,
    policy: Policy,
) -> Campaign<CornerOutput> {
    let mcu = McuModel::paper_default();
    let mut program = CornerProgram::paper_default(spec.trace_seed ^ 0x1196);
    match policy {
        Policy::Continuous => {
            run_continuous(&mut program, &mcu, spec.sample_period, spec.horizon)
        }
        _ => {
            let power = generate(trace, spec.horizon.min(1800.0), 0.01, spec.trace_seed);
            let engine_cfg = EngineConfig::paper_default(spec.horizon);
            let mut engine = Engine::new(engine_cfg, Harvester::Replay(power));
            match policy {
                Policy::Chinchilla => {
                    let cfg = ChinchillaConfig {
                        sample_period: spec.sample_period,
                        ..Default::default()
                    };
                    run_chinchilla(&mut program, &mut engine, &cfg)
                }
                _ => run_approx(
                    &mut program,
                    &mut engine,
                    &ApproxConfig::greedy(spec.sample_period),
                ),
            }
        }
    }
}

/// Fig. 12 — corner output vs perforation rate per picture kind.
pub struct Fig12Row {
    pub picture: crate::imgproc::images::Picture,
    pub skip_fraction: f64,
    pub corners: usize,
    pub reference_corners: usize,
    pub equivalent: bool,
}

pub fn fig12(size: usize, skip_fractions: &[f64]) -> Vec<Fig12Row> {
    use crate::imgproc::equivalence::equivalent;
    use crate::imgproc::harris::{harris_full, harris_perforated, HarrisConfig};
    use crate::imgproc::images::{render, Picture};
    let cfg = HarrisConfig::default();
    let mut rows = Vec::new();
    for &picture in &Picture::ALL {
        let img = render(picture, size, size, 11);
        let reference = harris_full(&img, &cfg);
        for &skip in skip_fractions {
            let run_rows = ((1.0 - skip) * size as f64).round() as usize;
            let corners = harris_perforated(&img, &cfg, run_rows);
            rows.push(Fig12Row {
                picture,
                skip_fraction: skip,
                corners: corners.len(),
                reference_corners: reference.len(),
                equivalent: equivalent(&reference, &corners),
            });
        }
    }
    rows
}

/// Figs. 13-15 rows: per-trace comparison of AIC vs Chinchilla.
pub struct ImgTraceRow {
    pub trace: TraceKind,
    pub equivalence_aic: f64,
    pub throughput_aic_vs_continuous: f64,
    pub throughput_chinchilla_vs_continuous: f64,
    pub aic_same_cycle: f64,
    pub chinchilla_latency_mean: f64,
}

/// Fig. 13 proper: per-picture equivalence pooled over all five traces
/// (the paper reports "at least 84 %" per picture complexity).
pub fn fig13_by_picture(
    spec: &ImgRunSpec,
) -> Vec<(crate::imgproc::images::Picture, f64)> {
    let size = crate::imgproc::images::EVAL_SIZE;
    let campaigns: Vec<_> = TraceKind::ALL
        .iter()
        .map(|&trace| run_img_policy(spec, trace, Policy::Greedy))
        .collect();
    let refs: Vec<&Campaign<CornerOutput>> = campaigns.iter().collect();
    super::metrics::corner_equivalence_by_picture(&refs, size)
}

pub fn img_trace_comparison(spec: &ImgRunSpec) -> Vec<ImgTraceRow> {
    let size = crate::imgproc::images::EVAL_SIZE;
    // One thread per (trace, policy) device, as in the HAR sweeps.
    let runs: Vec<Campaign<CornerOutput>> = std::thread::scope(|scope| {
        let handles: Vec<_> = TraceKind::ALL
            .iter()
            .flat_map(|&t| {
                [Policy::Continuous, Policy::Greedy, Policy::Chinchilla]
                    .into_iter()
                    .map(move |p| (t, p))
            })
            .map(|(t, p)| scope.spawn(move || run_img_policy(spec, t, p)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("imaging thread")).collect()
    });
    TraceKind::ALL
        .iter()
        .enumerate()
        .map(|(i, &trace)| {
            let cont = &runs[i * 3];
            let aic = &runs[i * 3 + 1];
            let chin = &runs[i * 3 + 2];
            let lat = {
                let v: Vec<f64> =
                    chin.emitted().map(|r| r.latency_cycles as f64).collect();
                crate::util::stats::mean(&v)
            };
            ImgTraceRow {
                trace,
                equivalence_aic: super::metrics::corner_equivalence_fraction(&aic, size),
                throughput_aic_vs_continuous: super::metrics::throughput_ratio(&aic, &cont),
                throughput_chinchilla_vs_continuous: super::metrics::throughput_ratio(
                    &chin, &cont,
                ),
                aic_same_cycle: super::metrics::same_cycle_fraction(&aic),
                chinchilla_latency_mean: lat,
            }
        })
        .collect()
}

/// A cheap smoke context for tests (small corpus, fast training).
pub fn test_context() -> HarContext {
    HarContext::build_with(
        &CorpusSpec {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 6,
        },
        7,
    )
}

/// Feature-count sanity for specs.
pub fn num_features() -> usize {
    NUM_FEATURES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_curves_rise_to_ceiling() {
        let ctx = test_context();
        let rows = fig4(&ctx, &[0, 20, 60, 140]);
        assert_eq!(rows.len(), 4);
        // Chance at p=0 (~1/6 measured and modelled).
        assert!(rows[0].measured < 0.45, "p=0 measured {}", rows[0].measured);
        // Measured accuracy at p=140 equals the full accuracy.
        assert!((rows[3].measured - ctx.full_accuracy).abs() < 1e-9);
        // Expected tracks measured within the paper's visual delta.
        for r in &rows {
            assert!(
                (r.expected - r.measured).abs() < 0.22,
                "p={}: expected={} measured={}",
                r.p,
                r.expected,
                r.measured
            );
        }
        // Monotone-ish growth.
        assert!(rows[2].measured > rows[0].measured);
    }

    #[test]
    fn greedy_har_campaign_emits_within_cycle() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 1800.0, ..Default::default() };
        let c = run_har_policy(&ctx, &spec, Policy::Greedy);
        assert!(c.emitted().count() > 0, "no results in 30 min");
        assert!((super::super::metrics::same_cycle_fraction(&c) - 1.0).abs() < 1e-9);
        assert_eq!(c.state_energy, 0.0, "approx must not manage state");
    }

    #[test]
    fn fig12_degrades_gracefully() {
        let rows = fig12(64, &[0.0, 0.3, 0.8]);
        assert_eq!(rows.len(), 9);
        for chunk in rows.chunks(3) {
            // skip=0 is exactly the reference.
            assert!(chunk[0].equivalent);
            assert_eq!(chunk[0].corners, chunk[0].reference_corners);
            // skip=0.8 finds no more corners than skip=0.3.
            assert!(chunk[2].corners <= chunk[1].corners + 2);
        }
    }
}
