//! Declarative scenarios: a sweep is data, not code.
//!
//! A [`Scenario`] is one serialisable spec describing a whole campaign
//! grid — workload × harvesters × device knobs × policies × seeds —
//! plus the derived-metric [`Projection`] that turns the grid into
//! tables. Every paper figure (figs. 4–15) is a *named built-in
//! scenario* ([`builtin`]); arbitrary grids the paper never printed —
//! HAR on the five ambient traces, imaging on the kinetic harvester,
//! capacitor-size × policy sweeps — are JSON files fed to
//! `aic sweep --scenario file.json`, with zero new Rust.
//!
//! The pipeline is strictly staged:
//!
//! ```text
//! Scenario ──resolve(fast)──► Scenario ──plan()──► JobPlan (deterministic cells)
//!                                                     │ run_fleet (job-ordered)
//!                                                     ▼
//!                         SweepRun { grid } ──projections──► Vec<TableData> ──► Sink
//! ```
//!
//! The plan is a pure function of the spec, and the fleet returns
//! results in job order, so every sweep is deterministic for any
//! `AIC_WORKERS` setting. JSON round-trips losslessly
//! (`to_json_string` → [`Scenario::parse`] → identical plan), which is
//! what makes scenario files a stable interchange format.

use crate::audio::app::AudioOutput;
use crate::coordinator::experiment::{
    run_campaign_cached, AudioRunSpec, AudioWorkload, HarContext, HarRunSpec, HarWorkload,
    ImgRunSpec, ImgWorkload, SupplyCache,
};
use crate::coordinator::fleet::run_fleet;
use crate::coordinator::metrics;
use crate::coordinator::sink::{f2, pct, ratio, TableData};
use crate::coordinator::store::digest::{CellDigest, FleetDigest, Needs};
use crate::coordinator::sync::{self, FleetSpec};
use crate::energy::capacitor::Capacitor;
use crate::energy::harvester::{kinetic_power_trace, Harvester, KineticConfig};
use crate::energy::synth::SynthSpec;
use crate::energy::traces::{generate, TraceKind};
use crate::exec::engine::{EngineConfig, EngineKind};
use crate::exec::{Campaign, Policy};
use crate::har::app::HarOutput;
use crate::har::dataset::{ActivityScript, Corpus, CorpusSpec};
use crate::imgproc::app::CornerOutput;
use crate::imgproc::images::{Picture, EVAL_SIZE};
use crate::util::json::{self, opt_arr, opt_bool, opt_f64, opt_str, opt_u64, opt_usize, Value};
use crate::util::stats::Histogram;

// ---------------------------------------------------------------------
// Spec axes.
// ---------------------------------------------------------------------

/// Which energy supply powers a device cell.
#[derive(Clone, Debug, PartialEq)]
pub enum HarvesterSpec {
    /// Kinetic energy of the volunteer's wrist motion; the seed selects
    /// the activity script (the paper's §5 HAR supply).
    Kinetic,
    /// One of the §6 ambient traces; the seed selects the realisation.
    Ambient(TraceKind),
    /// A generated stochastic environment (`energy::synth`); the seed
    /// selects the family member. Serialised in scenario files as
    /// `{"synth": {...spec...}}`.
    Synth(SynthSpec),
}

impl HarvesterSpec {
    pub fn name(&self) -> String {
        match self {
            HarvesterSpec::Kinetic => "kinetic".to_string(),
            HarvesterSpec::Ambient(kind) => kind.name().to_string(),
            HarvesterSpec::Synth(spec) => spec.name.clone(),
        }
    }

    /// The named (non-synth) supplies; synthetic environments have no
    /// bare-name spelling — they come from a spec object or a
    /// `synth:<file>` CLI reference.
    pub fn from_name(s: &str) -> Option<HarvesterSpec> {
        if s == "kinetic" {
            Some(HarvesterSpec::Kinetic)
        } else {
            TraceKind::from_name(s).map(HarvesterSpec::Ambient)
        }
    }

    /// Build the supply for one device (deterministic in `seed`). The
    /// kinetic arm derives the trace from the same activity script that
    /// feeds the HAR classifier; ambient traces are capped at one 30-min
    /// realisation and replayed periodically, as the imaging figures
    /// always did; synth environments realise their family member for
    /// the seed, emitting segments natively (no sampling grid).
    pub fn build(&self, horizon: f64, seed: u64) -> Harvester {
        match self {
            HarvesterSpec::Kinetic => {
                let script = ActivityScript::generate(horizon, seed);
                let accel = script.accel_magnitude(50.0);
                Harvester::Replay(kinetic_power_trace(&accel, 50.0, &KineticConfig::default()))
            }
            HarvesterSpec::Ambient(kind) => {
                Harvester::Replay(generate(*kind, horizon.min(1800.0), 0.01, seed))
            }
            HarvesterSpec::Synth(spec) => Harvester::Synth(spec.build(seed)),
        }
    }

    fn to_json(&self) -> Value {
        match self {
            HarvesterSpec::Synth(spec) => Value::obj(vec![("synth", spec.to_json())]),
            other => other.name().into(),
        }
    }

    fn from_json(v: &Value) -> Result<HarvesterSpec, String> {
        if let Some(name) = v.as_str() {
            return HarvesterSpec::from_name(name).ok_or_else(|| {
                format!("unknown harvester '{name}' (expected kinetic|rf|som|sim|sor|sir)")
            });
        }
        if let Some(obj) = v.as_obj() {
            for key in obj.keys() {
                if key != "synth" {
                    return Err(format!("unknown harvester key '{key}'"));
                }
            }
            return SynthSpec::from_json(v.get("synth")).map(HarvesterSpec::Synth);
        }
        Err("harvester must be a supply name or a {\"synth\": {...}} object".to_string())
    }
}

/// Device knobs of one cell: capacitor sizing/thresholds and the energy
/// integrator. `None` fields keep the paper defaults; `engine: None`
/// keeps the `AIC_ENGINE` environment variable as a read-only fallback
/// (the CLI's `--engine` flag lands here instead of mutating the
/// process environment).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceSpec {
    /// Buffer capacitance, farads (paper: 1470e-6).
    pub capacitance: Option<f64>,
    /// Turn-on threshold, volts (paper: 3.0).
    pub v_on: Option<f64>,
    /// Brown-out threshold, volts (paper: 1.8).
    pub v_off: Option<f64>,
    /// Energy integrator; `None` defers to `AIC_ENGINE`.
    pub engine: Option<EngineKind>,
}

impl DeviceSpec {
    /// The engine configuration this spec selects on `horizon`. With no
    /// overrides this is exactly [`EngineConfig::paper_default`].
    pub fn engine_config(&self, horizon: f64) -> EngineConfig {
        let mut cfg = EngineConfig::paper_default(horizon);
        let (base_c, base_vmax, base_von, base_voff) = {
            let b = &cfg.capacitor;
            (b.capacitance, b.v_max, b.v_on, b.v_off)
        };
        let cap = Capacitor::new(
            self.capacitance.unwrap_or(base_c),
            base_vmax,
            self.v_on.unwrap_or(base_von),
            self.v_off.unwrap_or(base_voff),
        );
        cfg.initial_voltage = cap.v_on;
        cfg.capacitor = cap;
        if let Some(kind) = self.engine {
            cfg.kind = kind;
        }
        cfg
    }

    /// Short human label for table rows ("paper" when all-default).
    pub fn label(&self) -> String {
        if *self == DeviceSpec::default() {
            return "paper".to_string();
        }
        let mut parts = Vec::new();
        if let Some(c) = self.capacitance {
            parts.push(format!("C={c}"));
        }
        if let Some(v) = self.v_on {
            parts.push(format!("Von={v}"));
        }
        if let Some(v) = self.v_off {
            parts.push(format!("Voff={v}"));
        }
        if let Some(k) = self.engine {
            parts.push(format!("engine={}", k.label()));
        }
        parts.join(" ")
    }

    fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = Vec::new();
        if let Some(c) = self.capacitance {
            pairs.push(("capacitance", c.into()));
        }
        if let Some(v) = self.v_on {
            pairs.push(("v_on", v.into()));
        }
        if let Some(v) = self.v_off {
            pairs.push(("v_off", v.into()));
        }
        if let Some(k) = self.engine {
            pairs.push(("engine", k.label().into()));
        }
        Value::obj(pairs)
    }

    fn from_json(v: &Value) -> Result<DeviceSpec, String> {
        let obj = v.as_obj().ok_or("device must be a JSON object")?;
        for key in obj.keys() {
            if !["capacitance", "v_on", "v_off", "engine"].contains(&key.as_str()) {
                return Err(format!("unknown device key '{key}'"));
            }
        }
        let engine = match opt_str(v, "engine")? {
            None => None,
            Some(s) => Some(
                EngineKind::parse(s)
                    .ok_or_else(|| format!("unknown engine '{s}' (expected analytic|step)"))?,
            ),
        };
        Ok(DeviceSpec {
            capacitance: opt_f64(v, "capacitance")?,
            v_on: opt_f64(v, "v_on")?,
            v_off: opt_f64(v, "v_off")?,
            engine,
        })
    }
}

/// What the grid computes per cell.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSpec {
    /// HAR campaigns: seeds are volunteers' activity scripts.
    Har,
    /// Harris imaging campaigns: seeds are trace/picture realisations.
    Img,
    /// Anytime acoustic event detection: seeds are event scripts.
    Audio,
    /// Fig. 4 offline analysis: expected vs measured accuracy per
    /// anytime prefix length.
    AccuracyCurve { ps: Vec<usize> },
    /// Fig. 12 offline analysis: corner output per perforation rate.
    Perforation { size: usize, skips: Vec<f64> },
    /// Multi-device fleet with coordination-free delta sync: each cell
    /// simulates N devices on per-seed substreams of the cell's supply,
    /// meeting opportunistically ([`sync::run_fleet_cell`]).
    Fleet(FleetSpec),
}

impl WorkloadSpec {
    pub fn is_campaign(&self) -> bool {
        matches!(
            self,
            WorkloadSpec::Har | WorkloadSpec::Img | WorkloadSpec::Audio | WorkloadSpec::Fleet(_)
        )
    }

    fn to_json(&self) -> Value {
        match self {
            WorkloadSpec::Har => "har".into(),
            WorkloadSpec::Img => "img".into(),
            WorkloadSpec::Audio => "audio".into(),
            WorkloadSpec::Fleet(fs) => fs.to_json(),
            WorkloadSpec::AccuracyCurve { ps } => Value::obj(vec![
                ("kind", "accuracy-curve".into()),
                ("ps", Value::Arr(ps.iter().map(|&p| Value::Num(p as f64)).collect())),
            ]),
            WorkloadSpec::Perforation { size, skips } => Value::obj(vec![
                ("kind", "perforation".into()),
                ("size", (*size).into()),
                ("skips", Value::nums(skips)),
            ]),
        }
    }

    fn from_json(v: &Value) -> Result<WorkloadSpec, String> {
        if let Some(s) = v.as_str() {
            return match s {
                "har" => Ok(WorkloadSpec::Har),
                "img" => Ok(WorkloadSpec::Img),
                "audio" => Ok(WorkloadSpec::Audio),
                _ => {
                    Err(format!("unknown workload '{s}' (expected har|img|audio or an object)"))
                }
            };
        }
        let obj = v.as_obj().ok_or("workload must be a string or an object")?;
        match v.get("kind").as_str() {
            Some("accuracy-curve") => {
                for key in obj.keys() {
                    if !["kind", "ps"].contains(&key.as_str()) {
                        return Err(format!("unknown workload key '{key}'"));
                    }
                }
                let ps = v
                    .get("ps")
                    .as_arr()
                    .ok_or("accuracy-curve needs a 'ps' array")?
                    .iter()
                    .map(|p| {
                        p.as_u64()
                            .map(|n| n as usize)
                            .ok_or_else(|| "'ps' entries must be unsigned integers".to_string())
                    })
                    .collect::<Result<Vec<usize>, String>>()?;
                Ok(WorkloadSpec::AccuracyCurve { ps })
            }
            Some("perforation") => {
                for key in obj.keys() {
                    if !["kind", "size", "skips"].contains(&key.as_str()) {
                        return Err(format!("unknown workload key '{key}'"));
                    }
                }
                let size = v
                    .get("size")
                    .as_u64()
                    .map(|n| n as usize)
                    .ok_or("perforation needs an unsigned integer 'size'")?;
                let skips = v
                    .get("skips")
                    .as_arr()
                    .ok_or("perforation needs a 'skips' array")?
                    .iter()
                    .map(|s| s.as_f64().ok_or_else(|| "'skips' entries must be numbers".to_string()))
                    .collect::<Result<Vec<f64>, String>>()?;
                Ok(WorkloadSpec::Perforation { size, skips })
            }
            Some("fleet") => Ok(WorkloadSpec::Fleet(FleetSpec::from_json(v)?)),
            _ => Err("workload object needs kind: accuracy-curve|perforation|fleet".to_string()),
        }
    }
}

/// HAR corpus/training parameters (ignored by non-HAR workloads).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Training {
    pub train_volunteers: usize,
    pub test_volunteers: usize,
    pub windows_per_volunteer_per_class: usize,
    pub seed: u64,
}

impl Training {
    /// Full-fidelity training on the default corpus.
    pub fn full(seed: u64) -> Training {
        let d = CorpusSpec::default();
        Training {
            train_volunteers: d.train_volunteers,
            test_volunteers: d.test_volunteers,
            windows_per_volunteer_per_class: d.windows_per_volunteer_per_class,
            seed,
        }
    }

    /// The CI-sized corpus `experiment::test_context` trains on.
    pub fn tiny() -> Training {
        Training {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 6,
            seed: 7,
        }
    }

    pub fn corpus_spec(&self) -> CorpusSpec {
        CorpusSpec {
            train_volunteers: self.train_volunteers,
            test_volunteers: self.test_volunteers,
            windows_per_volunteer_per_class: self.windows_per_volunteer_per_class,
        }
    }

    /// Train the shared HAR context this spec describes (the expensive,
    /// once-per-sweep step).
    pub fn context(&self) -> HarContext {
        HarContext::build_with(&self.corpus_spec(), self.seed)
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("train_volunteers", self.train_volunteers.into()),
            ("test_volunteers", self.test_volunteers.into()),
            ("windows", self.windows_per_volunteer_per_class.into()),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    fn from_json(v: &Value, base: Training) -> Result<Training, String> {
        let obj = v.as_obj().ok_or("training must be a JSON object")?;
        for key in obj.keys() {
            if !["train_volunteers", "test_volunteers", "windows", "seed"]
                .contains(&key.as_str())
            {
                return Err(format!("unknown training key '{key}'"));
            }
        }
        Ok(Training {
            train_volunteers: opt_usize(v, "train_volunteers")?.unwrap_or(base.train_volunteers),
            test_volunteers: opt_usize(v, "test_volunteers")?.unwrap_or(base.test_volunteers),
            windows_per_volunteer_per_class: opt_usize(v, "windows")?
                .unwrap_or(base.windows_per_volunteer_per_class),
            seed: opt_u64(v, "seed")?.unwrap_or(base.seed),
        })
    }
}

/// What `--fast` does to this scenario (CI-sized sweeps). One place for
/// the scaling the CLI helpers and every bench used to duplicate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FastMode {
    /// Replacement campaign horizon, seconds.
    pub horizon: Option<f64>,
    /// Keep only the first N seeds.
    pub max_seeds: Option<usize>,
    /// Swap training for [`Training::tiny`].
    pub tiny_corpus: bool,
    /// Replacement evaluation size for `Perforation` workloads.
    pub img_size: Option<usize>,
}

impl FastMode {
    /// `--fast` changes nothing (fig. 4 reports full fidelity always).
    pub fn none() -> FastMode {
        FastMode::default()
    }

    fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = Vec::new();
        if let Some(h) = self.horizon {
            pairs.push(("horizon", h.into()));
        }
        if let Some(n) = self.max_seeds {
            pairs.push(("max_seeds", n.into()));
        }
        if self.tiny_corpus {
            pairs.push(("tiny_corpus", true.into()));
        }
        if let Some(s) = self.img_size {
            pairs.push(("img_size", s.into()));
        }
        Value::obj(pairs)
    }

    fn from_json(v: &Value) -> Result<FastMode, String> {
        let obj = v.as_obj().ok_or("fast must be a JSON object")?;
        for key in obj.keys() {
            if !["horizon", "max_seeds", "tiny_corpus", "img_size"].contains(&key.as_str()) {
                return Err(format!("unknown fast key '{key}'"));
            }
        }
        Ok(FastMode {
            horizon: opt_f64(v, "horizon")?,
            max_seeds: opt_usize(v, "max_seeds")?,
            tiny_corpus: opt_bool(v, "tiny_corpus")?.unwrap_or(false),
            img_size: opt_usize(v, "img_size")?,
        })
    }
}

/// The derived-metric view rendered from the grid — each paper figure is
/// one of these plus a scenario; custom sweeps default to [`Cells`].
///
/// [`Cells`]: Projection::Cells
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Projection {
    /// One row per grid cell with the standard campaign metrics.
    Cells,
    /// Figs. 5: per-policy accuracy/throughput summary.
    PolicyAccuracy,
    /// Fig. 7: per-policy coherence + throughput vs continuous.
    PolicyCoherence,
    /// Fig. 8: per-policy coherence vs Chinchilla, throughput vs GREEDY.
    PolicyVsChinchilla,
    /// Fig. 6: latency distribution buckets (emulation framing).
    LatencyEmulation,
    /// Fig. 9: latency distribution buckets (real-world framing).
    LatencyRealWorld,
    /// Fig. 4: expected vs measured accuracy curve.
    AccuracyCurve,
    /// Fig. 12: corner output vs perforation rate.
    Perforation,
    /// Fig. 13: per-picture equivalence + per-trace supplementary table.
    ImgEquivalence,
    /// Fig. 14: imaging throughput normalised to continuous.
    ImgThroughput,
    /// Fig. 15: imaging latency per trace.
    ImgLatency,
    /// Audio: per-policy detection accuracy, refinement depth and
    /// latency summary.
    AudioSummary,
    /// Adaptive-vs-static judgement: one accuracy/throughput point per
    /// policy with Pareto-frontier and Approxify-style auto-selection
    /// markers (any campaign workload).
    Pareto,
    /// Fleet-level detection latency: coverage and mean time from a
    /// detection to fleet-wide knowledge, per cell.
    FleetLatency,
    /// Convergence time vs duty cycle: when the fleet's replicas last
    /// diverged, against how often its devices were powered.
    FleetConvergence,
    /// Wire-cost accounting: bytes synced, per-exchange cost, GC
    /// effectiveness.
    FleetBytes,
}

impl Projection {
    pub fn name(&self) -> &'static str {
        match self {
            Projection::Cells => "cells",
            Projection::PolicyAccuracy => "policy-accuracy",
            Projection::PolicyCoherence => "policy-coherence",
            Projection::PolicyVsChinchilla => "policy-vs-chinchilla",
            Projection::LatencyEmulation => "latency-emulation",
            Projection::LatencyRealWorld => "latency-real-world",
            Projection::AccuracyCurve => "accuracy-curve",
            Projection::Perforation => "perforation",
            Projection::ImgEquivalence => "img-equivalence",
            Projection::ImgThroughput => "img-throughput",
            Projection::ImgLatency => "img-latency",
            Projection::AudioSummary => "audio-summary",
            Projection::Pareto => "pareto",
            Projection::FleetLatency => "fleet-latency",
            Projection::FleetConvergence => "fleet-convergence",
            Projection::FleetBytes => "fleet-bytes",
        }
    }

    pub fn from_name(s: &str) -> Option<Projection> {
        [
            Projection::Cells,
            Projection::PolicyAccuracy,
            Projection::PolicyCoherence,
            Projection::PolicyVsChinchilla,
            Projection::LatencyEmulation,
            Projection::LatencyRealWorld,
            Projection::AccuracyCurve,
            Projection::Perforation,
            Projection::ImgEquivalence,
            Projection::ImgThroughput,
            Projection::ImgLatency,
            Projection::AudioSummary,
            Projection::Pareto,
            Projection::FleetLatency,
            Projection::FleetConvergence,
            Projection::FleetBytes,
        ]
        .into_iter()
        .find(|p| p.name() == s)
    }
}

/// Latency histograms count power cycles into this many unit bins (the
/// paper's figures saturate far below it).
pub const LATENCY_CYCLES: usize = 40;

// ---------------------------------------------------------------------
// The scenario itself.
// ---------------------------------------------------------------------

/// One declarative sweep. Build with [`Scenario::new`] + `with_*`
/// chainers, load from JSON with [`Scenario::parse`], or take a paper
/// figure from [`builtin`].
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// File stem for outputs and the `aic <name>` registry key.
    pub name: String,
    /// Table title.
    pub title: String,
    pub workload: WorkloadSpec,
    pub policies: Vec<Policy>,
    pub harvesters: Vec<HarvesterSpec>,
    pub devices: Vec<DeviceSpec>,
    /// Per-cell seeds: volunteers (HAR) or trace realisations (imaging).
    pub seeds: Vec<u64>,
    /// Campaign horizon, seconds.
    pub horizon: f64,
    /// Seconds between sampling slots.
    pub sample_period: f64,
    pub training: Training,
    pub fast: FastMode,
    pub projection: Projection,
}

impl Scenario {
    /// A scenario with workload-appropriate defaults: HAR defaults to
    /// the kinetic wrist supply on the paper's 4 h horizon, imaging to
    /// the five ambient traces on 2 h.
    pub fn new(name: &str, workload: WorkloadSpec) -> Scenario {
        let (horizon, sample_period, harvesters) = match &workload {
            WorkloadSpec::Har => (4.0 * 3600.0, 60.0, vec![HarvesterSpec::Kinetic]),
            WorkloadSpec::Img | WorkloadSpec::Audio => (
                2.0 * 3600.0,
                30.0,
                TraceKind::ALL.iter().map(|&k| HarvesterSpec::Ambient(k)).collect(),
            ),
            WorkloadSpec::Fleet(_) => (
                3600.0,
                60.0,
                vec![HarvesterSpec::Synth(SynthSpec::builtin_solar())],
            ),
            _ => (0.0, 0.0, Vec::new()),
        };
        Scenario {
            name: name.to_string(),
            title: name.to_string(),
            workload,
            policies: vec![Policy::Greedy],
            harvesters,
            devices: vec![DeviceSpec::default()],
            seeds: vec![1],
            horizon,
            sample_period,
            training: Training::full(42),
            fast: FastMode::none(),
            projection: Projection::Cells,
        }
    }

    pub fn with_title(mut self, title: &str) -> Scenario {
        self.title = title.to_string();
        self
    }

    pub fn with_workload(mut self, workload: WorkloadSpec) -> Scenario {
        self.workload = workload;
        self
    }

    pub fn with_policies(mut self, policies: Vec<Policy>) -> Scenario {
        self.policies = policies;
        self
    }

    pub fn with_harvesters(mut self, harvesters: Vec<HarvesterSpec>) -> Scenario {
        self.harvesters = harvesters;
        self
    }

    pub fn with_devices(mut self, devices: Vec<DeviceSpec>) -> Scenario {
        self.devices = devices;
        self
    }

    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Scenario {
        self.seeds = seeds;
        self
    }

    pub fn with_horizon(mut self, horizon: f64) -> Scenario {
        self.horizon = horizon;
        self
    }

    pub fn with_sample_period(mut self, period: f64) -> Scenario {
        self.sample_period = period;
        self
    }

    pub fn with_training(mut self, training: Training) -> Scenario {
        self.training = training;
        self
    }

    pub fn with_fast(mut self, fast: FastMode) -> Scenario {
        self.fast = fast;
        self
    }

    pub fn with_projection(mut self, projection: Projection) -> Scenario {
        self.projection = projection;
        self
    }

    /// Force the integrator on every device cell (the CLI `--engine`
    /// flag — no `set_var`, no process-global state).
    pub fn with_engine(mut self, kind: EngineKind) -> Scenario {
        for d in &mut self.devices {
            d.engine = Some(kind);
        }
        self
    }

    /// Apply the scenario's own `--fast` scaling.
    pub fn resolve(&self, fast: bool) -> Scenario {
        if !fast {
            return self.clone();
        }
        let mut s = self.clone();
        if let Some(h) = s.fast.horizon {
            s.horizon = h;
        }
        if let Some(n) = s.fast.max_seeds {
            s.seeds.truncate(n.max(1));
        }
        if s.fast.tiny_corpus {
            s.training = Training::tiny();
        }
        let img_size = s.fast.img_size;
        if let WorkloadSpec::Perforation { size, .. } = &mut s.workload {
            if let Some(n) = img_size {
                *size = n;
            }
        }
        s
    }

    /// Number of campaign cells the plan expands to (0 for non-campaign
    /// workloads).
    pub fn campaign_cell_count(&self) -> usize {
        if !self.workload.is_campaign() {
            return 0;
        }
        self.harvesters.len() * self.devices.len() * self.policies.len() * self.seeds.len()
    }

    /// The campaign cell at plan index `idx` — the inverse of the plan's
    /// harvesters ▸ devices ▸ policies ▸ seeds nesting, computed without
    /// materialising the grid.
    pub fn cell_at(&self, idx: usize) -> CampaignCell {
        let (s_n, p_n, d_n) = (self.seeds.len(), self.policies.len(), self.devices.len());
        let s = idx % s_n;
        let p = (idx / s_n) % p_n;
        let d = (idx / (s_n * p_n)) % d_n;
        let h = idx / (s_n * p_n * d_n);
        CampaignCell {
            harvester: self.harvesters[h].clone(),
            device: self.devices[d],
            policy: self.policies[p],
            seed: self.seeds[s],
        }
    }

    /// Lazy plan-order cell iterator — what the streaming sweep chunks
    /// over. `plan()` is this iterator collected.
    pub fn cells(&self) -> impl Iterator<Item = CampaignCell> + '_ {
        (0..self.campaign_cell_count()).map(|i| self.cell_at(i))
    }

    /// Expand into the deterministic job plan: the exact cells, in the
    /// exact order, the fleet will run (harvesters ▸ devices ▸ policies
    /// ▸ seeds). A pure function of the spec.
    pub fn plan(&self) -> JobPlan {
        match &self.workload {
            WorkloadSpec::Har
            | WorkloadSpec::Img
            | WorkloadSpec::Audio
            | WorkloadSpec::Fleet(_) => JobPlan::Campaigns(self.cells().collect()),
            WorkloadSpec::AccuracyCurve { ps } => JobPlan::Accuracy(ps.clone()),
            WorkloadSpec::Perforation { skips, .. } => JobPlan::Perforation(
                Picture::ALL
                    .iter()
                    .flat_map(|&pic| skips.iter().map(move |&s| (pic, s)))
                    .collect(),
            ),
        }
    }

    /// Train the HAR context this scenario's (unresolved) training spec
    /// describes — callers that run several HAR scenarios share one.
    pub fn har_context(&self) -> HarContext {
        self.training.context()
    }

    /// Run the sweep: resolve `--fast`, expand the plan, dispatch every
    /// cell on the bounded fleet pool, and wrap the job-ordered grid.
    pub fn run(&self, fast: bool) -> SweepRun {
        self.run_with(fast, None, None)
    }

    /// [`run`](Scenario::run) with a pre-trained HAR context (must come
    /// from a [`Training`] equal to this scenario's resolved one — this
    /// is how `aic all` trains once for figs. 4–9) and/or an explicit
    /// fleet worker cap (determinism tests).
    pub fn run_with(
        &self,
        fast: bool,
        ctx: Option<&HarContext>,
        workers: Option<usize>,
    ) -> SweepRun {
        // One supply cache per sweep: every grid cell resolving to the
        // same (harvester, seed, booster) shares one materialised supply
        // and one analytic stepping table. `AIC_SUPPLY_CACHE=off` keeps
        // the uncached path reachable for A/B timing and bisection.
        self.run_cached(fast, ctx, workers, &SupplyCache::from_env())
    }

    /// [`run_with`](Scenario::run_with) with an explicit [`SupplyCache`]
    /// — the programmatic cache-mode entry point (tests and benches must
    /// not steer sharing through the process environment).
    pub fn run_cached(
        &self,
        fast: bool,
        ctx: Option<&HarContext>,
        workers: Option<usize>,
        cache: &SupplyCache,
    ) -> SweepRun {
        let s = self.resolve(fast);
        let plan = s.plan();
        let grid = match (&s.workload, &plan) {
            (WorkloadSpec::Har, JobPlan::Campaigns(cells)) => {
                let owned = if ctx.is_none() { Some(s.training.context()) } else { None };
                let ctx = match ctx {
                    Some(c) => c,
                    None => owned.as_ref().unwrap(),
                };
                GridData::Har(run_fleet(cells, workers, |cell| {
                    let spec = HarRunSpec {
                        horizon: s.horizon,
                        sample_period: s.sample_period,
                        script_seed: cell.seed,
                    };
                    let workload =
                        HarWorkload { ctx, spec, harvester: cell.harvester.clone() };
                    run_campaign_cached(&workload, cell.seed, cell.policy, &cell.device, cache)
                }))
            }
            (WorkloadSpec::Img, JobPlan::Campaigns(cells)) => {
                GridData::Img(run_fleet(cells, workers, |cell| {
                    let spec = ImgRunSpec {
                        horizon: s.horizon,
                        sample_period: s.sample_period,
                        trace_seed: cell.seed,
                    };
                    let workload = ImgWorkload { spec, harvester: cell.harvester.clone() };
                    run_campaign_cached(&workload, cell.seed, cell.policy, &cell.device, cache)
                }))
            }
            (WorkloadSpec::Audio, JobPlan::Campaigns(cells)) => {
                GridData::Audio(run_fleet(cells, workers, |cell| {
                    let spec = AudioRunSpec {
                        horizon: s.horizon,
                        sample_period: s.sample_period,
                        stream_seed: cell.seed,
                    };
                    let workload = AudioWorkload { spec, harvester: cell.harvester.clone() };
                    run_campaign_cached(&workload, cell.seed, cell.policy, &cell.device, cache)
                }))
            }
            (WorkloadSpec::Fleet(fs), JobPlan::Campaigns(cells)) => {
                GridData::Fleet(run_fleet(cells, workers, |cell| {
                    fleet_cell_digest(fs, cell, s.horizon)
                }))
            }
            (WorkloadSpec::AccuracyCurve { ps }, _) => {
                let owned = if ctx.is_none() { Some(s.training.context()) } else { None };
                let ctx = match ctx {
                    Some(c) => c,
                    None => owned.as_ref().unwrap(),
                };
                GridData::Accuracy(accuracy_rows(ctx, ps))
            }
            (WorkloadSpec::Perforation { size, skips }, _) => {
                GridData::Perforation(perforation_rows(*size, skips))
            }
            _ => unreachable!("plan kind always matches the workload kind"),
        };
        SweepRun { scenario: s, grid }
    }

    // -----------------------------------------------------------------
    // JSON.
    // -----------------------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("title", self.title.as_str().into()),
            ("workload", self.workload.to_json()),
            (
                "policies",
                Value::Arr(self.policies.iter().map(|p| p.name().into()).collect()),
            ),
            (
                "harvesters",
                Value::Arr(self.harvesters.iter().map(|h| h.to_json()).collect()),
            ),
            ("devices", Value::Arr(self.devices.iter().map(|d| d.to_json()).collect())),
            (
                "seeds",
                Value::Arr(self.seeds.iter().map(|&s| Value::Num(s as f64)).collect()),
            ),
            ("horizon", self.horizon.into()),
            ("sample_period", self.sample_period.into()),
            ("training", self.training.to_json()),
            ("fast", self.fast.to_json()),
            ("projection", self.projection.name().into()),
        ])
    }

    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Parse a scenario document (the `aic sweep --scenario` format).
    pub fn parse(text: &str) -> Result<Scenario, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Scenario::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Scenario, String> {
        const KEYS: [&str; 12] = [
            "name",
            "title",
            "workload",
            "policies",
            "harvesters",
            "devices",
            "seeds",
            "horizon",
            "sample_period",
            "training",
            "fast",
            "projection",
        ];
        let obj = v.as_obj().ok_or("scenario must be a JSON object")?;
        for key in obj.keys() {
            if !KEYS.contains(&key.as_str()) {
                return Err(format!("unknown scenario key '{key}'"));
            }
        }
        let name = v.get("name").as_str().ok_or("scenario needs a string 'name'")?;
        let workload = WorkloadSpec::from_json(v.get("workload"))?;
        let mut s = Scenario::new(name, workload);
        if let Some(t) = opt_str(v, "title")? {
            s.title = t.to_string();
        }
        if let Some(items) = opt_arr(v, "policies")? {
            s.policies = items
                .iter()
                .map(|p| {
                    p.as_str()
                        .ok_or_else(|| "'policies' entries must be strings".to_string())?
                        .parse::<Policy>()
                })
                .collect::<Result<Vec<Policy>, String>>()?;
        }
        if let Some(items) = opt_arr(v, "harvesters")? {
            s.harvesters = items
                .iter()
                .map(HarvesterSpec::from_json)
                .collect::<Result<Vec<HarvesterSpec>, String>>()?;
        }
        if let Some(items) = opt_arr(v, "devices")? {
            s.devices = items
                .iter()
                .map(DeviceSpec::from_json)
                .collect::<Result<Vec<DeviceSpec>, String>>()?;
        }
        if let Some(items) = opt_arr(v, "seeds")? {
            s.seeds = items
                .iter()
                .map(|x| x.as_u64().ok_or_else(|| "'seeds' entries must be unsigned integers".to_string()))
                .collect::<Result<Vec<u64>, String>>()?;
        }
        if let Some(h) = opt_f64(v, "horizon")? {
            s.horizon = h;
        }
        if let Some(p) = opt_f64(v, "sample_period")? {
            s.sample_period = p;
        }
        if !matches!(v.get("training"), Value::Null) {
            s.training = Training::from_json(v.get("training"), s.training.clone())?;
        }
        if !matches!(v.get("fast"), Value::Null) {
            s.fast = FastMode::from_json(v.get("fast"))?;
        }
        if let Some(p) = opt_str(v, "projection")? {
            s.projection =
                Projection::from_name(p).ok_or_else(|| format!("unknown projection '{p}'"))?;
        }
        s.validate()?;
        Ok(s)
    }

    /// Structural validation (campaign grids must be non-empty and the
    /// projection must fit the workload).
    pub fn validate(&self) -> Result<(), String> {
        use Projection::*;
        if self.workload.is_campaign() {
            if self.policies.is_empty() {
                return Err("scenario has no policies".to_string());
            }
            if self.harvesters.is_empty() {
                return Err("scenario has no harvesters".to_string());
            }
            if self.devices.is_empty() {
                return Err("scenario has no devices".to_string());
            }
            if self.seeds.is_empty() {
                return Err("scenario has no seeds".to_string());
            }
            if self.horizon <= 0.0 {
                return Err("campaign horizon must be positive".to_string());
            }
            if self.sample_period <= 0.0 {
                return Err("sample_period must be positive".to_string());
            }
            // Synth environments: a structurally broken spec must fail
            // here (parse/validate time), never inside a fleet worker.
            for (i, h) in self.harvesters.iter().enumerate() {
                if let HarvesterSpec::Synth(spec) = h {
                    spec.validate().map_err(|e| format!("harvester {i}: {e}"))?;
                }
            }
            // Device physics: catch impossible knob combinations here,
            // not as a Capacitor::new assert inside a fleet worker.
            let base = Capacitor::paper_default();
            for (i, d) in self.devices.iter().enumerate() {
                let c = d.capacitance.unwrap_or(base.capacitance);
                let v_on = d.v_on.unwrap_or(base.v_on);
                let v_off = d.v_off.unwrap_or(base.v_off);
                if c <= 0.0 {
                    return Err(format!("device {i}: capacitance must be positive"));
                }
                if v_off <= 0.0 || v_on <= v_off || v_on > base.v_max {
                    return Err(format!(
                        "device {i}: thresholds must satisfy 0 < v_off < v_on <= {} \
                         (got v_on={v_on}, v_off={v_off})",
                        base.v_max
                    ));
                }
            }
            if let WorkloadSpec::Fleet(fs) = &self.workload {
                // Execution policies are per-device knobs; the fleet axis
                // multiplies devices, not policies.
                if self.policies.len() != 1 {
                    return Err(format!(
                        "fleet scenarios take exactly one policy, got {}",
                        self.policies.len()
                    ));
                }
                fs.validate()?;
                fs.validate_with_horizon(self.horizon)?;
            }
        }
        let ok = match &self.workload {
            WorkloadSpec::Har => matches!(
                self.projection,
                Cells
                    | PolicyAccuracy
                    | PolicyCoherence
                    | PolicyVsChinchilla
                    | LatencyEmulation
                    | LatencyRealWorld
                    | Pareto
            ),
            WorkloadSpec::Img => {
                matches!(
                    self.projection,
                    Cells | ImgEquivalence | ImgThroughput | ImgLatency | Pareto
                )
            }
            WorkloadSpec::Audio => matches!(self.projection, Cells | AudioSummary | Pareto),
            WorkloadSpec::AccuracyCurve { .. } => {
                matches!(self.projection, Cells | AccuracyCurve)
            }
            WorkloadSpec::Perforation { .. } => matches!(self.projection, Cells | Perforation),
            WorkloadSpec::Fleet(..) => matches!(
                self.projection,
                Cells | FleetLatency | FleetConvergence | FleetBytes
            ),
        };
        if !ok {
            return Err(format!(
                "projection '{}' does not fit this workload",
                self.projection.name()
            ));
        }
        Ok(())
    }
}

/// One campaign cell of the grid.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignCell {
    pub harvester: HarvesterSpec,
    pub device: DeviceSpec,
    pub policy: Policy,
    pub seed: u64,
}

/// The deterministic expansion of a scenario: what the fleet runs.
#[derive(Clone, Debug, PartialEq)]
pub enum JobPlan {
    Campaigns(Vec<CampaignCell>),
    Accuracy(Vec<usize>),
    Perforation(Vec<(Picture, f64)>),
}

impl JobPlan {
    pub fn len(&self) -> usize {
        match self {
            JobPlan::Campaigns(c) => c.len(),
            JobPlan::Accuracy(p) => p.len(),
            JobPlan::Perforation(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Run one fleet cell and digest it. The cell's harvester spec is the
/// *family*: each of the N devices builds its own correlated-but-distinct
/// member via [`sync::device_seed`] substreams, so a `fleet_solar` fleet
/// shares the weather but not the exact clouds. Shared by the batch grid
/// ([`Scenario::run_cached`]) and the streaming sweep — one code path is
/// what makes their outputs bitwise-identical.
pub fn fleet_cell_digest(fs: &FleetSpec, cell: &CampaignCell, horizon: f64) -> CellDigest {
    let supplies: Vec<Harvester> = (0..fs.devices)
        .map(|d| cell.harvester.build(horizon, sync::device_seed(cell.seed, d)))
        .collect();
    let f = sync::run_fleet_cell(fs, &supplies, horizon, cell.seed);
    CellDigest::of_fleet(&f, horizon)
}

// (The typed optional JSON accessors live in `util::json` — shared with
// the synth-spec reader.)

// ---------------------------------------------------------------------
// Grid results and projections.
// ---------------------------------------------------------------------

/// Fig. 4 row — expected vs measured accuracy for one prefix length.
#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub p: usize,
    pub expected: f64,
    pub measured: f64,
}

/// Fig. 12 row — corner output at one (picture, perforation) cell.
#[derive(Clone, Debug)]
pub struct Fig12Row {
    pub picture: Picture,
    pub skip_fraction: f64,
    pub corners: usize,
    pub reference_corners: usize,
    pub equivalent: bool,
}

/// Figs. 5/7/8 row — one policy summarised over every (harvester,
/// device, seed) unit of the grid. Columns against a reference policy
/// (continuous / Chinchilla / GREEDY) are 0 when the grid omits it.
#[derive(Clone, Debug)]
pub struct PolicyRow {
    pub policy: Policy,
    pub accuracy: f64,
    pub coherence_vs_continuous: f64,
    pub coherence_vs_chinchilla: f64,
    pub throughput_vs_continuous: f64,
    pub throughput_vs_greedy: f64,
    pub throughput_vs_chinchilla: f64,
    pub same_cycle_fraction: f64,
    pub mean_features: f64,
    pub state_energy_fraction: f64,
}

/// Figs. 13–15 row — one harvester (energy trace) summarised: AIC vs
/// Chinchilla, normalised to continuous.
#[derive(Clone, Debug)]
pub struct ImgTraceRow {
    pub harvester: HarvesterSpec,
    pub equivalence_aic: f64,
    pub throughput_aic_vs_continuous: f64,
    pub throughput_chinchilla_vs_continuous: f64,
    pub aic_same_cycle: f64,
    pub chinchilla_latency_mean: f64,
}

/// Audio summary row — one policy summarised over every (harvester,
/// device, seed) unit: detection accuracy, throughput against the
/// continuous ceiling (0 when the grid omits it), refinement depth and
/// delivery latency.
#[derive(Clone, Debug)]
pub struct AudioPolicyRow {
    pub policy: Policy,
    pub accuracy: f64,
    pub throughput_vs_continuous: f64,
    pub mean_probes: f64,
    pub same_cycle_fraction: f64,
    pub mean_latency_cycles: f64,
}

/// Pareto row — one policy's pooled accuracy/throughput point plus the
/// frontier and auto-selection judgement. The Continuous ceiling is
/// shown but excluded from the frontier: a battery is not a harvesting
/// policy, it is the normalisation bound every figure plots against.
#[derive(Clone, Debug)]
pub struct ParetoRow {
    pub policy: Policy,
    /// Pooled quality over every unit (correct / total emitted-with-output).
    pub accuracy: f64,
    /// Pooled throughput: emitted results per second of campaign time.
    pub throughput: f64,
    /// Pooled joules per delivered result (app + state energy).
    pub energy_per_result: f64,
    /// False for the Continuous ceiling.
    pub harvesting: bool,
    /// Non-dominated on (accuracy, throughput) among harvesting policies.
    pub frontier: bool,
    /// Approxify-style auto-selection: the harvesting policy with the
    /// best accuracy × throughput product (ties → earlier policy axis).
    pub pick: bool,
}

/// Per-policy pooled sums behind a [`ParetoRow`] — integer counts plus
/// f64 folds in plan order, so the batch path and the streaming
/// accumulator produce bitwise-identical rows by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ParetoPool {
    pub quality_ok: u64,
    pub quality_total: u64,
    pub emitted: u64,
    pub duration: f64,
    pub app_energy: f64,
    pub state_energy: f64,
}

impl ParetoPool {
    /// Fold one cell's digest into the pool (both the batch projection
    /// and the streaming accumulator call exactly this).
    pub fn fold(&mut self, d: &CellDigest) {
        self.quality_ok += d.quality_ok;
        self.quality_total += d.quality_total;
        self.emitted += d.emitted;
        self.duration += d.duration;
        self.app_energy += d.app_energy;
        self.state_energy += d.state_energy;
    }
}

/// Judge pooled per-policy points: frontier membership (strict Pareto
/// dominance among harvesting policies) and the auto-selection pick.
pub fn pareto_rows_from_pools(policies: &[Policy], pools: &[ParetoPool]) -> Vec<ParetoRow> {
    assert_eq!(policies.len(), pools.len());
    let point = |p: &ParetoPool| {
        let acc = if p.quality_total == 0 { 0.0 } else { p.quality_ok as f64 / p.quality_total as f64 };
        let thr = if p.duration == 0.0 { 0.0 } else { p.emitted as f64 / p.duration };
        (acc, thr)
    };
    let harvesting: Vec<bool> =
        policies.iter().map(|p| !matches!(p, Policy::Continuous)).collect();
    let points: Vec<(f64, f64)> = pools.iter().map(point).collect();
    // The pick maximises accuracy × throughput among harvesting policies.
    let pick = policies
        .iter()
        .enumerate()
        .filter(|&(i, _)| harvesting[i])
        .map(|(i, _)| (i, points[i].0 * points[i].1))
        .fold(None::<(usize, f64)>, |best, (i, score)| match best {
            Some((_, s)) if s >= score => best,
            _ => Some((i, score)),
        })
        .map(|(i, _)| i);
    policies
        .iter()
        .enumerate()
        .map(|(i, &policy)| {
            let (accuracy, throughput) = points[i];
            let dominated = harvesting[i]
                && points.iter().enumerate().any(|(j, &(a, t))| {
                    j != i
                        && harvesting[j]
                        && a >= accuracy
                        && t >= throughput
                        && (a > accuracy || t > throughput)
                });
            ParetoRow {
                policy,
                accuracy,
                throughput,
                energy_per_result: if pools[i].emitted == 0 {
                    0.0
                } else {
                    (pools[i].app_energy + pools[i].state_energy) / pools[i].emitted as f64
                },
                harvesting: harvesting[i],
                frontier: harvesting[i] && !dominated,
                pick: pick == Some(i),
            }
        })
        .collect()
}

/// The campaigns (or analysis rows) a sweep produced, in plan order.
pub enum GridData {
    Har(Vec<Campaign<HarOutput>>),
    Img(Vec<Campaign<CornerOutput>>),
    Audio(Vec<Campaign<AudioOutput>>),
    Accuracy(Vec<Fig4Row>),
    Perforation(Vec<Fig12Row>),
    /// Fleet cells digest in the worker (N replicas are dropped there),
    /// so the batch grid holds exactly what the stream accumulators and
    /// the store hold — bitwise agreement by construction.
    Fleet(Vec<CellDigest>),
}

/// A completed sweep: the resolved scenario plus its grid, with the
/// derived-metric projections as methods.
pub struct SweepRun {
    pub scenario: Scenario,
    pub grid: GridData,
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    crate::util::stats::mean(&v)
}

impl SweepRun {
    pub fn har_campaigns(&self) -> &[Campaign<HarOutput>] {
        match &self.grid {
            GridData::Har(c) => c,
            _ => panic!("scenario '{}' did not produce a HAR grid", self.scenario.name),
        }
    }

    pub fn img_campaigns(&self) -> &[Campaign<CornerOutput>] {
        match &self.grid {
            GridData::Img(c) => c,
            _ => panic!("scenario '{}' did not produce an imaging grid", self.scenario.name),
        }
    }

    pub fn audio_campaigns(&self) -> &[Campaign<AudioOutput>] {
        match &self.grid {
            GridData::Audio(c) => c,
            _ => panic!("scenario '{}' did not produce an audio grid", self.scenario.name),
        }
    }

    pub fn accuracy_rows(&self) -> &[Fig4Row] {
        match &self.grid {
            GridData::Accuracy(r) => r,
            _ => panic!("scenario '{}' did not produce an accuracy curve", self.scenario.name),
        }
    }

    pub fn perforation_rows(&self) -> &[Fig12Row] {
        match &self.grid {
            GridData::Perforation(r) => r,
            _ => panic!("scenario '{}' did not produce a perforation sweep", self.scenario.name),
        }
    }

    pub fn fleet_digests(&self) -> &[CellDigest] {
        match &self.grid {
            GridData::Fleet(d) => d,
            _ => panic!("scenario '{}' did not produce a fleet grid", self.scenario.name),
        }
    }

    /// Grid index of the cell (harvester, device, policy, seed) — the
    /// plan order.
    pub fn cell_index(&self, h: usize, d: usize, p: usize, s: usize) -> usize {
        let sc = &self.scenario;
        ((h * sc.devices.len() + d) * sc.policies.len() + p) * sc.seeds.len() + s
    }

    /// Position of `policy` in the scenario's policy axis.
    pub fn policy_index(&self, policy: Policy) -> Option<usize> {
        self.scenario.policies.iter().position(|&q| q == policy)
    }

    /// Number of (harvester, device, seed) units per policy.
    fn unit_count(&self) -> usize {
        let sc = &self.scenario;
        sc.harvesters.len() * sc.devices.len() * sc.seeds.len()
    }

    /// Grid index of policy `p` on unit `u` (units iterate harvesters ▸
    /// devices ▸ seeds, matching plan order).
    fn campaign_of(&self, p: usize, u: usize) -> usize {
        let sc = &self.scenario;
        let (d_n, s_n) = (sc.devices.len(), sc.seeds.len());
        let h = u / (d_n * s_n);
        let d = (u / s_n) % d_n;
        let s = u % s_n;
        self.cell_index(h, d, p, s)
    }

    /// Figs. 5/7/8 — per-policy summary over every unit; references
    /// (continuous / Chinchilla / GREEDY) align pairwise on the unit.
    pub fn policy_rows(&self) -> Vec<PolicyRow> {
        let sc = &self.scenario;
        let campaigns = self.har_campaigns();
        let units = self.unit_count();
        let period = sc.sample_period;
        let pos = |q: Policy| sc.policies.iter().position(|&x| x == q);
        let cont = pos(Policy::Continuous);
        let chin = pos(Policy::Chinchilla);
        let greedy = pos(Policy::Greedy);
        let at = |p: usize, u: usize| &campaigns[self.campaign_of(p, u)];
        // Monomorphic view of the generic ratio for the &dyn projections.
        fn thr(a: &Campaign<HarOutput>, b: &Campaign<HarOutput>) -> f64 {
            metrics::throughput_ratio(a, b)
        }
        sc.policies
            .iter()
            .enumerate()
            .map(|(i, &policy)| {
                let per_unit = |f: &dyn Fn(usize) -> f64| mean((0..units).map(f));
                let vs = |r: Option<usize>,
                          f: &dyn Fn(&Campaign<HarOutput>, &Campaign<HarOutput>) -> f64|
                 -> f64 {
                    match r {
                        Some(r) => per_unit(&|u| f(at(i, u), at(r, u))),
                        None => 0.0,
                    }
                };
                PolicyRow {
                    policy,
                    accuracy: per_unit(&|u| metrics::har_accuracy(at(i, u))),
                    coherence_vs_continuous: vs(cont, &|a, b| {
                        metrics::har_coherence(a, b, period)
                    }),
                    coherence_vs_chinchilla: vs(chin, &|a, b| {
                        metrics::har_coherence(a, b, period)
                    }),
                    throughput_vs_continuous: vs(cont, &thr),
                    throughput_vs_greedy: vs(greedy, &thr),
                    throughput_vs_chinchilla: vs(chin, &thr),
                    same_cycle_fraction: per_unit(&|u| {
                        metrics::same_cycle_fraction(at(i, u))
                    }),
                    mean_features: per_unit(&|u| {
                        mean(at(i, u).emitted().map(|r| r.steps_executed as f64))
                    }),
                    state_energy_fraction: per_unit(&|u| {
                        let c = at(i, u);
                        let total = c.app_energy + c.state_energy;
                        if total == 0.0 {
                            0.0
                        } else {
                            c.state_energy / total
                        }
                    }),
                }
            })
            .collect()
    }

    /// Audio — per-policy detection accuracy/latency summary over every
    /// unit; throughput aligns pairwise on the unit against continuous.
    pub fn audio_policy_rows(&self) -> Vec<AudioPolicyRow> {
        let sc = &self.scenario;
        let campaigns = self.audio_campaigns();
        let units = self.unit_count();
        let cont = self.policy_index(Policy::Continuous);
        let at = |p: usize, u: usize| &campaigns[self.campaign_of(p, u)];
        sc.policies
            .iter()
            .enumerate()
            .map(|(i, &policy)| {
                let per_unit = |f: &dyn Fn(usize) -> f64| mean((0..units).map(f));
                AudioPolicyRow {
                    policy,
                    accuracy: per_unit(&|u| metrics::audio_accuracy(at(i, u))),
                    throughput_vs_continuous: match cont {
                        Some(c) => {
                            per_unit(&|u| metrics::throughput_ratio(at(i, u), at(c, u)))
                        }
                        None => 0.0,
                    },
                    mean_probes: per_unit(&|u| {
                        mean(at(i, u).emitted().map(|r| r.steps_executed as f64))
                    }),
                    same_cycle_fraction: per_unit(&|u| {
                        metrics::same_cycle_fraction(at(i, u))
                    }),
                    mean_latency_cycles: per_unit(&|u| {
                        mean(at(i, u).emitted().map(|r| r.latency_cycles as f64))
                    }),
                }
            })
            .collect()
    }

    /// Pareto — one pooled accuracy/throughput point per policy, with
    /// frontier membership and the Approxify-style pick. Works on any
    /// campaign grid; pooling goes through the same [`CellDigest`] fold
    /// the streaming accumulator uses, in the same per-policy cell
    /// order, so the two paths agree bitwise.
    pub fn pareto_rows(&self) -> Vec<ParetoRow> {
        let sc = &self.scenario;
        let units = self.unit_count();
        let needs = Needs::none();
        let mut pools = vec![ParetoPool::default(); sc.policies.len()];
        for (i, pool) in pools.iter_mut().enumerate() {
            for u in 0..units {
                let idx = self.campaign_of(i, u);
                let d = match &self.grid {
                    GridData::Har(cs) => CellDigest::of_har(&cs[idx], sc.sample_period, needs),
                    GridData::Img(cs) => CellDigest::of_img(&cs[idx], needs),
                    GridData::Audio(cs) => CellDigest::of_audio(&cs[idx], needs),
                    _ => panic!(
                        "scenario '{}' did not produce a campaign grid",
                        self.scenario.name
                    ),
                };
                pool.fold(&d);
            }
        }
        pareto_rows_from_pools(&sc.policies, &pools)
    }

    /// Figs. 6/9 — per-policy latency histogram pooled over every unit.
    pub fn latency_histograms(&self, max_cycles: usize) -> Vec<(Policy, Histogram)> {
        let campaigns = self.har_campaigns();
        let units = self.unit_count();
        self.scenario
            .policies
            .iter()
            .enumerate()
            .map(|(i, &policy)| {
                let mut h = Histogram::new(0.0, max_cycles as f64, max_cycles);
                for u in 0..units {
                    for r in campaigns[self.campaign_of(i, u)].emitted() {
                        h.add(r.latency_cycles as f64);
                    }
                }
                (policy, h)
            })
            .collect()
    }

    /// Figs. 13–15 — one row per harvester, averaged over (device, seed)
    /// units within it.
    pub fn img_trace_rows(&self) -> Vec<ImgTraceRow> {
        let sc = &self.scenario;
        let campaigns = self.img_campaigns();
        let size = EVAL_SIZE;
        let cont = self.policy_index(Policy::Continuous);
        let chin = self.policy_index(Policy::Chinchilla);
        let greedy = self.policy_index(Policy::Greedy);
        let (d_n, p_n, s_n) = (sc.devices.len(), sc.policies.len(), sc.seeds.len());
        sc.harvesters
            .iter()
            .enumerate()
            .map(|(hi, harvester)| {
                let harvester = harvester.clone();
                let local_units = d_n * s_n;
                let at = |p: usize, lu: usize| {
                    let d = lu / s_n;
                    let s = lu % s_n;
                    &campaigns[((hi * d_n + d) * p_n + p) * s_n + s]
                };
                let per = |f: &dyn Fn(usize) -> f64| mean((0..local_units).map(f));
                let ratio_of = |a: Option<usize>, b: Option<usize>| match (a, b) {
                    (Some(a), Some(b)) => {
                        per(&|u| metrics::throughput_ratio(at(a, u), at(b, u)))
                    }
                    _ => 0.0,
                };
                ImgTraceRow {
                    harvester,
                    equivalence_aic: greedy
                        .map(|g| per(&|u| metrics::corner_equivalence_fraction(at(g, u), size)))
                        .unwrap_or(0.0),
                    throughput_aic_vs_continuous: ratio_of(greedy, cont),
                    throughput_chinchilla_vs_continuous: ratio_of(chin, cont),
                    aic_same_cycle: greedy
                        .map(|g| per(&|u| metrics::same_cycle_fraction(at(g, u))))
                        .unwrap_or(0.0),
                    chinchilla_latency_mean: chin
                        .map(|c| {
                            per(&|u| mean(at(c, u).emitted().map(|r| r.latency_cycles as f64)))
                        })
                        .unwrap_or(0.0),
                }
            })
            .collect()
    }

    /// Fig. 13 proper — per-picture equivalence pooled over every GREEDY
    /// campaign in the grid (the paper pools across all five traces).
    pub fn equivalence_by_picture(&self) -> Vec<(Picture, f64)> {
        let campaigns = self.img_campaigns();
        let Some(g) = self.policy_index(Policy::Greedy) else {
            return Picture::ALL.iter().map(|&p| (p, 0.0)).collect();
        };
        let refs: Vec<&Campaign<CornerOutput>> =
            (0..self.unit_count()).map(|u| &campaigns[self.campaign_of(g, u)]).collect();
        metrics::corner_equivalence_by_picture(&refs, EVAL_SIZE)
    }

    /// Render the scenario's projection: the tables a sink consumes.
    pub fn tables(&self) -> Vec<TableData> {
        let sc = &self.scenario;
        let name = sc.name.as_str();
        let title = sc.title.as_str();
        match sc.projection {
            Projection::AccuracyCurve => vec![self.accuracy_table(name, title)],
            Projection::Perforation => vec![self.perforation_table(name, title)],
            Projection::PolicyAccuracy => {
                vec![policy_accuracy_table(name, title, &self.policy_rows())]
            }
            Projection::PolicyCoherence => {
                vec![policy_coherence_table(name, title, &self.policy_rows())]
            }
            Projection::PolicyVsChinchilla => {
                vec![policy_vs_chinchilla_table(name, title, &self.policy_rows())]
            }
            Projection::LatencyEmulation => {
                vec![latency_emulation_table(
                    name,
                    title,
                    &self.latency_histograms(LATENCY_CYCLES),
                )]
            }
            Projection::LatencyRealWorld => {
                vec![latency_real_world_table(
                    name,
                    title,
                    &self.latency_histograms(LATENCY_CYCLES),
                )]
            }
            Projection::ImgEquivalence => img_equivalence_tables(
                name,
                title,
                &self.equivalence_by_picture(),
                &self.img_trace_rows(),
            ),
            Projection::ImgThroughput => {
                vec![img_throughput_table(name, title, &self.img_trace_rows())]
            }
            Projection::ImgLatency => {
                vec![img_latency_table(name, title, &self.img_trace_rows())]
            }
            Projection::AudioSummary => {
                vec![audio_summary_table(name, title, &self.audio_policy_rows())]
            }
            Projection::Pareto => vec![pareto_table(name, title, &self.pareto_rows())],
            Projection::FleetLatency
            | Projection::FleetConvergence
            | Projection::FleetBytes => vec![self.fleet_table(name, title)],
            Projection::Cells => match &self.grid {
                GridData::Accuracy(_) => vec![self.accuracy_table(name, title)],
                GridData::Perforation(_) => vec![self.perforation_table(name, title)],
                GridData::Har(_) | GridData::Img(_) | GridData::Audio(_)
                | GridData::Fleet(_) => {
                    vec![self.cells_table(name, title)]
                }
            },
        }
    }

    fn accuracy_table(&self, name: &str, title: &str) -> TableData {
        let mut t = TableData::new(name, title, &["features", "expected", "measured"]);
        for r in self.accuracy_rows() {
            t.push(vec![r.p.to_string(), pct(r.expected), pct(r.measured)]);
        }
        t
    }

    fn perforation_table(&self, name: &str, title: &str) -> TableData {
        let mut t = TableData::new(
            name,
            title,
            &["picture", "skipped", "corners", "reference", "equivalent"],
        );
        for r in self.perforation_rows() {
            t.push(vec![
                r.picture.name().to_string(),
                pct(r.skip_fraction),
                r.corners.to_string(),
                r.reference_corners.to_string(),
                r.equivalent.to_string(),
            ]);
        }
        t
    }

    /// The generic sweep view: one row per grid cell, standard metrics.
    /// "quality" is classification accuracy for HAR cells and the §6.3
    /// corner-equivalence fraction for imaging cells.
    fn cells_table(&self, name: &str, title: &str) -> TableData {
        let mut t = TableData::new(name, title, &CELLS_HEADER);
        let JobPlan::Campaigns(cells) = self.scenario.plan() else {
            unreachable!("cells_table is only called on campaign grids");
        };
        let mut push =
            |cell: &CampaignCell, emitted: usize, cycles: u64, failures: u64, quality: f64,
             same_cycle: f64, app: f64, state: f64| {
                t.push(cells_row(
                    cell,
                    emitted as u64,
                    cycles,
                    failures,
                    quality,
                    same_cycle,
                    app,
                    state,
                ));
            };
        match &self.grid {
            GridData::Har(campaigns) => {
                for (cell, c) in cells.iter().zip(campaigns) {
                    push(
                        cell,
                        c.emitted().count(),
                        c.power_cycles,
                        c.power_failures,
                        metrics::har_accuracy(c),
                        metrics::same_cycle_fraction(c),
                        c.app_energy,
                        c.state_energy,
                    );
                }
            }
            GridData::Img(campaigns) => {
                for (cell, c) in cells.iter().zip(campaigns) {
                    push(
                        cell,
                        c.emitted().count(),
                        c.power_cycles,
                        c.power_failures,
                        metrics::corner_equivalence_fraction(c, EVAL_SIZE),
                        metrics::same_cycle_fraction(c),
                        c.app_energy,
                        c.state_energy,
                    );
                }
            }
            GridData::Audio(campaigns) => {
                for (cell, c) in cells.iter().zip(campaigns) {
                    push(
                        cell,
                        c.emitted().count(),
                        c.power_cycles,
                        c.power_failures,
                        metrics::audio_accuracy(c),
                        metrics::same_cycle_fraction(c),
                        c.app_energy,
                        c.state_energy,
                    );
                }
            }
            GridData::Fleet(digests) => {
                for (cell, d) in cells.iter().zip(digests) {
                    push(
                        cell,
                        d.emitted as usize,
                        d.power_cycles,
                        d.power_failures,
                        d.quality(),
                        d.same_cycle_fraction(),
                        d.app_energy,
                        d.state_energy,
                    );
                }
            }
            _ => unreachable!("cells_table is only called on campaign grids"),
        }
        t
    }

    /// The fleet projections: one row per grid cell, rendered by the
    /// shared [`fleet_header`]/[`fleet_row`] pair (the streaming
    /// accumulator calls exactly the same functions).
    fn fleet_table(&self, name: &str, title: &str) -> TableData {
        let p = self.scenario.projection;
        let mut t = TableData::new(name, title, fleet_header(p));
        let JobPlan::Campaigns(cells) = self.scenario.plan() else {
            unreachable!("fleet_table is only called on fleet grids");
        };
        for (cell, d) in cells.iter().zip(self.fleet_digests()) {
            let f = d.fleet.as_ref().expect("fleet digests carry the fleet payload");
            t.push(fleet_row(p, cell, f));
        }
        t
    }
}

// ---------------------------------------------------------------------
// Shared table renderers.
//
// Each projection's table layout lives in exactly one function, called
// by both the batch path (`SweepRun::tables`, via the row structs) and
// the streaming accumulators (`coordinator::stream`, via incrementally
// folded digests). Rendered bytes are therefore identical by
// construction — the incremental-vs-batch bitwise guarantee only has to
// cover the *numbers*, never the formatting.
// ---------------------------------------------------------------------

/// Header of the generic per-cell sweep view (`Projection::Cells` and
/// `aic store table`).
pub const CELLS_HEADER: [&str; 11] = [
    "harvester", "device", "policy", "seed", "emitted", "cycles", "failures",
    "quality", "same cycle", "app mJ", "state mJ",
];

/// One row of the generic sweep view. "quality" is classification
/// accuracy for HAR/audio cells and the §6.3 corner-equivalence fraction
/// for imaging cells.
pub fn cells_row(
    cell: &CampaignCell,
    emitted: u64,
    cycles: u64,
    failures: u64,
    quality: f64,
    same_cycle: f64,
    app: f64,
    state: f64,
) -> Vec<String> {
    vec![
        cell.harvester.name(),
        cell.device.label(),
        cell.policy.name(),
        cell.seed.to_string(),
        emitted.to_string(),
        cycles.to_string(),
        failures.to_string(),
        pct(quality),
        pct(same_cycle),
        f2(app * 1e3),
        f2(state * 1e3),
    ]
}

/// Header of each fleet projection — shared by the batch table and the
/// streaming accumulator so the two render identical bytes.
pub fn fleet_header(p: Projection) -> &'static [&'static str] {
    match p {
        Projection::FleetLatency => &[
            "harvester", "device", "seed", "devices", "detections", "propagated",
            "coverage", "mean latency s", "duty cycle",
        ],
        Projection::FleetConvergence => &[
            "harvester", "device", "seed", "devices", "duty cycle", "converged",
            "converged at s", "exchanges",
        ],
        Projection::FleetBytes => &[
            "harvester", "device", "seed", "devices", "meetings", "dropped",
            "exchanges", "bytes", "bytes/exch", "gc pruned",
        ],
        _ => unreachable!("not a fleet projection"),
    }
}

/// One fleet-projection row for a grid cell — the single rendering path
/// for batch tables, streaming accumulators, and store views.
pub fn fleet_row(p: Projection, cell: &CampaignCell, f: &FleetDigest) -> Vec<String> {
    let mut row = vec![
        cell.harvester.name(),
        cell.device.label(),
        cell.seed.to_string(),
        f.devices.to_string(),
    ];
    match p {
        Projection::FleetLatency => row.extend([
            f.detections.to_string(),
            f.propagated.to_string(),
            pct(f.coverage()),
            f2(f.mean_latency()),
            pct(f.duty_cycle()),
        ]),
        Projection::FleetConvergence => row.extend([
            pct(f.duty_cycle()),
            f.converged.to_string(),
            f2(f.converged_at),
            f.exchanges.to_string(),
        ]),
        Projection::FleetBytes => row.extend([
            f.meetings.to_string(),
            f.dropped.to_string(),
            f.exchanges.to_string(),
            f.bytes.to_string(),
            f2(f.bytes_per_exchange()),
            f.gc_pruned.to_string(),
        ]),
        _ => unreachable!("not a fleet projection"),
    }
    row
}

/// Figs. 5/7/8 layout over per-policy summary rows.
pub fn policy_accuracy_table(name: &str, title: &str, rows: &[PolicyRow]) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &["policy", "accuracy", "thrpt vs continuous", "mean features", "state energy"],
    );
    for r in rows {
        t.push(vec![
            r.policy.name(),
            pct(r.accuracy),
            pct(r.throughput_vs_continuous),
            f2(r.mean_features),
            pct(r.state_energy_fraction),
        ]);
    }
    t
}

pub fn policy_coherence_table(name: &str, title: &str, rows: &[PolicyRow]) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &["policy", "coherence vs continuous", "thrpt vs continuous"],
    );
    for r in rows.iter().filter(|r| !matches!(r.policy, Policy::Continuous)) {
        t.push(vec![
            r.policy.name(),
            pct(r.coherence_vs_continuous),
            pct(r.throughput_vs_continuous),
        ]);
    }
    t
}

pub fn policy_vs_chinchilla_table(name: &str, title: &str, rows: &[PolicyRow]) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &["policy", "coherence vs chinchilla", "thrpt vs greedy", "thrpt vs chinchilla"],
    );
    for r in rows.iter().filter(|r| !matches!(r.policy, Policy::Continuous)) {
        t.push(vec![
            r.policy.name(),
            pct(r.coherence_vs_chinchilla),
            pct(r.throughput_vs_greedy),
            ratio(r.throughput_vs_chinchilla),
        ]);
    }
    t
}

/// Fig. 6 layout over per-policy pooled latency histograms.
pub fn latency_emulation_table(
    name: &str,
    title: &str,
    hists: &[(Policy, Histogram)],
) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &["policy", "cycle0", "cycle1", "cycle2-5", "cycle6-15", "cycle16+"],
    );
    for (policy, h) in hists {
        let range =
            |a: usize, b: usize| -> f64 { (a..b.min(h.bins.len())).map(|i| h.frac(i)).sum() };
        t.push(vec![
            policy.name(),
            pct(h.frac(0)),
            pct(h.frac(1)),
            pct(range(2, 6)),
            pct(range(6, 16)),
            pct(range(16, LATENCY_CYCLES) + h.overflow as f64 / h.count.max(1) as f64),
        ]);
    }
    t
}

/// Fig. 9 layout over per-policy pooled latency histograms.
pub fn latency_real_world_table(
    name: &str,
    title: &str,
    hists: &[(Policy, Histogram)],
) -> TableData {
    let mut t = TableData::new(name, title, &["policy", "same cycle", "1 cycle", "2+ cycles"]);
    for (policy, h) in hists {
        let rest: f64 = (2..h.bins.len()).map(|i| h.frac(i)).sum::<f64>()
            + h.overflow as f64 / h.count.max(1) as f64;
        t.push(vec![policy.name(), pct(h.frac(0)), pct(h.frac(1)), pct(rest)]);
    }
    t
}

/// Fig. 13 layout: the pooled per-picture table plus the supplementary
/// per-trace table.
pub fn img_equivalence_tables(
    name: &str,
    title: &str,
    by_picture: &[(Picture, f64)],
    trace_rows: &[ImgTraceRow],
) -> Vec<TableData> {
    let mut t = TableData::new(
        name,
        title,
        &["picture", "equivalent corner info (pooled over traces)"],
    );
    for (picture, eq) in by_picture {
        t.push(vec![picture.name().to_string(), pct(*eq)]);
    }
    let mut per_trace = TableData::new(
        &format!("{name}_per_trace"),
        &format!("{title} (suppl.: per energy trace)"),
        &["trace", "equivalent corner info"],
    );
    for r in trace_rows {
        per_trace.push(vec![r.harvester.name(), pct(r.equivalence_aic)]);
    }
    vec![t, per_trace]
}

/// Fig. 14 layout over per-trace summary rows.
pub fn img_throughput_table(name: &str, title: &str, rows: &[ImgTraceRow]) -> TableData {
    let mut t = TableData::new(name, title, &["trace", "AIC", "Chinchilla", "AIC/Chinchilla"]);
    for r in rows {
        let gain = if r.throughput_chinchilla_vs_continuous > 0.0 {
            r.throughput_aic_vs_continuous / r.throughput_chinchilla_vs_continuous
        } else {
            f64::INFINITY
        };
        t.push(vec![
            r.harvester.name(),
            pct(r.throughput_aic_vs_continuous),
            pct(r.throughput_chinchilla_vs_continuous),
            ratio(gain),
        ]);
    }
    t
}

/// Fig. 15 layout over per-trace summary rows.
pub fn img_latency_table(name: &str, title: &str, rows: &[ImgTraceRow]) -> TableData {
    let mut t =
        TableData::new(name, title, &["trace", "AIC same-cycle", "Chinchilla mean latency"]);
    for r in rows {
        t.push(vec![
            r.harvester.name(),
            pct(r.aic_same_cycle),
            f2(r.chinchilla_latency_mean),
        ]);
    }
    t
}

/// Audio summary layout over per-policy rows.
pub fn audio_summary_table(name: &str, title: &str, rows: &[AudioPolicyRow]) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &[
            "policy", "accuracy", "thrpt vs continuous", "mean probes",
            "same cycle", "mean latency (cycles)",
        ],
    );
    for r in rows {
        t.push(vec![
            r.policy.name(),
            pct(r.accuracy),
            pct(r.throughput_vs_continuous),
            f2(r.mean_probes),
            pct(r.same_cycle_fraction),
            f2(r.mean_latency_cycles),
        ]);
    }
    t
}

/// Pareto layout over per-policy pooled points. The continuous ceiling
/// is rendered as `ceiling` rather than `yes`/`no`: it is shown for
/// scale but never competes for the frontier.
pub fn pareto_table(name: &str, title: &str, rows: &[ParetoRow]) -> TableData {
    let mut t = TableData::new(
        name,
        title,
        &["policy", "accuracy", "thrpt (/h)", "mJ/result", "frontier", "pick"],
    );
    for r in rows {
        t.push(vec![
            r.policy.name(),
            pct(r.accuracy),
            f2(r.throughput * 3600.0),
            f2(r.energy_per_result * 1e3),
            if !r.harvesting {
                "ceiling".to_string()
            } else if r.frontier {
                "yes".to_string()
            } else {
                "no".to_string()
            },
            if r.pick { "<-".to_string() } else { String::new() },
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Offline analyses (figs. 4 and 12).
// ---------------------------------------------------------------------

/// Fig. 4 — expected (Eq. 7) vs measured accuracy per prefix length.
/// The whole curve is evaluated in one pass: the coherence model shares
/// its Monte-Carlo draw across prefix lengths, so per-p splitting would
/// change the numbers.
pub fn accuracy_rows(ctx: &HarContext, ps: &[usize]) -> Vec<Fig4Row> {
    use crate::svm::analysis::{coherence_curve_model, expected_accuracy};
    let coh = coherence_curve_model(&ctx.asvm, &ctx.class_model, ps, 3000, 0xF164);
    let expected = expected_accuracy(&coh, ctx.full_accuracy, 6);
    let (test_rows, test_labels) = Corpus::features(&ctx.corpus.test);
    let measured = ctx.asvm.accuracy_curve(&test_rows, &test_labels, ps);
    ps.iter()
        .enumerate()
        .map(|(i, &p)| Fig4Row { p, expected: expected[i], measured: measured[i] })
        .collect()
}

/// Fig. 12 — corner output vs perforation rate per picture kind.
pub fn perforation_rows(size: usize, skips: &[f64]) -> Vec<Fig12Row> {
    use crate::imgproc::equivalence::equivalent;
    use crate::imgproc::harris::{harris_full, harris_perforated, HarrisConfig};
    use crate::imgproc::images::render;
    let cfg = HarrisConfig::default();
    let mut rows = Vec::new();
    for &picture in &Picture::ALL {
        let img = render(picture, size, size, 11);
        let reference = harris_full(&img, &cfg);
        for &skip in skips {
            let run_rows = ((1.0 - skip) * size as f64).round() as usize;
            let corners = harris_perforated(&img, &cfg, run_rows);
            rows.push(Fig12Row {
                picture,
                skip_fraction: skip,
                corners: corners.len(),
                reference_corners: reference.len(),
                equivalent: equivalent(&reference, &corners),
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------
// Named built-in scenarios (the figure registry).
// ---------------------------------------------------------------------

/// The five intermittent policies of §5 plus the continuous ceiling.
pub fn har_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Alpaca,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
        Policy::Smart { bound: 0.80 },
    ]
}

/// The policies the latency figures (6 and 9) compare.
pub fn latency_policies() -> Vec<Policy> {
    vec![Policy::Greedy, Policy::Smart { bound: 0.80 }, Policy::Chinchilla, Policy::Alpaca]
}

/// The five policies the audio grids compare (the committed
/// `examples/scenarios/audio_ambient.json` runs the same set).
pub fn audio_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Alpaca,
        Policy::Greedy,
        Policy::Smart { bound: 0.80 },
    ]
}

/// The HAR/Img policy set plus the adaptive learner — the comparison the
/// `adaptive_*` builtins judge via the Pareto projection.
pub fn adaptive_policies() -> Vec<Policy> {
    let mut ps = har_policies();
    ps.push(Policy::Adaptive {
        alpha: crate::exec::adaptive::DEFAULT_ALPHA,
        explore: crate::exec::adaptive::DEFAULT_EXPLORE,
    });
    ps
}

/// The audio policy set plus the adaptive learner.
pub fn adaptive_audio_policies() -> Vec<Policy> {
    let mut ps = audio_policies();
    ps.push(Policy::Adaptive {
        alpha: crate::exec::adaptive::DEFAULT_ALPHA,
        explore: crate::exec::adaptive::DEFAULT_EXPLORE,
    });
    ps
}

/// Every figure the `aic` CLI knows by name, plus the audio grid (the
/// third workload's builtin scenario), the three synthetic-environment
/// grids (`synth_*`: generated supplies × all policies × ≥10 environment
/// seeds — one builtin per workload), the three adaptive judgements
/// (`adaptive_*`: the same synth families with the adaptive learner added
/// and the Pareto projection selecting the per-family winner), and the
/// two multi-device fleet grids (`fleet_*`: N devices per cell with
/// coordination-free delta sync).
pub const BUILTIN_NAMES: [&str; 19] = [
    "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig12", "fig13", "fig14", "fig15",
    "audio", "synth_solar", "synth_rf", "synth_multi", "adaptive_solar", "adaptive_rf",
    "adaptive_multi", "fleet_solar", "fleet_multi",
];

/// The environment-seed axis of the builtin synth grids: ten independent
/// members of each generated family.
pub fn synth_seeds() -> Vec<u64> {
    (1..=10).collect()
}

/// The named figure scenarios. `seed` is the CLI base seed: it seeds HAR
/// training and is the single trace realisation of the imaging figures.
pub fn builtin(name: &str, seed: u64) -> Option<Scenario> {
    let har_fast =
        FastMode { horizon: Some(1800.0), max_seeds: Some(2), tiny_corpus: true, img_size: None };
    let har_fig = |n: &str, title: &str, policies: Vec<Policy>, proj: Projection| {
        Scenario::new(n, WorkloadSpec::Har)
            .with_title(title)
            .with_policies(policies)
            .with_seeds(vec![1, 2, 3, 4, 5, 6])
            .with_training(Training::full(seed))
            .with_fast(har_fast.clone())
            .with_projection(proj)
    };
    let img_fig = |n: &str, title: &str, proj: Projection| {
        Scenario::new(n, WorkloadSpec::Img)
            .with_title(title)
            .with_policies(vec![Policy::Continuous, Policy::Greedy, Policy::Chinchilla])
            .with_seeds(vec![seed])
            .with_fast(FastMode { horizon: Some(1200.0), ..FastMode::none() })
            .with_projection(proj)
    };
    Some(match name {
        "fig4" => Scenario::new(
            "fig4",
            WorkloadSpec::AccuracyCurve { ps: (0..=140).step_by(10).collect() },
        )
        .with_title("Fig. 4 — expected vs measured accuracy vs number of features")
        .with_training(Training::full(seed))
        .with_projection(Projection::AccuracyCurve),
        "fig5" => har_fig(
            "fig5",
            "Fig. 5 — emulation: accuracy and throughput normalised to continuous",
            har_policies(),
            Projection::PolicyAccuracy,
        ),
        "fig6" => har_fig(
            "fig6",
            "Fig. 6 — emulation: latency distribution in power cycles",
            latency_policies(),
            Projection::LatencyEmulation,
        ),
        "fig7" => har_fig(
            "fig7",
            "Fig. 7 — real-world: coherence and throughput vs continuous",
            har_policies(),
            Projection::PolicyCoherence,
        ),
        "fig8" => har_fig(
            "fig8",
            "Fig. 8 — real-world: coherence vs Chinchilla, throughput vs GREEDY",
            har_policies(),
            Projection::PolicyVsChinchilla,
        ),
        "fig9" => har_fig(
            "fig9",
            "Fig. 9 — real-world: latency distribution in power cycles",
            latency_policies(),
            Projection::LatencyRealWorld,
        ),
        "fig12" => Scenario::new(
            "fig12",
            WorkloadSpec::Perforation {
                size: EVAL_SIZE,
                skips: vec![0.0, 0.2, 0.42, 0.55, 0.7, 0.85],
            },
        )
        .with_title("Fig. 12 — corner detection output vs fraction of loop iterations skipped")
        .with_fast(FastMode { img_size: Some(96), ..FastMode::none() })
        .with_projection(Projection::Perforation),
        "fig13" => img_fig(
            "fig13",
            "Fig. 13 — corner info equivalent to a continuous execution",
            Projection::ImgEquivalence,
        ),
        "fig14" => img_fig(
            "fig14",
            "Fig. 14 — imaging throughput normalised to continuous",
            Projection::ImgThroughput,
        ),
        "fig15" => img_fig(
            "fig15",
            "Fig. 15 — latency to produce the corner output (power cycles)",
            Projection::ImgLatency,
        ),
        "audio" => Scenario::new("audio", WorkloadSpec::Audio)
            .with_title("Audio — anytime acoustic event detection on the five ambient traces")
            .with_policies(audio_policies())
            .with_seeds(vec![seed, seed.wrapping_add(1)])
            .with_fast(FastMode {
                horizon: Some(900.0),
                max_seeds: Some(1),
                ..FastMode::none()
            })
            .with_projection(Projection::AudioSummary),
        "synth_solar" => Scenario::new("synth_solar", WorkloadSpec::Img)
            .with_title("Synth — imaging on generated diurnal solar with cloud occlusion")
            .with_policies(har_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_solar())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_fast(FastMode {
                horizon: Some(600.0),
                max_seeds: Some(2),
                ..FastMode::none()
            })
            .with_projection(Projection::Cells),
        "synth_rf" => Scenario::new("synth_rf", WorkloadSpec::Audio)
            .with_title("Synth — audio on generated duty-cycled RF bursts")
            .with_policies(audio_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_fast(FastMode {
                horizon: Some(600.0),
                max_seeds: Some(2),
                ..FastMode::none()
            })
            .with_projection(Projection::AudioSummary),
        "synth_multi" => Scenario::new("synth_multi", WorkloadSpec::Har)
            .with_title(
                "Synth — HAR on an amalgamated multi-source device \
                 (solar + RF + kinetic + thermal, switchover)",
            )
            .with_policies(har_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_multi())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_training(Training::full(seed))
            .with_fast(FastMode {
                horizon: Some(900.0),
                max_seeds: Some(2),
                tiny_corpus: true,
                img_size: None,
            })
            .with_projection(Projection::Cells),
        "adaptive_solar" => Scenario::new("adaptive_solar", WorkloadSpec::Img)
            .with_title("Adaptive — imaging on generated solar: learner vs static policies")
            .with_policies(adaptive_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_solar())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_fast(FastMode {
                horizon: Some(600.0),
                max_seeds: Some(2),
                ..FastMode::none()
            })
            .with_projection(Projection::Pareto),
        "adaptive_rf" => Scenario::new("adaptive_rf", WorkloadSpec::Audio)
            .with_title("Adaptive — audio on generated RF bursts: learner vs static policies")
            .with_policies(adaptive_audio_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_fast(FastMode {
                horizon: Some(600.0),
                max_seeds: Some(2),
                ..FastMode::none()
            })
            .with_projection(Projection::Pareto),
        "adaptive_multi" => Scenario::new("adaptive_multi", WorkloadSpec::Har)
            .with_title(
                "Adaptive — HAR on the multi-source composite: learner vs static policies",
            )
            .with_policies(adaptive_policies())
            .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_multi())])
            .with_seeds(synth_seeds())
            .with_horizon(3600.0)
            .with_training(Training::full(seed))
            .with_fast(FastMode {
                horizon: Some(900.0),
                max_seeds: Some(2),
                tiny_corpus: true,
                img_size: None,
            })
            .with_projection(Projection::Pareto),
        "fleet_solar" => Scenario::new(
            "fleet_solar",
            WorkloadSpec::Fleet(FleetSpec::default()),
        )
        .with_title("Fleet — 4 devices on correlated solar, delta sync at powered overlap")
        .with_seeds(synth_seeds())
        .with_fast(FastMode {
            horizon: Some(600.0),
            max_seeds: Some(2),
            ..FastMode::none()
        })
        .with_projection(Projection::FleetLatency),
        "fleet_multi" => Scenario::new(
            "fleet_multi",
            WorkloadSpec::Fleet(FleetSpec {
                devices: 6,
                drop_rate: 0.2,
                clock_skew: 3.0,
                ..FleetSpec::default()
            }),
        )
        .with_title(
            "Fleet — 6 devices on the multi-source composite with drop-out and clock skew",
        )
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_multi())])
        .with_seeds(synth_seeds())
        .with_fast(FastMode {
            horizon: Some(600.0),
            max_seeds: Some(2),
            ..FastMode::none()
        })
        .with_projection(Projection::FleetConvergence),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::test_context;

    #[test]
    fn builder_defaults_follow_workload() {
        let har = Scenario::new("h", WorkloadSpec::Har);
        assert_eq!(har.harvesters, vec![HarvesterSpec::Kinetic]);
        assert_eq!(har.horizon, 4.0 * 3600.0);
        let img = Scenario::new("i", WorkloadSpec::Img);
        assert_eq!(img.harvesters.len(), 5);
        assert_eq!(img.sample_period, 30.0);
        let audio = Scenario::new("a", WorkloadSpec::Audio);
        assert_eq!(audio.harvesters.len(), 5);
        assert_eq!(audio.sample_period, 30.0);
        assert_eq!(audio.horizon, 2.0 * 3600.0);
        let fleet = Scenario::new("f", WorkloadSpec::Fleet(FleetSpec::default()));
        assert_eq!(fleet.horizon, 3600.0);
        assert_eq!(fleet.harvesters.len(), 1, "fleet defaults to one synth family");
        assert!(matches!(fleet.harvesters[0], HarvesterSpec::Synth(_)));
        fleet.validate().expect("fleet defaults validate");
    }

    #[test]
    fn fleet_projections_fit_the_workload() {
        let base = || Scenario::new("f", WorkloadSpec::Fleet(FleetSpec::default()));
        for p in [
            Projection::Cells,
            Projection::FleetLatency,
            Projection::FleetConvergence,
            Projection::FleetBytes,
        ] {
            base().with_projection(p).validate().expect("fleet projection fits");
        }
        assert!(base().with_projection(Projection::PolicyAccuracy).validate().is_err());
        assert!(
            Scenario::new("h", WorkloadSpec::Har)
                .with_projection(Projection::FleetLatency)
                .validate()
                .is_err(),
            "fleet projections must not fit single-device workloads"
        );
        assert!(
            base().with_policies(vec![Policy::Greedy, Policy::Continuous]).validate().is_err(),
            "fleet scenarios take exactly one policy"
        );
    }

    #[test]
    fn fleet_scenarios_run_and_render_deterministically() {
        let sc = Scenario::new("mini-fleet", WorkloadSpec::Fleet(FleetSpec::default()))
            .with_seeds(vec![1, 2])
            .with_horizon(600.0)
            .with_projection(Projection::FleetLatency);
        let run = sc.run(false);
        let digests = run.fleet_digests();
        assert_eq!(digests.len(), 2, "one digest per seed cell");
        for d in digests {
            let f = d.fleet.expect("fleet cells carry the fleet payload");
            assert_eq!(f.devices, 4);
            assert!(f.meetings > 0, "devices must meet within the horizon");
        }
        let tables = run.tables();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].rows.len(), 2);
        assert_eq!(tables[0].header, fleet_header(Projection::FleetLatency));
        // Same spec, fresh run: bitwise-identical tables.
        let again = sc.run(false);
        assert_eq!(again.tables()[0].rows, tables[0].rows);
        // Every fleet projection renders on the same grid.
        for p in [Projection::Cells, Projection::FleetConvergence, Projection::FleetBytes] {
            let t = sc.clone().with_projection(p).run(false).tables();
            assert_eq!(t[0].rows.len(), 2, "{}", p.name());
        }
    }

    #[test]
    fn fleet_json_round_trips_through_scenario_parse() {
        let sc = Scenario::new(
            "fleet-json",
            WorkloadSpec::Fleet(FleetSpec {
                devices: 3,
                drop_rate: 0.1,
                clock_skew: 2.0,
                overlap: Some(vec![
                    vec![1.0, 0.5, 0.25],
                    vec![0.5, 1.0, 0.75],
                    vec![0.25, 0.75, 1.0],
                ]),
                ..FleetSpec::default()
            }),
        )
        .with_seeds(vec![7])
        .with_projection(Projection::FleetBytes);
        let parsed = Scenario::parse(&sc.to_json_string()).expect("fleet round trip");
        assert_eq!(parsed, sc);
    }

    #[test]
    fn audio_projections_fit_the_workload() {
        let ok = Scenario::new("a", WorkloadSpec::Audio)
            .with_projection(Projection::AudioSummary);
        ok.validate().expect("audio-summary fits audio");
        let bad = Scenario::new("a", WorkloadSpec::Audio)
            .with_projection(Projection::PolicyAccuracy);
        assert!(bad.validate().is_err(), "HAR projection must not fit audio");
        let har_bad =
            Scenario::new("h", WorkloadSpec::Har).with_projection(Projection::AudioSummary);
        assert!(har_bad.validate().is_err(), "audio projection must not fit HAR");
    }

    #[test]
    fn audio_projections_render_one_row_per_cell_and_policy() {
        let sc = Scenario::new("mini-audio", WorkloadSpec::Audio)
            .with_policies(vec![Policy::Greedy, Policy::Continuous])
            .with_harvesters(vec![HarvesterSpec::Ambient(TraceKind::Som)])
            .with_seeds(vec![1, 2])
            .with_horizon(600.0);
        let run = sc.run(false);
        let cells = run.tables();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].rows.len(), 4, "one row per (policy, seed) cell");
        assert_eq!(cells[0].rows[0][0], "SOM");
        assert_eq!(cells[0].rows[0][2], "greedy");
        let summary = sc.with_projection(Projection::AudioSummary).run(false);
        let tables = summary.tables();
        assert_eq!(tables[0].rows.len(), 2, "one summary row per policy");
        // The continuous ceiling runs every probe; greedy is normalised
        // against it on the same unit.
        let rows = summary.audio_policy_rows();
        let cont = rows.iter().find(|r| r.policy == Policy::Continuous).unwrap();
        assert!((cont.mean_probes - 63.0).abs() < 1e-9);
        assert!(cont.accuracy > 0.99, "full refinement is exact");
    }

    #[test]
    fn plan_order_is_harvester_device_policy_seed() {
        let sc = Scenario::new("t", WorkloadSpec::Har)
            .with_policies(vec![Policy::Greedy, Policy::Continuous])
            .with_harvesters(vec![
                HarvesterSpec::Kinetic,
                HarvesterSpec::Ambient(TraceKind::Som),
            ])
            .with_seeds(vec![1, 2]);
        let JobPlan::Campaigns(cells) = sc.plan() else { panic!("campaign plan") };
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].harvester, HarvesterSpec::Kinetic);
        assert_eq!(cells[0].policy, Policy::Greedy);
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].seed, 2);
        assert_eq!(cells[2].policy, Policy::Continuous);
        assert_eq!(cells[4].harvester, HarvesterSpec::Ambient(TraceKind::Som));
    }

    #[test]
    fn fast_resolution_applies_the_spec_scaling() {
        let sc = builtin("fig5", 42).unwrap();
        let fast = sc.resolve(true);
        assert_eq!(fast.horizon, 1800.0);
        assert_eq!(fast.seeds, vec![1, 2]);
        assert_eq!(fast.training, Training::tiny());
        // fig4 opts out of fast scaling entirely.
        let fig4 = builtin("fig4", 42).unwrap();
        assert_eq!(fig4.resolve(true), fig4);
        // fig12 swaps the evaluation size only.
        let fig12 = builtin("fig12", 42).unwrap().resolve(true);
        assert!(matches!(fig12.workload, WorkloadSpec::Perforation { size: 96, .. }));
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let sc = Scenario::new("custom", WorkloadSpec::Har)
            .with_policies(vec![Policy::Greedy, Policy::Smart { bound: 0.80 }])
            .with_harvesters(vec![
                HarvesterSpec::Ambient(TraceKind::Rf),
                HarvesterSpec::Kinetic,
            ])
            .with_devices(vec![
                DeviceSpec::default(),
                DeviceSpec { capacitance: Some(2940e-6), ..DeviceSpec::default() },
            ])
            .with_seeds(vec![3, 5])
            .with_horizon(1234.5)
            .with_fast(FastMode { horizon: Some(300.0), ..FastMode::none() });
        let parsed = Scenario::parse(&sc.to_json_string()).expect("round trip");
        assert_eq!(parsed, sc);
        assert_eq!(parsed.plan(), sc.plan());
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_values() {
        assert!(Scenario::parse(r#"{"name":"x","workload":"har","bogus":1}"#).is_err());
        assert!(Scenario::parse(r#"{"name":"x","workload":"nope"}"#).is_err());
        assert!(Scenario::parse(r#"{"name":"x","workload":"har","policies":["gredy"]}"#)
            .is_err());
        assert!(Scenario::parse(r#"{"name":"x","workload":"har","harvesters":["mars"]}"#)
            .is_err());
        assert!(Scenario::parse(r#"{"name":"x","workload":"har","seeds":[]}"#).is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"img","projection":"policy-accuracy"}"#
        )
        .is_err());
        // Mistyped values are hard errors, not silent defaults.
        assert!(Scenario::parse(r#"{"name":"x","workload":"har","horizon":"900"}"#).is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"har","devices":[{"capacitance":"0.00147"}]}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"har","training":{"windows":6.5}}"#
        )
        .is_err());
        // Impossible device physics fail at parse time, not mid-fleet.
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"har","devices":[{"v_off":3.5}]}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"har","devices":[{"capacitance":0}]}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"har","devices":[{"v_on":4.0}]}"#
        )
        .is_err());
    }

    #[test]
    fn synth_harvesters_round_trip_and_validate() {
        let sc = Scenario::new("synth-custom", WorkloadSpec::Audio)
            .with_policies(vec![Policy::Greedy, Policy::Continuous])
            .with_harvesters(vec![
                HarvesterSpec::Synth(SynthSpec::builtin_multi()),
                HarvesterSpec::Ambient(TraceKind::Rf),
            ])
            .with_seeds(vec![1, 2, 3])
            .with_horizon(900.0);
        let parsed = Scenario::parse(&sc.to_json_string()).expect("round trip");
        assert_eq!(parsed, sc);
        assert_eq!(parsed.plan(), sc.plan());
        // An embedded synth object parses from raw JSON too.
        let doc = r#"{
            "name": "inline-synth",
            "workload": "audio",
            "harvesters": [{"synth": {
                "name": "rf-family",
                "seed": 5,
                "duration": 600,
                "combine": "sum",
                "sources": [{"kind": "rf", "burst_power": 0.0016,
                             "mean_on": 0.5, "mean_off": 4.5, "jitter": 0.35}]
            }}]
        }"#;
        let sc2 = Scenario::parse(doc).expect("inline synth parses");
        assert_eq!(sc2.harvesters.len(), 1);
        assert_eq!(sc2.harvesters[0].name(), "rf-family");
        // A broken embedded spec is a parse error, not a fleet panic.
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"audio","harvesters":[{"synth":{
                "name":"bad","seed":1,"duration":0,"combine":"sum",
                "sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,
                            "mean_off":4.5,"jitter":0}]}}]}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"name":"x","workload":"audio","harvesters":[{"bogus":{}}]}"#
        )
        .is_err());
    }

    #[test]
    fn synth_builtins_are_one_per_workload() {
        let solar = builtin("synth_solar", 42).unwrap();
        assert_eq!(solar.workload, WorkloadSpec::Img);
        let rf = builtin("synth_rf", 42).unwrap();
        assert_eq!(rf.workload, WorkloadSpec::Audio);
        let multi = builtin("synth_multi", 42).unwrap();
        assert_eq!(multi.workload, WorkloadSpec::Har);
        for sc in [&solar, &rf, &multi] {
            assert!(sc.seeds.len() >= 10, "{}: {} environment seeds", sc.name, sc.seeds.len());
            assert!(
                matches!(sc.harvesters[0], HarvesterSpec::Synth(_)),
                "{}: synthetic supply expected",
                sc.name
            );
            // Fast mode keeps the grids CI-sized.
            assert!(sc.resolve(true).seeds.len() <= 2, "{}", sc.name);
        }
    }

    #[test]
    fn adaptive_builtins_add_the_learner_and_judge_by_pareto() {
        for (name, workload) in [
            ("adaptive_solar", WorkloadSpec::Img),
            ("adaptive_rf", WorkloadSpec::Audio),
            ("adaptive_multi", WorkloadSpec::Har),
        ] {
            let sc = builtin(name, 42).unwrap();
            assert_eq!(sc.workload, workload, "{name}");
            assert_eq!(sc.projection, Projection::Pareto, "{name}");
            assert!(
                sc.policies.iter().any(|p| matches!(p, Policy::Adaptive { .. })),
                "{name}: adaptive policy missing from the comparison set"
            );
            assert!(
                sc.policies.iter().any(|p| matches!(p, Policy::Continuous)),
                "{name}: continuous ceiling missing"
            );
            assert!(matches!(sc.harvesters[0], HarvesterSpec::Synth(_)), "{name}");
            assert!(sc.seeds.len() >= 10, "{name}");
            sc.validate().unwrap();
        }
    }

    #[test]
    fn pareto_frontier_is_strict_dominance_among_harvesters() {
        let policies = vec![
            Policy::Continuous,                  // ceiling: excluded from frontier
            Policy::Greedy,                      // dominated by smart80 below
            Policy::Smart { bound: 0.80 },       // dominates greedy
            Policy::Adaptive { alpha: 0.2, explore: 0.5 }, // trades acc for thrpt
        ];
        let mk = |ok: u64, total: u64, emitted: u64| ParetoPool {
            quality_ok: ok,
            quality_total: total,
            emitted,
            duration: 3600.0,
            app_energy: 1.0e-3 * emitted as f64,
            state_energy: 0.0,
        };
        let pools = vec![
            mk(100, 100, 500), // continuous: best everywhere, but a ceiling
            mk(60, 100, 80),   // greedy
            mk(80, 100, 90),   // smart80: strictly dominates greedy
            mk(70, 100, 120),  // adaptive: best harvesting throughput
        ];
        let rows = pareto_rows_from_pools(&policies, &pools);
        assert_eq!(rows.len(), 4);
        assert!(!rows[0].harvesting && !rows[0].frontier && !rows[0].pick);
        assert!(!rows[1].frontier, "greedy is dominated by smart80");
        assert!(rows[2].frontier, "smart80 is non-dominated");
        assert!(rows[3].frontier, "adaptive is non-dominated");
        // Pick = max accuracy x throughput among harvesters:
        // smart80 scores 0.8*90, adaptive 0.7*120 -> adaptive wins.
        assert!(rows[3].pick && !rows[2].pick && !rows[1].pick);
        let t = pareto_table("pareto", "t", &rows);
        assert_eq!(t.rows[0][4], "ceiling");
        assert_eq!(t.rows[2][4], "yes");
        assert_eq!(t.rows[3][5], "<-");
    }

    #[test]
    fn pareto_pick_breaks_score_ties_toward_the_earlier_policy() {
        let policies = vec![Policy::Greedy, Policy::Smart { bound: 0.80 }];
        let pool = ParetoPool {
            quality_ok: 50,
            quality_total: 100,
            emitted: 100,
            duration: 3600.0,
            app_energy: 0.1,
            state_energy: 0.0,
        };
        let rows = pareto_rows_from_pools(&policies, &[pool, pool]);
        assert!(rows[0].pick && !rows[1].pick);
        // Identical points do not strictly dominate each other.
        assert!(rows[0].frontier && rows[1].frontier);
    }

    #[test]
    fn engine_override_lands_in_every_device() {
        let sc = builtin("fig5", 42).unwrap().with_engine(EngineKind::FixedStep);
        assert!(sc.devices.iter().all(|d| d.engine == Some(EngineKind::FixedStep)));
        let cfg = sc.devices[0].engine_config(10.0);
        assert_eq!(cfg.kind, EngineKind::FixedStep);
    }

    #[test]
    fn default_device_is_the_paper_device() {
        let cfg = DeviceSpec::default().engine_config(100.0);
        let paper = EngineConfig::paper_default(100.0);
        assert_eq!(cfg.capacitor.capacitance, paper.capacitor.capacitance);
        assert_eq!(cfg.capacitor.v_on, paper.capacitor.v_on);
        assert_eq!(cfg.capacitor.v_off, paper.capacitor.v_off);
        assert_eq!(cfg.initial_voltage, paper.initial_voltage);
        assert_eq!(DeviceSpec::default().label(), "paper");
    }

    #[test]
    fn fig4_curves_rise_to_ceiling() {
        let ctx = test_context();
        let rows = accuracy_rows(&ctx, &[0, 20, 60, 140]);
        assert_eq!(rows.len(), 4);
        // Chance at p=0 (~1/6 measured and modelled).
        assert!(rows[0].measured < 0.45, "p=0 measured {}", rows[0].measured);
        // Measured accuracy at p=140 equals the full accuracy.
        assert!((rows[3].measured - ctx.full_accuracy).abs() < 1e-9);
        // Expected tracks measured within the paper's visual delta.
        for r in &rows {
            assert!(
                (r.expected - r.measured).abs() < 0.22,
                "p={}: expected={} measured={}",
                r.p,
                r.expected,
                r.measured
            );
        }
        // Monotone-ish growth.
        assert!(rows[2].measured > rows[0].measured);
    }

    #[test]
    fn fig12_degrades_gracefully() {
        let rows = perforation_rows(64, &[0.0, 0.3, 0.8]);
        assert_eq!(rows.len(), 9);
        for chunk in rows.chunks(3) {
            // skip=0 is exactly the reference.
            assert!(chunk[0].equivalent);
            assert_eq!(chunk[0].corners, chunk[0].reference_corners);
            // skip=0.8 finds no more corners than skip=0.3.
            assert!(chunk[2].corners <= chunk[1].corners + 2);
        }
    }

    #[test]
    fn cells_projection_emits_one_row_per_cell() {
        let ctx = test_context();
        let sc = Scenario::new("mini", WorkloadSpec::Har)
            .with_policies(vec![Policy::Greedy, Policy::Continuous])
            .with_seeds(vec![1, 2])
            .with_horizon(900.0);
        let run = sc.run_with(false, Some(&ctx), None);
        let tables = run.tables();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].rows.len(), 4);
        assert_eq!(tables[0].rows[0][0], "kinetic");
        assert_eq!(tables[0].rows[0][2], "greedy");
    }

    #[test]
    fn builtin_registry_covers_every_figure() {
        for name in BUILTIN_NAMES {
            let sc = builtin(name, 42).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(sc.name, name);
            sc.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!sc.plan().is_empty(), "{name} plan empty");
        }
        assert!(builtin("fig99", 42).is_none());
    }
}
