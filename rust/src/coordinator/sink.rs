//! Row sinks: where sweep/figure tables go.
//!
//! A scenario run produces [`TableData`] — titled, headered string rows.
//! The [`Sink`] trait is the single row-streaming abstraction behind
//! every output format: markdown to stdout, CSV and JSON files under
//! `out/`, or in-memory capture for tests and parity checks. The `aic`
//! CLI fans every table out to all three file-facing sinks at once
//! ([`standard`]), which is exactly what the retired `report::Table`
//! used to hard-code.

use crate::util::json::{self, Value};
use std::io::{self, Write};
use std::path::PathBuf;

/// One rendered table of a sweep: the unit every sink consumes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TableData {
    /// File stem for CSV/JSON sinks (`out/<stem>.csv`).
    pub stem: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableData {
    pub fn new(stem: &str, title: &str, header: &[&str]) -> TableData {
        TableData {
            stem: stem.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("### ");
        s.push_str(&self.title);
        s.push_str("\n\n");
        push_md_row(&mut s, &self.header);
        s.push('|');
        for _ in &self.header {
            s.push_str("---|");
        }
        s.push('\n');
        for row in &self.rows {
            push_md_row(&mut s, row);
        }
        s
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        push_csv_row(&mut s, &self.header);
        for row in &self.rows {
            push_csv_row(&mut s, row);
        }
        s
    }

    /// As a JSON value (for machine consumption).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", self.title.as_str().into()),
            (
                "header",
                Value::Arr(self.header.iter().map(|h| h.as_str().into()).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| Value::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Append `| a | b |\n` to a line buffer — the markdown row shape,
/// built without per-row vectors or joins.
fn push_md_row(s: &mut String, cells: &[String]) {
    s.push('|');
    for c in cells {
        s.push(' ');
        s.push_str(c);
        s.push_str(" |");
    }
    s.push('\n');
}

/// Append one CSV record (with trailing newline) to a line buffer,
/// escaping in place: cells containing `,` or `"` are quoted with
/// doubled quotes, exactly the dialect the retired `csv_escape` wrote.
fn push_csv_row(s: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        if cell.contains(',') || cell.contains('"') {
            s.push('"');
            for ch in cell.chars() {
                if ch == '"' {
                    s.push('"');
                }
                s.push(ch);
            }
            s.push('"');
        } else {
            s.push_str(cell);
        }
    }
    s.push('\n');
}

/// A destination for table rows. `begin` opens a table, `row` streams one
/// data row, `finish` closes it; `table` is the convenience driver.
pub trait Sink {
    fn begin(&mut self, stem: &str, title: &str, header: &[String]) -> io::Result<()>;
    fn row(&mut self, cells: &[String]) -> io::Result<()>;
    fn finish(&mut self) -> io::Result<()>;

    fn table(&mut self, t: &TableData) -> io::Result<()> {
        self.begin(&t.stem, &t.title, &t.header)?;
        for row in &t.rows {
            self.row(row)?;
        }
        self.finish()
    }
}

/// Send every table to a sink in order.
pub fn emit_all(tables: &[TableData], sink: &mut dyn Sink) -> io::Result<()> {
    for t in tables {
        sink.table(t)?;
    }
    Ok(())
}

/// Markdown tables streamed to a writer (stdout for the CLI).
pub struct MarkdownSink<W: Write> {
    out: W,
}

impl<W: Write> MarkdownSink<W> {
    pub fn new(out: W) -> MarkdownSink<W> {
        MarkdownSink { out }
    }
}

/// Markdown to stdout — what the CLI prints while the file sinks write.
pub fn markdown_stdout() -> MarkdownSink<io::Stdout> {
    MarkdownSink::new(io::stdout())
}

impl<W: Write> Sink for MarkdownSink<W> {
    fn begin(&mut self, _stem: &str, title: &str, header: &[String]) -> io::Result<()> {
        writeln!(self.out, "### {title}")?;
        writeln!(self.out)?;
        self.row(header)?;
        write!(self.out, "|")?;
        for _ in header {
            write!(self.out, "---|")?;
        }
        writeln!(self.out)
    }

    fn row(&mut self, cells: &[String]) -> io::Result<()> {
        write!(self.out, "|")?;
        for c in cells {
            write!(self.out, " {c} |")?;
        }
        writeln!(self.out)
    }

    fn finish(&mut self) -> io::Result<()> {
        writeln!(self.out)
    }
}

/// One `<stem>.csv` per table under `dir`, rows streamed as they arrive.
pub struct CsvSink {
    dir: PathBuf,
    file: Option<io::BufWriter<std::fs::File>>,
    /// Reusable record buffer: streaming a row allocates nothing once
    /// the buffer has warmed to the table's row width.
    line: String,
}

impl CsvSink {
    pub fn new(dir: &str) -> CsvSink {
        CsvSink { dir: PathBuf::from(dir), file: None, line: String::new() }
    }
}

impl Sink for CsvSink {
    fn begin(&mut self, stem: &str, _title: &str, header: &[String]) -> io::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let f = io::BufWriter::new(std::fs::File::create(
            self.dir.join(format!("{stem}.csv")),
        )?);
        self.file = Some(f);
        self.row(header)
    }

    fn row(&mut self, cells: &[String]) -> io::Result<()> {
        self.line.clear();
        push_csv_row(&mut self.line, cells);
        let f = self.file.as_mut().expect("CsvSink::row before begin");
        f.write_all(self.line.as_bytes())
    }

    fn finish(&mut self) -> io::Result<()> {
        if let Some(mut f) = self.file.take() {
            f.flush()?;
        }
        Ok(())
    }
}

/// One `<stem>.json` per table under `dir` (same document shape the
/// retired `report::Table::emit` wrote: `{title, header, rows}`).
pub struct JsonSink {
    dir: PathBuf,
    current: Option<TableData>,
}

impl JsonSink {
    pub fn new(dir: &str) -> JsonSink {
        JsonSink { dir: PathBuf::from(dir), current: None }
    }
}

impl Sink for JsonSink {
    fn begin(&mut self, stem: &str, title: &str, header: &[String]) -> io::Result<()> {
        self.current = Some(TableData {
            stem: stem.to_string(),
            title: title.to_string(),
            header: header.to_vec(),
            rows: Vec::new(),
        });
        Ok(())
    }

    fn row(&mut self, cells: &[String]) -> io::Result<()> {
        self.current
            .as_mut()
            .expect("JsonSink::row before begin")
            .rows
            .push(cells.to_vec());
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        if let Some(t) = self.current.take() {
            std::fs::create_dir_all(&self.dir)?;
            std::fs::write(
                self.dir.join(format!("{}.json", t.stem)),
                json::to_string_pretty(&t.to_json()),
            )?;
        }
        Ok(())
    }
}

/// Captures tables in memory — parity tests and programmatic consumers.
#[derive(Default)]
pub struct MemorySink {
    pub tables: Vec<TableData>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }
}

impl Sink for MemorySink {
    fn begin(&mut self, stem: &str, title: &str, header: &[String]) -> io::Result<()> {
        self.tables.push(TableData {
            stem: stem.to_string(),
            title: title.to_string(),
            header: header.to_vec(),
            rows: Vec::new(),
        });
        Ok(())
    }

    fn row(&mut self, cells: &[String]) -> io::Result<()> {
        self.tables.last_mut().expect("MemorySink::row before begin").rows.push(cells.to_vec());
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Discards everything — memory/time benchmarks of the streaming path
/// that must not measure formatting I/O.
#[derive(Default)]
pub struct NullSink;

impl Sink for NullSink {
    fn begin(&mut self, _stem: &str, _title: &str, _header: &[String]) -> io::Result<()> {
        Ok(())
    }

    fn row(&mut self, _cells: &[String]) -> io::Result<()> {
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Fans every call out to several sinks.
pub struct Fanout {
    pub sinks: Vec<Box<dyn Sink>>,
}

impl Sink for Fanout {
    fn begin(&mut self, stem: &str, title: &str, header: &[String]) -> io::Result<()> {
        for s in &mut self.sinks {
            s.begin(stem, title, header)?;
        }
        Ok(())
    }

    fn row(&mut self, cells: &[String]) -> io::Result<()> {
        for s in &mut self.sinks {
            s.row(cells)?;
        }
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        for s in &mut self.sinks {
            s.finish()?;
        }
        Ok(())
    }
}

/// The CLI's output fan: markdown on stdout plus CSV + JSON files under
/// `out_dir` — byte-compatible with the retired `Table::emit`.
pub fn standard(out_dir: &str) -> Fanout {
    Fanout {
        sinks: vec![
            Box::new(markdown_stdout()),
            Box::new(CsvSink::new(out_dir)),
            Box::new(JsonSink::new(out_dir)),
        ],
    }
}

/// Format helpers shared by the projections and the figure benches.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> TableData {
        let mut t = TableData::new("fig_test", "fig-test", &["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        t
    }

    #[test]
    fn markdown_and_csv_render() {
        let t = table();
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | x,y |"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn json_roundtrip() {
        let t = table();
        let v = t.to_json();
        assert_eq!(v.get("title").as_str(), Some("fig-test"));
        assert_eq!(v.get("rows").at(0).at(1).as_str(), Some("x,y"));
    }

    #[test]
    fn file_sinks_write_files() {
        let t = table();
        let dir = std::env::temp_dir().join("aic_sink_test");
        let dir_s = dir.to_str().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        let mut fan = Fanout {
            sinks: vec![Box::new(CsvSink::new(dir_s)), Box::new(JsonSink::new(dir_s))],
        };
        fan.table(&t).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig_test.csv")).unwrap();
        assert_eq!(csv, t.to_csv());
        let js = std::fs::read_to_string(dir.join("fig_test.json")).unwrap();
        assert_eq!(json::parse(&js).unwrap(), t.to_json());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn markdown_sink_matches_to_markdown() {
        let t = table();
        let mut buf = Vec::new();
        MarkdownSink::new(&mut buf).table(&t).unwrap();
        // Streamed output == buffered render + the trailing blank line the
        // old `println!("{}", to_markdown())` produced.
        assert_eq!(String::from_utf8(buf).unwrap(), t.to_markdown() + "\n");
    }

    #[test]
    fn memory_sink_captures_tables() {
        let t = table();
        let mut m = MemorySink::new();
        emit_all(&[t.clone()], &mut m).unwrap();
        assert_eq!(m.tables, vec![t]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.834), "83.4%");
        assert_eq!(ratio(7.0), "7.00x");
        assert_eq!(f2(1.234), "1.23");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_enforced() {
        let mut t = TableData::new("t", "t", &["a", "b"]);
        t.push(vec!["only-one".into()]);
    }
}
