//! Streaming campaign sweeps: lazy cells, incremental projections,
//! resumable stores.
//!
//! The batch path (`Scenario::run_cached`) materialises every cell's
//! full [`Campaign`](crate::exec::Campaign) before projecting — fine
//! for the paper figures, impossible for the million-cell synth grids
//! the roadmap targets. [`run_streaming`] keeps the same deterministic
//! plan order but pulls cells lazily from [`Scenario::cells`] in
//! bounded chunks, runs each chunk on the fleet pool, reduces every
//! campaign to a [`CellDigest`] immediately, and folds the digest into
//! a per-projection accumulator ([`StreamAcc`]). Peak memory is one
//! chunk of campaigns plus the accumulator — never the grid.
//!
//! **Bitwise contract.** Streamed output must equal
//! `SweepRun::tables()` byte-for-byte, for any `AIC_WORKERS`, chunk
//! size, or kill/resume history. Three ingredients make that hold:
//!
//! 1. Rendering is shared — both paths call the same
//!    `scenario::*_table` functions, so only numbers need to agree.
//! 2. Digests store integer event counts (quality hits, latency bins,
//!    per-slot classes); integer sums are grouping-independent, and the
//!    final divisions reproduce the batch expressions exactly.
//! 3. Where the batch path folds f64 means in unit order
//!    (`stats::mean` over units), the accumulators buffer exactly one
//!    policy block — all policies of one (harvester, device) — and
//!    replay it in the batch iteration order, adding into per-column
//!    running sums. Additions happen in the identical sequence, so the
//!    f64 results are identical, not merely close.
//!
//! **Memory bounds per projection:** `cells` streams rows with O(1)
//! state (the million-cell mode); latency histograms keep O(policies ×
//! bins); HAR/audio policy summaries keep one (policies × seeds) block
//! of digests; imaging keeps one (devices × policies × seeds) harvester
//! group (pairwise coherence/throughput columns need co-unit cells).
//! All bounds are independent of the harvester × device extent — and of
//! total cell count for the acceptance-scale `cells` grids. Note the
//! digest of a HAR cell with slot payloads is O(rounds); see DESIGN.md
//! §8 for the full accounting (including the Harris reference memo).
//!
//! **Resume.** With a [`Store`], every completed cell is committed
//! under `(grid_hash, cell index)` before the sweep moves on; a re-run
//! reads committed digests instead of re-simulating and converges to
//! the same bytes. A killed campaign therefore loses at most the
//! in-flight chunk — the repo's own sweeps now tolerate the power
//! failures the paper's devices do.

use crate::coordinator::experiment::{
    run_campaign_cached, AudioRunSpec, AudioWorkload, HarContext, HarRunSpec, HarWorkload,
    ImgRunSpec, ImgWorkload, SupplyCache,
};
use crate::coordinator::fleet::run_fleet;
use crate::coordinator::scenario::{
    self, audio_summary_table, cells_row, img_equivalence_tables, img_latency_table,
    img_throughput_table, latency_emulation_table, latency_real_world_table,
    pareto_rows_from_pools, pareto_table, policy_accuracy_table, policy_coherence_table,
    policy_vs_chinchilla_table, AudioPolicyRow, CampaignCell, ImgTraceRow, ParetoPool, PolicyRow,
    Projection, Scenario, WorkloadSpec, LATENCY_CYCLES,
};
use crate::coordinator::sink::{emit_all, Sink};
use crate::coordinator::store::{grid_hash, CellDigest, Needs, Store};
use crate::exec::Policy;
use crate::imgproc::images::Picture;
use crate::util::stats::Histogram;
use std::collections::HashMap;
use std::io;

/// Default cell-chunk size for streaming sweeps: large enough to keep
/// every worker busy between merge points, small enough that in-flight
/// (uncommitted, lost-on-kill) work stays bounded.
pub const DEFAULT_CHUNK: usize = 256;

/// Knobs of one streaming sweep.
pub struct StreamOptions {
    /// Apply the scenario's `--fast` scaling.
    pub fast: bool,
    /// Fleet pool override (`None` = `AIC_WORKERS`/cores).
    pub workers: Option<usize>,
    /// Cells dispatched per fleet round.
    pub chunk: usize,
    /// Experiment label registered in the store.
    pub label: String,
    /// Abort (without finishing projections) after committing this many
    /// *fresh* cells — the CI kill/resume harness; `None` = run to
    /// completion.
    pub stop_after: Option<u64>,
}

impl Default for StreamOptions {
    fn default() -> StreamOptions {
        StreamOptions {
            fast: false,
            workers: None,
            chunk: DEFAULT_CHUNK,
            label: "sweep".to_string(),
            stop_after: None,
        }
    }
}

/// What a streaming sweep did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamReport {
    /// Grid size (resolved plan).
    pub cells: usize,
    /// Cells folded from committed store records instead of re-running.
    pub reused: usize,
    /// Cells actually simulated this run.
    pub ran: usize,
    /// True when `stop_after` aborted the sweep before the projections
    /// were finished (committed records survive for resume).
    pub partial: bool,
}

/// Run a sweep as a streaming pipeline. Campaign grids stream cell by
/// cell (optionally resuming from / committing to `store`);
/// non-campaign workloads (fig. 4 accuracy curves, fig. 12 perforation)
/// are small offline analyses and fall back to the batch path
/// internally, with identical output either way.
pub fn run_streaming(
    sc: &Scenario,
    opts: &StreamOptions,
    shared_ctx: Option<&HarContext>,
    cache: &SupplyCache,
    mut store: Option<&mut Store>,
    sink: &mut dyn Sink,
) -> io::Result<StreamReport> {
    let s = sc.resolve(opts.fast);
    if !s.workload.is_campaign() {
        let run = sc.run_cached(opts.fast, shared_ctx, opts.workers, cache);
        let n = run.scenario.plan().len();
        emit_all(&run.tables(), sink)?;
        return Ok(StreamReport { cells: n, reused: 0, ran: n, partial: false });
    }

    let needs = Needs::for_projection(s.projection);
    let hash = grid_hash(&s, needs);
    if let Some(st) = store.as_deref_mut() {
        st.ensure_experiment(&opts.label, hash, &s)?;
    }

    let total = s.campaign_cell_count();
    let chunk = opts.chunk.max(1);
    let mut acc = StreamAcc::new(&s, sink)?;
    let mut owned_ctx: Option<HarContext> = None;
    let mut reused = 0usize;
    let mut ran = 0usize;
    let mut fresh = 0u64;

    let mut idx = 0usize;
    while idx < total {
        let hi = (idx + chunk).min(total);
        // Partition the chunk: committed digests fold straight from the
        // store; the rest go to the fleet. A committed digest missing a
        // payload this projection needs (written by a narrower
        // projection) is re-run — the dedup key keeps the old record
        // authoritative for what it *does* serve, so the re-run only
        // feeds the accumulator.
        let mut have: Vec<(usize, CellDigest)> = Vec::new();
        let mut to_run: Vec<(usize, CampaignCell)> = Vec::new();
        for i in idx..hi {
            if let Some(st) = store.as_deref_mut() {
                if st.has_cell(hash, i as u32) {
                    let d = st
                        .read_cell(hash, i as u32)?
                        .expect("indexed cell must read back");
                    if d.satisfies(needs) {
                        have.push((i, d));
                        continue;
                    }
                }
            }
            to_run.push((i, s.cell_at(i)));
        }

        let fresh_digests: Vec<CellDigest> = if to_run.is_empty() {
            Vec::new()
        } else {
            match &s.workload {
                WorkloadSpec::Har => {
                    let ctx = match shared_ctx {
                        Some(c) => c,
                        None => owned_ctx.get_or_insert_with(|| s.training.context()),
                    };
                    run_fleet(&to_run, opts.workers, |(_, cell)| {
                        let spec = HarRunSpec {
                            horizon: s.horizon,
                            sample_period: s.sample_period,
                            script_seed: cell.seed,
                        };
                        let workload =
                            HarWorkload { ctx, spec, harvester: cell.harvester.clone() };
                        let c = run_campaign_cached(
                            &workload, cell.seed, cell.policy, &cell.device, cache,
                        );
                        CellDigest::of_har(&c, s.sample_period, needs)
                    })
                }
                WorkloadSpec::Img => run_fleet(&to_run, opts.workers, |(_, cell)| {
                    let spec = ImgRunSpec {
                        horizon: s.horizon,
                        sample_period: s.sample_period,
                        trace_seed: cell.seed,
                    };
                    let workload = ImgWorkload { spec, harvester: cell.harvester.clone() };
                    let c = run_campaign_cached(
                        &workload, cell.seed, cell.policy, &cell.device, cache,
                    );
                    CellDigest::of_img(&c, needs)
                }),
                WorkloadSpec::Audio => run_fleet(&to_run, opts.workers, |(_, cell)| {
                    let spec = AudioRunSpec {
                        horizon: s.horizon,
                        sample_period: s.sample_period,
                        stream_seed: cell.seed,
                    };
                    let workload = AudioWorkload { spec, harvester: cell.harvester.clone() };
                    let c = run_campaign_cached(
                        &workload, cell.seed, cell.policy, &cell.device, cache,
                    );
                    CellDigest::of_audio(&c, needs)
                }),
                WorkloadSpec::Fleet(fs) => run_fleet(&to_run, opts.workers, |(_, cell)| {
                    scenario::fleet_cell_digest(fs, cell, s.horizon)
                }),
                _ => unreachable!("non-campaign workloads fell back above"),
            }
        };

        // Merge both sources back into plan order and fold.
        let mut have_it = have.into_iter().peekable();
        let mut run_it =
            to_run.iter().map(|(i, _)| *i).zip(fresh_digests.into_iter()).peekable();
        for i in idx..hi {
            let (digest, is_fresh) = match (have_it.peek(), run_it.peek()) {
                (Some((hi_i, _)), _) if *hi_i == i => (have_it.next().unwrap().1, false),
                (_, Some((ri, _))) if *ri == i => (run_it.next().unwrap().1, true),
                _ => unreachable!("every chunk index is in exactly one partition"),
            };
            if is_fresh {
                ran += 1;
                if let Some(st) = store.as_deref_mut() {
                    st.append_cell(hash, i as u32, &digest)?;
                    fresh += 1;
                    if opts.stop_after.is_some_and(|n| fresh >= n) {
                        st.sync()?;
                        return Ok(StreamReport {
                            cells: total,
                            reused,
                            ran,
                            partial: true,
                        });
                    }
                }
            } else {
                reused += 1;
            }
            acc.fold(&s, i, &digest, sink)?;
        }
    }

    acc.finish(&s, sink)?;
    if let Some(st) = store.as_deref_mut() {
        st.sync()?;
    }
    Ok(StreamReport { cells: total, reused, ran, partial: false })
}

// ---------------------------------------------------------------------
// Incremental projection accumulators.
// ---------------------------------------------------------------------

/// Digest twin of `metrics::throughput_ratio` — bitwise-identical
/// guard and division.
fn thr_ratio(a: &CellDigest, b: &CellDigest) -> f64 {
    let tb = b.throughput();
    if tb == 0.0 {
        0.0
    } else {
        a.throughput() / tb
    }
}

/// Digest twin of `metrics::har_coherence`: replay the recorded
/// (slot, class) pairs through the same map-then-align algorithm.
fn coherence(a: &CellDigest, b: &CellDigest) -> f64 {
    let (Some(sa), Some(sb)) = (&a.slots, &b.slots) else {
        return 0.0;
    };
    let mut by_slot: HashMap<i64, u64> = HashMap::new();
    for &(slot, class) in sb {
        by_slot.insert(slot, class);
    }
    let mut total = 0usize;
    let mut same = 0usize;
    for &(slot, class) in sa {
        if let Some(&other) = by_slot.get(&slot) {
            total += 1;
            if class == other {
                same += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

/// Digest twin of the batch `state_energy_fraction` column expression.
fn state_fraction(d: &CellDigest) -> f64 {
    let total = d.app_energy + d.state_energy;
    if total == 0.0 {
        0.0
    } else {
        d.state_energy / total
    }
}

/// Per-policy running column sums for the HAR policy projections
/// (figs. 5/7/8). One f64 per rendered column; divided by the unit
/// count at finish.
#[derive(Clone, Copy, Default)]
struct PolicySums {
    accuracy: f64,
    coh_cont: f64,
    coh_chin: f64,
    thr_cont: f64,
    thr_greedy: f64,
    thr_chin: f64,
    same_cycle: f64,
    mean_features: f64,
    state_energy: f64,
}

/// Per-policy running column sums for the audio summary.
#[derive(Clone, Copy, Default)]
struct AudioSums {
    accuracy: f64,
    thr_cont: f64,
    mean_probes: f64,
    same_cycle: f64,
    mean_latency: f64,
}

/// Pooled integer latency histogram for one policy.
#[derive(Clone, Default)]
struct LatencyPool {
    bins: Vec<u64>,
    overflow: u64,
    count: u64,
}

/// The per-projection incremental state. `fold` consumes digests in
/// plan order; `finish` renders through the shared table functions.
enum StreamAcc {
    /// `Projection::Cells`: rows stream straight to the sink.
    Cells,
    /// Figs. 5/7/8: one (policies × seeds) block + per-policy sums.
    HarPolicy { block: Vec<Option<CellDigest>>, sums: Vec<PolicySums> },
    /// Figs. 6/9: per-policy pooled integer bins.
    Latency { pools: Vec<LatencyPool> },
    /// Audio summary: one (policies × seeds) block + per-policy sums.
    Audio { block: Vec<Option<CellDigest>>, sums: Vec<AudioSums> },
    /// Pareto judgement: one pooled digest per policy, O(policies)
    /// state. Each pool adds cells in the policy's plan order — the
    /// identical addition sequence the batch `pareto_rows` uses — so the
    /// folded f64 columns are bitwise equal, not merely close.
    Pareto { pools: Vec<ParetoPool> },
    /// Figs. 13–15: one harvester group + finished trace rows + pooled
    /// per-picture counts.
    Img {
        group: Vec<Option<CellDigest>>,
        trace_rows: Vec<ImgTraceRow>,
        pooled: Vec<(u64, u64)>,
    },
    /// Fleet projections: one row per cell, rendered by the same
    /// `fleet_header`/`fleet_row` pair as the batch table — rows stream
    /// straight to the sink with O(1) state, like `Cells`.
    Fleet,
}

impl StreamAcc {
    fn new(s: &Scenario, sink: &mut dyn Sink) -> io::Result<StreamAcc> {
        let p_n = s.policies.len();
        let s_n = s.seeds.len();
        Ok(match s.projection {
            Projection::Cells => {
                let header: Vec<String> =
                    scenario::CELLS_HEADER.iter().map(|h| h.to_string()).collect();
                sink.begin(&s.name, &s.title, &header)?;
                StreamAcc::Cells
            }
            Projection::PolicyAccuracy
            | Projection::PolicyCoherence
            | Projection::PolicyVsChinchilla => StreamAcc::HarPolicy {
                block: vec![None; p_n * s_n],
                sums: vec![PolicySums::default(); p_n],
            },
            Projection::LatencyEmulation | Projection::LatencyRealWorld => StreamAcc::Latency {
                pools: vec![
                    LatencyPool { bins: vec![0; LATENCY_CYCLES], ..Default::default() };
                    p_n
                ],
            },
            Projection::AudioSummary => StreamAcc::Audio {
                block: vec![None; p_n * s_n],
                sums: vec![AudioSums::default(); p_n],
            },
            Projection::Pareto => StreamAcc::Pareto { pools: vec![ParetoPool::default(); p_n] },
            Projection::ImgEquivalence | Projection::ImgThroughput | Projection::ImgLatency => {
                StreamAcc::Img {
                    group: vec![None; s.devices.len() * p_n * s_n],
                    trace_rows: Vec::new(),
                    pooled: vec![(0, 0); Picture::ALL.len()],
                }
            }
            Projection::FleetLatency
            | Projection::FleetConvergence
            | Projection::FleetBytes => {
                let header: Vec<String> = scenario::fleet_header(s.projection)
                    .iter()
                    .map(|h| h.to_string())
                    .collect();
                sink.begin(&s.name, &s.title, &header)?;
                StreamAcc::Fleet
            }
            Projection::AccuracyCurve | Projection::Perforation => {
                unreachable!("non-campaign projections use the batch fallback")
            }
        })
    }

    fn fold(
        &mut self,
        s: &Scenario,
        idx: usize,
        d: &CellDigest,
        sink: &mut dyn Sink,
    ) -> io::Result<()> {
        let p_n = s.policies.len();
        let s_n = s.seeds.len();
        match self {
            StreamAcc::Cells => sink.row(&cells_row(
                &s.cell_at(idx),
                d.emitted,
                d.power_cycles,
                d.power_failures,
                d.quality(),
                d.same_cycle_fraction(),
                d.app_energy,
                d.state_energy,
            )),
            StreamAcc::HarPolicy { block, sums } => {
                let pos = idx % (p_n * s_n);
                block[pos] = Some(d.clone());
                if pos == p_n * s_n - 1 {
                    flush_har_block(s, block, sums);
                }
                Ok(())
            }
            StreamAcc::Latency { pools } => {
                let p = (idx / s_n) % p_n;
                let lb = d
                    .latency_bins
                    .as_ref()
                    .expect("latency digests carry bins (Needs::for_projection)");
                let pool = &mut pools[p];
                for (dst, &src) in pool.bins.iter_mut().zip(&lb.bins) {
                    *dst += src;
                }
                pool.overflow += lb.overflow;
                pool.count += d.emitted;
                Ok(())
            }
            StreamAcc::Audio { block, sums } => {
                let pos = idx % (p_n * s_n);
                block[pos] = Some(d.clone());
                if pos == p_n * s_n - 1 {
                    flush_audio_block(s, block, sums);
                }
                Ok(())
            }
            StreamAcc::Pareto { pools } => {
                pools[(idx / s_n) % p_n].fold(d);
                Ok(())
            }
            StreamAcc::Img { group, trace_rows, pooled } => {
                let group_len = s.devices.len() * p_n * s_n;
                let pos = idx % group_len;
                // Pool fig. 13's per-picture counts from GREEDY cells as
                // they arrive: integer sums, grouping-independent.
                if s.policies.iter().position(|&q| q == Policy::Greedy)
                    == Some((idx / s_n) % p_n)
                {
                    if let Some(pics) = &d.pictures {
                        for (dst, &(ok, tot)) in pooled.iter_mut().zip(pics) {
                            dst.0 += ok;
                            dst.1 += tot;
                        }
                    }
                }
                group[pos] = Some(d.clone());
                if pos == group_len - 1 {
                    let hi = idx / group_len;
                    trace_rows.push(img_trace_row(s, hi, group));
                }
                Ok(())
            }
            StreamAcc::Fleet => sink.row(&scenario::fleet_row(
                s.projection,
                &s.cell_at(idx),
                d.fleet
                    .as_ref()
                    .expect("fleet digests carry the fleet payload (Needs::for_projection)"),
            )),
        }
    }

    fn finish(&mut self, s: &Scenario, sink: &mut dyn Sink) -> io::Result<()> {
        let name = s.name.as_str();
        let title = s.title.as_str();
        let units = (s.harvesters.len() * s.devices.len() * s.seeds.len()) as f64;
        match self {
            StreamAcc::Cells => sink.finish(),
            StreamAcc::HarPolicy { sums, .. } => {
                let cont = s.policies.iter().position(|&q| q == Policy::Continuous);
                let chin = s.policies.iter().position(|&q| q == Policy::Chinchilla);
                let greedy = s.policies.iter().position(|&q| q == Policy::Greedy);
                // A per-unit mean is its running sum over the unit count;
                // columns against an absent reference are the constant
                // 0.0 the batch path emits, not a folded mean.
                let vs = |present: Option<usize>, sum: f64| match present {
                    Some(_) => sum / units,
                    None => 0.0,
                };
                let rows: Vec<PolicyRow> = s
                    .policies
                    .iter()
                    .zip(sums.iter())
                    .map(|(&policy, m)| PolicyRow {
                        policy,
                        accuracy: m.accuracy / units,
                        coherence_vs_continuous: vs(cont, m.coh_cont),
                        coherence_vs_chinchilla: vs(chin, m.coh_chin),
                        throughput_vs_continuous: vs(cont, m.thr_cont),
                        throughput_vs_greedy: vs(greedy, m.thr_greedy),
                        throughput_vs_chinchilla: vs(chin, m.thr_chin),
                        same_cycle_fraction: m.same_cycle / units,
                        mean_features: m.mean_features / units,
                        state_energy_fraction: m.state_energy / units,
                    })
                    .collect();
                let t = match s.projection {
                    Projection::PolicyAccuracy => policy_accuracy_table(name, title, &rows),
                    Projection::PolicyCoherence => policy_coherence_table(name, title, &rows),
                    _ => policy_vs_chinchilla_table(name, title, &rows),
                };
                sink.table(&t)
            }
            StreamAcc::Latency { pools } => {
                let hists: Vec<(Policy, Histogram)> = s
                    .policies
                    .iter()
                    .zip(pools.iter())
                    .map(|(&policy, pool)| {
                        (
                            policy,
                            Histogram {
                                lo: 0.0,
                                hi: LATENCY_CYCLES as f64,
                                bins: pool.bins.clone(),
                                underflow: 0,
                                overflow: pool.overflow,
                                count: pool.count,
                            },
                        )
                    })
                    .collect();
                let t = match s.projection {
                    Projection::LatencyEmulation => latency_emulation_table(name, title, &hists),
                    _ => latency_real_world_table(name, title, &hists),
                };
                sink.table(&t)
            }
            StreamAcc::Audio { sums, .. } => {
                let cont = s.policies.iter().position(|&q| q == Policy::Continuous);
                let rows: Vec<AudioPolicyRow> = s
                    .policies
                    .iter()
                    .zip(sums.iter())
                    .map(|(&policy, m)| AudioPolicyRow {
                        policy,
                        accuracy: m.accuracy / units,
                        throughput_vs_continuous: match cont {
                            Some(_) => m.thr_cont / units,
                            None => 0.0,
                        },
                        mean_probes: m.mean_probes / units,
                        same_cycle_fraction: m.same_cycle / units,
                        mean_latency_cycles: m.mean_latency / units,
                    })
                    .collect();
                sink.table(&audio_summary_table(name, title, &rows))
            }
            StreamAcc::Pareto { pools } => {
                sink.table(&pareto_table(name, title, &pareto_rows_from_pools(&s.policies, pools)))
            }
            StreamAcc::Img { trace_rows, pooled, .. } => {
                let greedy = s.policies.iter().any(|&q| q == Policy::Greedy);
                let by_picture: Vec<(Picture, f64)> = Picture::ALL
                    .iter()
                    .zip(pooled.iter())
                    .map(|(&p, &(ok, total))| {
                        // No GREEDY axis → the batch path's constant-0
                        // rows; otherwise the pooled integer fraction.
                        let eq = if !greedy || total == 0 {
                            0.0
                        } else {
                            ok as f64 / total as f64
                        };
                        (p, eq)
                    })
                    .collect();
                match s.projection {
                    Projection::ImgEquivalence => {
                        for t in img_equivalence_tables(name, title, &by_picture, trace_rows) {
                            sink.table(&t)?;
                        }
                        Ok(())
                    }
                    Projection::ImgThroughput => {
                        sink.table(&img_throughput_table(name, title, trace_rows))
                    }
                    _ => sink.table(&img_latency_table(name, title, trace_rows)),
                }
            }
            StreamAcc::Fleet => sink.finish(),
        }
    }
}

/// Replay one completed (harvester, device) block in the batch
/// iteration order — for each policy, units (seeds) ascending — adding
/// each column value into its running sum. The addition sequence per
/// column is exactly the batch `stats::mean` fold.
fn flush_har_block(s: &Scenario, block: &mut [Option<CellDigest>], sums: &mut [PolicySums]) {
    let s_n = s.seeds.len();
    let cont = s.policies.iter().position(|&q| q == Policy::Continuous);
    let chin = s.policies.iter().position(|&q| q == Policy::Chinchilla);
    let greedy = s.policies.iter().position(|&q| q == Policy::Greedy);
    {
        let at = |p: usize, u: usize| block[p * s_n + u].as_ref().expect("block is complete");
        for (i, m) in sums.iter_mut().enumerate() {
            for u in 0..s_n {
                let c = at(i, u);
                m.accuracy += c.quality();
                if let Some(r) = cont {
                    m.coh_cont += coherence(c, at(r, u));
                    m.thr_cont += thr_ratio(c, at(r, u));
                }
                if let Some(r) = chin {
                    m.coh_chin += coherence(c, at(r, u));
                    m.thr_chin += thr_ratio(c, at(r, u));
                }
                if let Some(r) = greedy {
                    m.thr_greedy += thr_ratio(c, at(r, u));
                }
                m.same_cycle += c.same_cycle_fraction();
                m.mean_features += c.mean_over_emitted(c.steps_sum);
                m.state_energy += state_fraction(c);
            }
        }
    }
    block.iter_mut().for_each(|slot| *slot = None);
}

/// Audio twin of [`flush_har_block`].
fn flush_audio_block(s: &Scenario, block: &mut [Option<CellDigest>], sums: &mut [AudioSums]) {
    let s_n = s.seeds.len();
    let cont = s.policies.iter().position(|&q| q == Policy::Continuous);
    {
        let at = |p: usize, u: usize| block[p * s_n + u].as_ref().expect("block is complete");
        for (i, m) in sums.iter_mut().enumerate() {
            for u in 0..s_n {
                let c = at(i, u);
                m.accuracy += c.quality();
                if let Some(r) = cont {
                    m.thr_cont += thr_ratio(c, at(r, u));
                }
                m.mean_probes += c.mean_over_emitted(c.steps_sum);
                m.same_cycle += c.same_cycle_fraction();
                m.mean_latency += c.mean_over_emitted(c.latency_sum);
            }
        }
    }
    block.iter_mut().for_each(|slot| *slot = None);
}

/// Compute one harvester's fig. 13–15 row from its completed group —
/// the digest twin of `SweepRun::img_trace_rows` for harvester `hi`.
fn img_trace_row(s: &Scenario, hi: usize, group: &mut [Option<CellDigest>]) -> ImgTraceRow {
    let (d_n, p_n, s_n) = (s.devices.len(), s.policies.len(), s.seeds.len());
    let cont = s.policies.iter().position(|&q| q == Policy::Continuous);
    let chin = s.policies.iter().position(|&q| q == Policy::Chinchilla);
    let greedy = s.policies.iter().position(|&q| q == Policy::Greedy);
    let local_units = d_n * s_n;
    let row = {
        let at = |p: usize, lu: usize| {
            let d = lu / s_n;
            let sd = lu % s_n;
            group[(d * p_n + p) * s_n + sd].as_ref().expect("group is complete")
        };
        let per = |f: &dyn Fn(usize) -> f64| {
            let mut sum = 0.0;
            for lu in 0..local_units {
                sum += f(lu);
            }
            sum / local_units as f64
        };
        let ratio_of = |a: Option<usize>, b: Option<usize>| match (a, b) {
            (Some(a), Some(b)) => per(&|u| thr_ratio(at(a, u), at(b, u))),
            _ => 0.0,
        };
        ImgTraceRow {
            harvester: s.harvesters[hi].clone(),
            equivalence_aic: greedy.map(|g| per(&|u| at(g, u).quality())).unwrap_or(0.0),
            throughput_aic_vs_continuous: ratio_of(greedy, cont),
            throughput_chinchilla_vs_continuous: ratio_of(chin, cont),
            aic_same_cycle: greedy
                .map(|g| per(&|u| at(g, u).same_cycle_fraction()))
                .unwrap_or(0.0),
            chinchilla_latency_mean: chin
                .map(|c| per(&|u| {
                    let d = at(c, u);
                    d.mean_over_emitted(d.latency_sum)
                }))
                .unwrap_or(0.0),
        }
    };
    group.iter_mut().for_each(|slot| *slot = None);
    row
}
