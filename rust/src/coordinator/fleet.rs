//! Device-fleet orchestration.
//!
//! The paper manufactures 12 identical prototypes and runs 15 volunteers
//! across 24 days. Fleet runs parallelise that across OS threads: each
//! (volunteer, policy) pair is one independent simulated device; the
//! coordinator joins the results deterministically (ordering never
//! depends on thread scheduling).

use crate::coordinator::experiment::{run_har_policy, HarContext, HarRunSpec};
use crate::exec::{Campaign, Policy};
use crate::har::app::HarOutput;

/// One fleet assignment: a simulated device on a volunteer's wrist.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub volunteer: u64,
    pub policy: Policy,
}

/// Run all assignments in parallel (bounded by available cores via the
/// OS scheduler; each campaign is single-threaded and independent).
pub fn run_har_fleet(
    ctx: &HarContext,
    spec: &HarRunSpec,
    assignments: &[Assignment],
) -> Vec<Campaign<HarOutput>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = assignments
            .iter()
            .map(|a| {
                let spec = HarRunSpec { script_seed: a.volunteer, ..spec.clone() };
                let policy = a.policy;
                scope.spawn(move || run_har_policy(ctx, &spec, policy))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("device thread panicked")).collect()
    })
}

/// The paper's §5.3 wrist setup: per volunteer, one device under `policy`
/// and one continuous reference on the same motion (same script seed).
pub fn wrist_pairs(volunteers: &[u64], policy: Policy) -> Vec<Assignment> {
    volunteers
        .iter()
        .flat_map(|&v| {
            [
                Assignment { volunteer: v, policy },
                Assignment { volunteer: v, policy: Policy::Continuous },
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::test_context;

    #[test]
    fn fleet_runs_match_sequential_runs() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 900.0, ..Default::default() };
        let assignments = vec![
            Assignment { volunteer: 1, policy: Policy::Greedy },
            Assignment { volunteer: 2, policy: Policy::Greedy },
        ];
        let fleet = run_har_fleet(&ctx, &spec, &assignments);
        assert_eq!(fleet.len(), 2);
        // Determinism: a sequential run of the same assignment agrees.
        let solo = run_har_policy(
            &ctx,
            &HarRunSpec { script_seed: 1, ..spec.clone() },
            Policy::Greedy,
        );
        assert_eq!(fleet[0].rounds.len(), solo.rounds.len());
        assert_eq!(fleet[0].power_cycles, solo.power_cycles);
    }

    #[test]
    fn wrist_pairs_shape() {
        let pairs = wrist_pairs(&[10, 11], Policy::Greedy);
        assert_eq!(pairs.len(), 4);
        assert_eq!(pairs[0].volunteer, 10);
        assert_eq!(pairs[1].policy, Policy::Continuous);
    }
}
