//! Device-fleet orchestration.
//!
//! The paper manufactures 12 identical prototypes and runs 15 volunteers
//! across 24 days. Fleet runs parallelise that: each job (one scenario
//! grid cell — a volunteer's wrist device, an imaging device on an
//! energy trace) is one independent simulated device, executed on a
//! **bounded worker pool** capped at the machine's available
//! parallelism. Results are returned **in job order** — never in
//! completion order — so fleet output is deterministic whatever the
//! pool size or thread scheduling. The scenario layer
//! (`coordinator/scenario.rs`) expands every sweep into a job plan and
//! dispatches it here; there is no per-workload fleet wiring anymore.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The pool cap: one worker per available core, overridable with
/// `AIC_WORKERS` (useful for CI smoke runs and contention experiments —
/// results are identical for any pool size, see [`run_fleet`]).
pub fn max_workers() -> usize {
    if let Some(n) = std::env::var("AIC_WORKERS").ok().and_then(|s| s.parse::<usize>().ok())
    {
        if n > 0 {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// The pool size `run_fleet` will actually spawn for `requested` workers
/// over `jobs` jobs.
///
/// An explicit request wins: `Some(n)` yields a pool of `max(n, 1)`
/// threads (capped only by the job count — more threads than jobs would
/// just idle). The `AIC_WORKERS` / core-count cap from [`max_workers`]
/// applies **only** to the default `None` path; a caller asserting
/// "run this with 8 workers" (e.g. a determinism gate sweeping pool
/// sizes) must get 8 even when the environment pins the default to 2.
pub fn resolve_workers(requested: Option<usize>, jobs: usize) -> usize {
    requested.unwrap_or_else(max_workers).max(1).min(jobs.max(1))
}

/// Run `run` over every job on a bounded worker pool and return the
/// results **in job order**.
///
/// `workers` requests a pool size, realised by [`resolve_workers`]: an
/// explicit `Some(n)` is honoured as-is (never env-clamped), `None`
/// falls back to the `AIC_WORKERS` / core-count default. Workers
/// pull job indices from a shared counter, so an expensive job never
/// head-of-line-blocks the rest of the fleet; each result lands in the
/// slot of its job index, which makes the output independent of both the
/// pool size and the OS scheduler.
pub fn run_fleet<J, T, F>(jobs: &[J], workers: Option<usize>, run: F) -> Vec<T>
where
    J: Sync,
    T: Send,
    F: Fn(&J) -> T + Sync,
{
    let workers = resolve_workers(workers, jobs.len());
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let result = run(&jobs[i]);
                *slots[i].lock().expect("fleet slot poisoned") = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("fleet slot poisoned")
                .expect("fleet job did not complete")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::{
        run_har_policy, run_img_policy, test_context, HarRunSpec, ImgRunSpec,
    };
    use crate::energy::traces::TraceKind;
    use crate::exec::Policy;

    #[test]
    fn pool_preserves_job_order_for_any_worker_count() {
        let jobs: Vec<usize> = (0..37).collect();
        let reference: Vec<usize> = jobs.iter().map(|j| j * j).collect();
        for workers in [1, 2, 3, 8, 64] {
            let got = run_fleet(&jobs, Some(workers), |&j| j * j);
            assert_eq!(got, reference, "workers={workers}");
        }
    }

    /// Regression: an explicit worker request used to be clamped to
    /// `max_workers()`, which reads `AIC_WORKERS` — so with the CI pin
    /// `AIC_WORKERS=2`, gates claiming "workers ∈ {1,2,8}" silently
    /// exercised pool size 2 three times. `Some(n)` must win over env.
    #[test]
    fn explicit_worker_requests_beat_the_env_cap() {
        let saved = std::env::var("AIC_WORKERS").ok();
        std::env::set_var("AIC_WORKERS", "2");
        let resolved = resolve_workers(Some(8), 100);
        let default = resolve_workers(None, 100);
        // Restore before asserting so a failure can't leak the pin into
        // other tests (results are pool-size independent anyway).
        match saved {
            Some(v) => std::env::set_var("AIC_WORKERS", v),
            None => std::env::remove_var("AIC_WORKERS"),
        }
        assert_eq!(resolved, 8, "explicit Some(8) was clamped by AIC_WORKERS");
        assert_eq!(default, 2, "None must still take the env default");
    }

    #[test]
    fn resolved_pool_never_exceeds_jobs_and_never_hits_zero() {
        assert_eq!(resolve_workers(Some(8), 3), 3, "more threads than jobs just idle");
        assert_eq!(resolve_workers(Some(0), 10), 1, "a zero request still runs");
        assert_eq!(resolve_workers(Some(5), 0), 1, "empty plans keep a worker");
    }

    /// The realised pool really spawns what was requested: each job
    /// parks until all `n` workers have checked in, so any clamp below
    /// `n` would deadlock (caught by the watchdog) instead of passing
    /// silently.
    #[test]
    fn explicit_pool_size_is_realised_by_run_fleet() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Condvar;
        let n = 4usize;
        let jobs: Vec<usize> = (0..n).collect();
        let arrivals = Mutex::new(0usize);
        let all_in = Condvar::new();
        let failed = AtomicBool::new(false);
        let got = run_fleet(&jobs, Some(n), |&j| {
            let mut count = arrivals.lock().unwrap();
            *count += 1;
            if *count == n {
                all_in.notify_all();
            } else {
                // Wait for the other workers; a pool smaller than n can
                // never fill the barrier, so time out and flag instead
                // of hanging the suite.
                let deadline = std::time::Duration::from_secs(10);
                while *count < n {
                    let (guard, timeout) = all_in.wait_timeout(count, deadline).unwrap();
                    count = guard;
                    if timeout.timed_out() {
                        failed.store(true, Ordering::Relaxed);
                        break;
                    }
                }
            }
            j
        });
        assert!(
            !failed.load(Ordering::Relaxed),
            "run_fleet(Some({n})) realised a smaller pool: {n} jobs never ran concurrently"
        );
        assert_eq!(got, jobs);
    }

    #[test]
    fn pool_handles_empty_job_lists() {
        let got: Vec<usize> = run_fleet(&[] as &[usize], None, |&j| j);
        assert!(got.is_empty());
    }

    #[test]
    fn fleet_runs_match_sequential_runs() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 900.0, ..Default::default() };
        let jobs = [(1u64, Policy::Greedy), (2u64, Policy::Greedy)];
        let fleet = run_fleet(&jobs, None, |&(v, p)| {
            run_har_policy(&ctx, &HarRunSpec { script_seed: v, ..spec.clone() }, p)
        });
        assert_eq!(fleet.len(), 2);
        // Determinism: a sequential run of the same cell agrees.
        let solo = run_har_policy(
            &ctx,
            &HarRunSpec { script_seed: 1, ..spec.clone() },
            Policy::Greedy,
        );
        assert_eq!(fleet[0].rounds.len(), solo.rounds.len());
        assert_eq!(fleet[0].power_cycles, solo.power_cycles);
    }

    #[test]
    fn img_fleet_has_har_parity() {
        let spec = ImgRunSpec { horizon: 400.0, ..Default::default() };
        let jobs = [(TraceKind::Som, Policy::Greedy), (TraceKind::Rf, Policy::Greedy)];
        let fleet = run_fleet(&jobs, None, |&(t, p)| run_img_policy(&spec, t, p));
        assert_eq!(fleet.len(), 2);
        // Deterministic twin of the sequential run.
        let solo = run_img_policy(&spec, TraceKind::Som, Policy::Greedy);
        assert_eq!(fleet[0].rounds.len(), solo.rounds.len());
        assert_eq!(fleet[0].power_cycles, solo.power_cycles);
    }
}
