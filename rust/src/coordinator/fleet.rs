//! Device-fleet orchestration.
//!
//! The paper manufactures 12 identical prototypes and runs 15 volunteers
//! across 24 days. Fleet runs parallelise that: each job (one scenario
//! grid cell — a volunteer's wrist device, an imaging device on an
//! energy trace) is one independent simulated device, executed on a
//! **bounded worker pool** capped at the machine's available
//! parallelism. Results are returned **in job order** — never in
//! completion order — so fleet output is deterministic whatever the
//! pool size or thread scheduling. The scenario layer
//! (`coordinator/scenario.rs`) expands every sweep into a job plan and
//! dispatches it here; there is no per-workload fleet wiring anymore.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The pool cap: one worker per available core, overridable with
/// `AIC_WORKERS` (useful for CI smoke runs and contention experiments —
/// results are identical for any pool size, see [`run_fleet`]).
pub fn max_workers() -> usize {
    if let Some(n) = std::env::var("AIC_WORKERS").ok().and_then(|s| s.parse::<usize>().ok())
    {
        if n > 0 {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `run` over every job on a bounded worker pool and return the
/// results **in job order**.
///
/// `workers` requests a pool size; it is clamped to
/// `[1, available_parallelism]` and never exceeds the job count. Workers
/// pull job indices from a shared counter, so an expensive job never
/// head-of-line-blocks the rest of the fleet; each result lands in the
/// slot of its job index, which makes the output independent of both the
/// pool size and the OS scheduler.
pub fn run_fleet<J, T, F>(jobs: &[J], workers: Option<usize>, run: F) -> Vec<T>
where
    J: Sync,
    T: Send,
    F: Fn(&J) -> T + Sync,
{
    let cap = max_workers();
    let workers = workers.unwrap_or(cap).clamp(1, cap).min(jobs.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let result = run(&jobs[i]);
                *slots[i].lock().expect("fleet slot poisoned") = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("fleet slot poisoned")
                .expect("fleet job did not complete")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::{
        run_har_policy, run_img_policy, test_context, HarRunSpec, ImgRunSpec,
    };
    use crate::energy::traces::TraceKind;
    use crate::exec::Policy;

    #[test]
    fn pool_preserves_job_order_for_any_worker_count() {
        let jobs: Vec<usize> = (0..37).collect();
        let reference: Vec<usize> = jobs.iter().map(|j| j * j).collect();
        for workers in [1, 2, 3, 8, 64] {
            let got = run_fleet(&jobs, Some(workers), |&j| j * j);
            assert_eq!(got, reference, "workers={workers}");
        }
    }

    #[test]
    fn pool_handles_empty_job_lists() {
        let got: Vec<usize> = run_fleet(&[] as &[usize], None, |&j| j);
        assert!(got.is_empty());
    }

    #[test]
    fn fleet_runs_match_sequential_runs() {
        let ctx = test_context();
        let spec = HarRunSpec { horizon: 900.0, ..Default::default() };
        let jobs = [(1u64, Policy::Greedy), (2u64, Policy::Greedy)];
        let fleet = run_fleet(&jobs, None, |&(v, p)| {
            run_har_policy(&ctx, &HarRunSpec { script_seed: v, ..spec.clone() }, p)
        });
        assert_eq!(fleet.len(), 2);
        // Determinism: a sequential run of the same cell agrees.
        let solo = run_har_policy(
            &ctx,
            &HarRunSpec { script_seed: 1, ..spec.clone() },
            Policy::Greedy,
        );
        assert_eq!(fleet[0].rounds.len(), solo.rounds.len());
        assert_eq!(fleet[0].power_cycles, solo.power_cycles);
    }

    #[test]
    fn img_fleet_has_har_parity() {
        let spec = ImgRunSpec { horizon: 400.0, ..Default::default() };
        let jobs = [(TraceKind::Som, Policy::Greedy), (TraceKind::Rf, Policy::Greedy)];
        let fleet = run_fleet(&jobs, None, |&(t, p)| run_img_policy(&spec, t, p));
        assert_eq!(fleet.len(), 2);
        // Deterministic twin of the sequential run.
        let solo = run_img_policy(&spec, TraceKind::Som, Policy::Greedy);
        assert_eq!(fleet[0].rounds.len(), solo.rounds.len());
        assert_eq!(fleet[0].power_cycles, solo.power_cycles);
    }
}
