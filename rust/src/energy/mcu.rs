//! MSP430-class MCU energy cost model.
//!
//! Single source of truth for the energy charged to the capacitor by any
//! operation anywhere in the simulator. Both the offline estimator (which
//! builds SMART's lookup tables) and the online engine consume this model,
//! mirroring the paper's structure where EPIC profiles the same firmware
//! the device runs.
//!
//! Constants are derived from the MSP430FR5969 datasheet family the paper
//! cites [33] and the peripherals of the prototype (§4.1): ADXL362
//! accelerometer, L3GD20H gyroscope, nRF51822 BLE, LTC1417 ADC. They are
//! deliberately configuration, not code: the figure benches sweep them.

/// Resource usage of one atomic operation (the estimator's cost vector).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    /// CPU cycles executed from SRAM.
    pub cycles: u64,
    /// 16-bit words read from FRAM.
    pub fram_reads: u64,
    /// 16-bit words written to FRAM.
    pub fram_writes: u64,
    /// Supply-voltage ADC conversions (SMART's energy introspection).
    pub adc_reads: u64,
    /// Bytes transmitted over BLE (result emission).
    pub ble_bytes: u64,
    /// Seconds of sensor acquisition (accelerometer + gyro active).
    pub sensor_secs: f64,
}

impl OpCost {
    pub fn cycles(n: u64) -> OpCost {
        OpCost { cycles: n, ..Default::default() }
    }

    /// Sum of two cost vectors.
    pub fn plus(&self, other: &OpCost) -> OpCost {
        OpCost {
            cycles: self.cycles + other.cycles,
            fram_reads: self.fram_reads + other.fram_reads,
            fram_writes: self.fram_writes + other.fram_writes,
            adc_reads: self.adc_reads + other.adc_reads,
            ble_bytes: self.ble_bytes + other.ble_bytes,
            sensor_secs: self.sensor_secs + other.sensor_secs,
        }
    }

    pub fn scaled(&self, k: u64) -> OpCost {
        OpCost {
            cycles: self.cycles * k,
            fram_reads: self.fram_reads * k,
            fram_writes: self.fram_writes * k,
            adc_reads: self.adc_reads * k,
            ble_bytes: self.ble_bytes * k,
            sensor_secs: self.sensor_secs * k as f64,
        }
    }
}

/// The MCU + peripherals energy/time model.
#[derive(Clone, Debug)]
pub struct McuModel {
    /// Core clock in Hz. The paper clocks at 8 MHz so FRAM needs no wait
    /// states; above `fram_wait_free_hz` every FRAM access pays
    /// `fram_wait_penalty` extra cycles.
    pub clock_hz: f64,
    /// Active-mode energy per CPU cycle, joules (I_active · V / f).
    pub energy_per_cycle: f64,
    /// Energy per 16-bit FRAM read, beyond the cycle cost.
    pub fram_read_energy: f64,
    /// Energy per 16-bit FRAM write, beyond the cycle cost. FRAM writes
    /// are the dominant NVM cost (the paper's "energy-hungry NVM").
    pub fram_write_energy: f64,
    /// Cycles per FRAM access added when clocked above `fram_wait_free_hz`.
    pub fram_wait_penalty: u64,
    /// Highest clock at which FRAM accesses take no wait states (8 MHz).
    pub fram_wait_free_hz: f64,
    /// Energy per supply-voltage ADC conversion (LTC1417 read).
    pub adc_read_energy: f64,
    /// Energy per BLE byte on air, including fixed per-packet overhead
    /// folded in (nRF51822 at 0 dBm).
    pub ble_byte_energy: f64,
    /// Fixed per-packet BLE cost (radio ramp-up, connection event).
    pub ble_packet_energy: f64,
    /// Sensor acquisition power, watts (ADXL362 + duty-cycled L3GD20H).
    pub sensor_power: f64,
    /// Sleep (LPM3) power, watts — drawn whenever the device idles alive.
    pub sleep_power: f64,
    /// Energy consumed by one reboot (supervisor + runtime init), J.
    pub boot_energy: f64,
}

impl McuModel {
    /// The paper's configuration: MSP430FR5969-class at 8 MHz (no FRAM
    /// wait states — the best case for the Chinchilla baseline, §5).
    pub fn paper_default() -> McuModel {
        McuModel {
            clock_hz: 8e6,
            // ~103 µA/MHz at 3.0 V → 0.82 mA, 2.47 mW, 0.31 nJ/cycle.
            energy_per_cycle: 0.31e-9,
            // FRAM access energy beyond CPU cycles; writes dominate.
            // System-level measured costs (controller, cache-miss and
            // burst overheads) exceed cell-level datasheet numbers —
            // the "missing joules" effect EPIC [2] documents.
            fram_read_energy: 3.0e-9,
            fram_write_energy: 12.0e-9,
            fram_wait_penalty: 1,
            fram_wait_free_hz: 8e6,
            adc_read_energy: 0.18e-6,
            ble_byte_energy: 1.1e-6,
            ble_packet_energy: 46e-6,
            // ADXL362 (1.8 µA) + L3GD20H FIFO-batched & duty-cycled to
            // ~1/40 (≈0.15 mA) at 3 V: a 2.56 s window costs ~1.3 mJ,
            // comfortably inside one buffer charge (acquisition must fit
            // a single cycle under every runtime, incl. the paper's).
            sensor_power: 0.5e-3,
            sleep_power: 1.4e-6,
            boot_energy: 18e-6,
        }
    }

    /// Energy in joules for one cost vector.
    pub fn energy(&self, cost: &OpCost) -> f64 {
        let wait_cycles = if self.clock_hz > self.fram_wait_free_hz {
            (cost.fram_reads + cost.fram_writes) * self.fram_wait_penalty
        } else {
            0
        };
        (cost.cycles + wait_cycles) as f64 * self.energy_per_cycle
            + cost.fram_reads as f64 * self.fram_read_energy
            + cost.fram_writes as f64 * self.fram_write_energy
            + cost.adc_reads as f64 * self.adc_read_energy
            + cost.ble_bytes as f64 * self.ble_byte_energy
            + if cost.ble_bytes > 0 { self.ble_packet_energy } else { 0.0 }
            + cost.sensor_secs * self.sensor_power
    }

    /// Wall-clock seconds for one cost vector (CPU + radio + sensor time).
    pub fn duration(&self, cost: &OpCost) -> f64 {
        let wait_cycles = if self.clock_hz > self.fram_wait_free_hz {
            (cost.fram_reads + cost.fram_writes) * self.fram_wait_penalty
        } else {
            0
        };
        // BLE: ~1 Mbps on air plus ~1.2 ms per-packet overhead.
        let ble_secs = if cost.ble_bytes > 0 {
            1.2e-3 + cost.ble_bytes as f64 * 8e-6
        } else {
            0.0
        };
        (cost.cycles + wait_cycles) as f64 / self.clock_hz
            + cost.adc_reads as f64 * 8e-6
            + ble_secs
            + cost.sensor_secs
    }

    /// Energy to idle alive for `secs` in LPM3.
    pub fn sleep_energy(&self, secs: f64) -> f64 {
        self.sleep_power * secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_compute_cost() {
        let m = McuModel::paper_default();
        let e = m.energy(&OpCost::cycles(1_000_000));
        assert!((e - 0.31e-3).abs() < 1e-12);
        let t = m.duration(&OpCost::cycles(8_000_000));
        assert!((t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fram_writes_cost_more_than_reads() {
        let m = McuModel::paper_default();
        let r = m.energy(&OpCost { fram_reads: 100, ..Default::default() });
        let w = m.energy(&OpCost { fram_writes: 100, ..Default::default() });
        assert!(w > 2.0 * r);
    }

    #[test]
    fn no_wait_states_at_8mhz() {
        let m = McuModel::paper_default();
        let cost = OpCost { cycles: 100, fram_reads: 50, ..Default::default() };
        assert!((m.duration(&cost) - 100.0 / 8e6).abs() < 1e-15);

        let mut fast = McuModel::paper_default();
        fast.clock_hz = 16e6;
        // At 16 MHz each FRAM access pays a wait cycle.
        assert!((fast.duration(&cost) - 150.0 / 16e6).abs() < 1e-15);
        assert!(fast.energy(&cost) > m.energy(&cost) - 1e-15);
    }

    #[test]
    fn ble_packet_overhead_charged_once() {
        let m = McuModel::paper_default();
        let one = m.energy(&OpCost { ble_bytes: 1, ..Default::default() });
        let twenty = m.energy(&OpCost { ble_bytes: 20, ..Default::default() });
        assert!(one > m.ble_packet_energy);
        assert!(twenty - one < 20.0 * m.ble_byte_energy);
    }

    #[test]
    fn cost_vector_algebra() {
        let a = OpCost { cycles: 10, fram_reads: 1, ..Default::default() };
        let b = OpCost { cycles: 5, ble_bytes: 2, ..Default::default() };
        let s = a.plus(&b);
        assert_eq!(s.cycles, 15);
        assert_eq!(s.fram_reads, 1);
        assert_eq!(s.ble_bytes, 2);
        let d = a.scaled(3);
        assert_eq!(d.cycles, 30);
        assert_eq!(d.fram_reads, 3);
    }

    #[test]
    fn sleep_energy_scales_linearly() {
        let m = McuModel::paper_default();
        assert!((m.sleep_energy(60.0) - 60.0 * 1.4e-6).abs() < 1e-15);
    }
}
