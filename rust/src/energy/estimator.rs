//! Offline energy estimation (the paper's EPIC role, §4.2/§6.2).
//!
//! Given the per-step cost vectors of a pipeline and the MCU model, the
//! estimator produces the tables the run-time policies consult:
//!
//! * cumulative energy to execute the first `k` steps (GREEDY's
//!   look-ahead: "is there just enough left to emit?"),
//! * for the SMART policy, the map from a user accuracy bound `A` to the
//!   minimum number of features `p'` whose *expected* accuracy (from the
//!   Eq. 7 analysis or a measured curve) meets `A`, together with the
//!   energy needed to process those `p'` features and emit.
//!
//! The estimator runs offline on the same cost model the engine charges
//! online, mirroring the paper's setup where EPIC profiles the firmware
//! that later runs on the device.

use crate::energy::mcu::{McuModel, OpCost};

/// Energy profile of a step pipeline.
#[derive(Clone, Debug)]
pub struct EnergyProfile {
    /// Energy of each step, joules.
    pub step_energy: Vec<f64>,
    /// `cumulative[k]` = energy of steps `0..k` (so `[0] == 0`).
    pub cumulative: Vec<f64>,
    /// Duration of each step, seconds.
    pub step_duration: Vec<f64>,
}

impl EnergyProfile {
    /// Profile a pipeline described by per-step cost vectors.
    pub fn from_costs(mcu: &McuModel, costs: &[OpCost]) -> EnergyProfile {
        let step_energy: Vec<f64> = costs.iter().map(|c| mcu.energy(c)).collect();
        let step_duration: Vec<f64> = costs.iter().map(|c| mcu.duration(c)).collect();
        let mut cumulative = Vec::with_capacity(costs.len() + 1);
        let mut acc = 0.0;
        cumulative.push(0.0);
        for &e in &step_energy {
            acc += e;
            cumulative.push(acc);
        }
        EnergyProfile { step_energy, cumulative, step_duration }
    }

    /// Total pipeline energy.
    pub fn total(&self) -> f64 {
        *self.cumulative.last().unwrap_or(&0.0)
    }

    /// Energy of steps `from..to`.
    pub fn span(&self, from: usize, to: usize) -> f64 {
        self.cumulative[to] - self.cumulative[from]
    }

    /// Largest `k` such that steps `0..k` plus `reserve` fit in `budget`.
    pub fn max_steps_within(&self, budget: f64, reserve: f64) -> usize {
        // cumulative is sorted; binary search for budget - reserve.
        let avail = budget - reserve;
        if avail < 0.0 {
            return 0;
        }
        match self
            .cumulative
            .binary_search_by(|e| e.partial_cmp(&avail).unwrap())
        {
            Ok(k) => k,
            Err(ins) => ins.saturating_sub(1),
        }
    }
}

/// SMART's offline lookup table: accuracy bound → (p', energy incl. emit).
#[derive(Clone, Debug)]
pub struct SmartTable {
    /// `expected_accuracy[p]` for classifications using `p` features
    /// (p = 0..=n), from the Eq. 7 analysis or an emulation sweep.
    pub expected_accuracy: Vec<f64>,
    /// Cumulative energy to process the first `p` features.
    pub cumulative_energy: Vec<f64>,
    /// Energy to emit the result (BLE packet).
    pub emit_energy: f64,
}

impl SmartTable {
    pub fn new(expected_accuracy: Vec<f64>, profile: &EnergyProfile, emit_energy: f64) -> SmartTable {
        assert_eq!(expected_accuracy.len(), profile.cumulative.len());
        SmartTable {
            expected_accuracy,
            cumulative_energy: profile.cumulative.clone(),
            emit_energy,
        }
    }

    /// Minimum feature count whose expected accuracy meets `bound`
    /// (None if even all features fall short).
    pub fn min_features_for(&self, bound: f64) -> Option<usize> {
        self.expected_accuracy.iter().position(|&a| a >= bound)
    }

    /// Energy required to meet `bound`: features plus the final emission.
    pub fn energy_for(&self, bound: f64) -> Option<f64> {
        self.min_features_for(bound)
            .map(|p| self.cumulative_energy[p] + self.emit_energy)
    }

    /// SMART's gate: can the current budget deliver accuracy >= bound?
    pub fn feasible(&self, budget: f64, bound: f64) -> Option<usize> {
        let p = self.min_features_for(bound)?;
        if self.cumulative_energy[p] + self.emit_energy <= budget {
            Some(p)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mcu() -> McuModel {
        McuModel::paper_default()
    }

    fn costs(n: usize) -> Vec<OpCost> {
        (0..n).map(|i| OpCost::cycles(1000 * (i as u64 + 1))).collect()
    }

    #[test]
    fn cumulative_is_prefix_sum() {
        let p = EnergyProfile::from_costs(&mcu(), &costs(4));
        assert_eq!(p.cumulative.len(), 5);
        assert_eq!(p.cumulative[0], 0.0);
        for k in 1..=4 {
            let direct: f64 = p.step_energy[..k].iter().sum();
            assert!((p.cumulative[k] - direct).abs() < 1e-18);
        }
        assert!((p.span(1, 3) - (p.step_energy[1] + p.step_energy[2])).abs() < 1e-18);
    }

    #[test]
    fn max_steps_within_budget() {
        let p = EnergyProfile::from_costs(&mcu(), &costs(4));
        assert_eq!(p.max_steps_within(p.total() + 1e-9, 0.0), 4);
        assert_eq!(p.max_steps_within(p.cumulative[2] + 1e-15, 0.0), 2);
        assert_eq!(p.max_steps_within(0.0, 0.0), 0);
        assert_eq!(p.max_steps_within(1.0, 2.0), 0); // reserve exceeds budget
        // Reserve shaves off the last step.
        let reserve = p.step_energy[3];
        assert!(p.max_steps_within(p.total(), reserve + 1e-15) < 4);
    }

    #[test]
    fn smart_table_lookup() {
        let profile = EnergyProfile::from_costs(&mcu(), &costs(4));
        let acc = vec![0.166, 0.5, 0.7, 0.82, 0.88];
        let t = SmartTable::new(acc, &profile, 50e-6);
        assert_eq!(t.min_features_for(0.8), Some(3));
        assert_eq!(t.min_features_for(0.95), None);
        let e = t.energy_for(0.8).unwrap();
        assert!((e - (profile.cumulative[3] + 50e-6)).abs() < 1e-15);
        // Feasibility gate.
        assert_eq!(t.feasible(e + 1e-9, 0.8), Some(3));
        assert_eq!(t.feasible(e - 1e-6, 0.8), None);
    }
}
