//! Offline energy estimation (the paper's EPIC role, §4.2/§6.2).
//!
//! Given the per-step cost vectors of a pipeline and the MCU model, the
//! estimator produces the tables the run-time policies consult:
//!
//! * cumulative energy to execute the first `k` steps (GREEDY's
//!   look-ahead: "is there just enough left to emit?"),
//! * for the SMART policy, the map from a user accuracy bound `A` to the
//!   minimum number of features `p'` whose *expected* accuracy (from the
//!   Eq. 7 analysis or a measured curve) meets `A`, together with the
//!   energy needed to process those `p'` features and emit.
//!
//! The estimator runs offline on the same cost model the engine charges
//! online, mirroring the paper's setup where EPIC profiles the firmware
//! that later runs on the device.

use crate::energy::mcu::{McuModel, OpCost};

/// Energy profile of a step pipeline.
#[derive(Clone, Debug)]
pub struct EnergyProfile {
    /// Energy of each step, joules.
    pub step_energy: Vec<f64>,
    /// `cumulative[k]` = energy of steps `0..k` (so `[0] == 0`).
    pub cumulative: Vec<f64>,
    /// Duration of each step, seconds.
    pub step_duration: Vec<f64>,
}

impl EnergyProfile {
    /// Profile a pipeline described by per-step cost vectors.
    pub fn from_costs(mcu: &McuModel, costs: &[OpCost]) -> EnergyProfile {
        let step_energy: Vec<f64> = costs.iter().map(|c| mcu.energy(c)).collect();
        let step_duration: Vec<f64> = costs.iter().map(|c| mcu.duration(c)).collect();
        let mut cumulative = Vec::with_capacity(costs.len() + 1);
        let mut acc = 0.0;
        cumulative.push(0.0);
        for &e in &step_energy {
            acc += e;
            cumulative.push(acc);
        }
        EnergyProfile { step_energy, cumulative, step_duration }
    }

    /// Total pipeline energy.
    pub fn total(&self) -> f64 {
        *self.cumulative.last().unwrap_or(&0.0)
    }

    /// Energy of steps `from..to`.
    pub fn span(&self, from: usize, to: usize) -> f64 {
        self.cumulative[to] - self.cumulative[from]
    }

    /// Largest `k` such that steps `0..k` plus `reserve` fit in `budget`.
    ///
    /// Total on any input: a non-finite or negative available budget
    /// (NaN/Inf can reach this from hostile scenario JSON via device-spec
    /// knobs, and `Inf - Inf` is NaN) affords zero steps rather than
    /// panicking. Tied prefix sums — zero-energy steps, e.g. perforated
    /// spans priced at 0 — resolve to the *largest* matching `k`, so a
    /// free step is never refused.
    pub fn max_steps_within(&self, budget: f64, reserve: f64) -> usize {
        let avail = budget - reserve;
        // `!(x >= 0)` also catches NaN, which every ordering comparison
        // answers `false` to; a plain `< 0.0` would fall through into the
        // search below and (before this guard) panic in `partial_cmp`.
        if !(avail >= 0.0) {
            return 0;
        }
        // `cumulative` is non-decreasing (step energies are >= 0), so the
        // prefix with `e <= avail` is exactly the affordable prefix; its
        // length minus one is the largest affordable step count. A binary
        // search's `Ok(k)` would be an arbitrary index among tied entries,
        // under-reporting the affordable count.
        self.cumulative.partition_point(|&e| e <= avail).saturating_sub(1)
    }
}

/// SMART's offline lookup table: accuracy bound → (p', energy incl. emit).
#[derive(Clone, Debug)]
pub struct SmartTable {
    /// `expected_accuracy[p]` for classifications using `p` features
    /// (p = 0..=n), from the Eq. 7 analysis or an emulation sweep.
    pub expected_accuracy: Vec<f64>,
    /// Cumulative energy to process the first `p` features.
    pub cumulative_energy: Vec<f64>,
    /// Energy to emit the result (BLE packet).
    pub emit_energy: f64,
}

impl SmartTable {
    pub fn new(expected_accuracy: Vec<f64>, profile: &EnergyProfile, emit_energy: f64) -> SmartTable {
        assert_eq!(expected_accuracy.len(), profile.cumulative.len());
        SmartTable {
            expected_accuracy,
            cumulative_energy: profile.cumulative.clone(),
            emit_energy,
        }
    }

    /// Minimum feature count whose expected accuracy meets `bound`
    /// (None if even all features fall short).
    ///
    /// Contract: the returned `p` satisfies `expected_accuracy[q] >= bound`
    /// for **every** `q >= p` — it is the first index of the curve's
    /// *monotone upper envelope* at `bound`, not merely the first crossing.
    /// Measured accuracy curves are not guaranteed monotone (they dip);
    /// on the first raw crossing, a GREEDY refinement past `p` could land
    /// in a dip below the bound, and [`SmartTable::energy_for`] would
    /// quote a cheaper depth that does not actually deliver the accuracy.
    /// On monotone curves (every analytic table we ship) this is
    /// identical to the first crossing.
    pub fn min_features_for(&self, bound: f64) -> Option<usize> {
        // Scan from the full-depth end: the envelope index is one past
        // the last entry below the bound.
        let mut first = None;
        for (p, &a) in self.expected_accuracy.iter().enumerate().rev() {
            if a >= bound {
                first = Some(p);
            } else {
                break;
            }
        }
        first
    }

    /// Energy required to meet `bound`: features plus the final emission.
    pub fn energy_for(&self, bound: f64) -> Option<f64> {
        self.min_features_for(bound)
            .map(|p| self.cumulative_energy[p] + self.emit_energy)
    }

    /// SMART's gate: can the current budget deliver accuracy >= bound?
    pub fn feasible(&self, budget: f64, bound: f64) -> Option<usize> {
        let p = self.min_features_for(bound)?;
        if self.cumulative_energy[p] + self.emit_energy <= budget {
            Some(p)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mcu() -> McuModel {
        McuModel::paper_default()
    }

    fn costs(n: usize) -> Vec<OpCost> {
        (0..n).map(|i| OpCost::cycles(1000 * (i as u64 + 1))).collect()
    }

    #[test]
    fn cumulative_is_prefix_sum() {
        let p = EnergyProfile::from_costs(&mcu(), &costs(4));
        assert_eq!(p.cumulative.len(), 5);
        assert_eq!(p.cumulative[0], 0.0);
        for k in 1..=4 {
            let direct: f64 = p.step_energy[..k].iter().sum();
            assert!((p.cumulative[k] - direct).abs() < 1e-18);
        }
        assert!((p.span(1, 3) - (p.step_energy[1] + p.step_energy[2])).abs() < 1e-18);
    }

    #[test]
    fn max_steps_within_budget() {
        let p = EnergyProfile::from_costs(&mcu(), &costs(4));
        assert_eq!(p.max_steps_within(p.total() + 1e-9, 0.0), 4);
        assert_eq!(p.max_steps_within(p.cumulative[2] + 1e-15, 0.0), 2);
        assert_eq!(p.max_steps_within(0.0, 0.0), 0);
        assert_eq!(p.max_steps_within(1.0, 2.0), 0); // reserve exceeds budget
        // Reserve shaves off the last step.
        let reserve = p.step_energy[3];
        assert!(p.max_steps_within(p.total(), reserve + 1e-15) < 4);
    }

    #[test]
    fn max_steps_within_is_total_on_non_finite_budgets() {
        let p = EnergyProfile::from_costs(&mcu(), &costs(4));
        // NaN anywhere must afford zero steps, never panic.
        assert_eq!(p.max_steps_within(f64::NAN, 0.0), 0);
        assert_eq!(p.max_steps_within(1.0, f64::NAN), 0);
        assert_eq!(p.max_steps_within(f64::NAN, f64::NAN), 0);
        // Inf - Inf is NaN; same guard.
        assert_eq!(p.max_steps_within(f64::INFINITY, f64::INFINITY), 0);
        // An infinite reserve affords nothing, an infinite budget affords
        // the whole pipeline.
        assert_eq!(p.max_steps_within(1.0, f64::INFINITY), 0);
        assert_eq!(p.max_steps_within(f64::INFINITY, 0.0), 4);
        assert_eq!(p.max_steps_within(f64::NEG_INFINITY, 0.0), 0);
    }

    #[test]
    fn max_steps_within_returns_maximal_k_on_tied_prefix_sums() {
        // Steps 1..=3 are free (perforated spans priced at zero), so the
        // cumulative grid carries duplicate entries. The affordable step
        // count must be the largest matching index: the free steps are
        // affordable whenever their predecessor is.
        let zero = OpCost::default();
        let costs = [OpCost::cycles(1000), zero, zero, zero, OpCost::cycles(1000)];
        let p = EnergyProfile::from_costs(&mcu(), &costs);
        assert_eq!(p.cumulative[1], p.cumulative[4], "fixture needs tied prefixes");
        // Exactly the first step's energy: steps 2..4 are free and must
        // all be granted, not an arbitrary binary-search match.
        assert_eq!(p.max_steps_within(p.cumulative[1], 0.0), 4);
        // A zero budget still affords nothing but index 0's empty prefix.
        assert_eq!(p.max_steps_within(0.0, 0.0), 0);
        // An all-free pipeline is fully affordable at zero budget.
        let free = EnergyProfile::from_costs(&mcu(), &[OpCost::default(); 3]);
        assert_eq!(free.max_steps_within(0.0, 0.0), 3);
    }

    #[test]
    fn min_features_for_uses_the_monotone_upper_envelope() {
        // A measured curve that dips back under the bound after first
        // crossing it: position() would return 2, but refining past 2
        // lands on 0.78 < 0.80 — the quoted depth must be 4, the first
        // index from which the curve never dips below the bound again.
        let profile = EnergyProfile::from_costs(&mcu(), &costs(4));
        let acc = vec![0.1, 0.5, 0.82, 0.78, 0.88];
        let t = SmartTable::new(acc, &profile, 50e-6);
        assert_eq!(t.min_features_for(0.8), Some(4));
        let e = t.energy_for(0.8).unwrap();
        assert!((e - (profile.cumulative[4] + 50e-6)).abs() < 1e-15);
        // The envelope never under-prices: feasibility at the envelope
        // depth is the real gate.
        assert_eq!(t.feasible(e + 1e-9, 0.8), Some(4));
        assert_eq!(t.feasible(e - 1e-6, 0.8), None);
        // Bounds the whole curve meets resolve to depth 0, and bounds
        // nothing meets stay None.
        assert_eq!(t.min_features_for(0.05), Some(0));
        assert_eq!(t.min_features_for(0.95), None);
    }

    #[test]
    fn smart_table_lookup() {
        let profile = EnergyProfile::from_costs(&mcu(), &costs(4));
        let acc = vec![0.166, 0.5, 0.7, 0.82, 0.88];
        let t = SmartTable::new(acc, &profile, 50e-6);
        assert_eq!(t.min_features_for(0.8), Some(3));
        assert_eq!(t.min_features_for(0.95), None);
        let e = t.energy_for(0.8).unwrap();
        assert!((e - (profile.cumulative[3] + 50e-6)).abs() < 1e-15);
        // Feasibility gate.
        assert_eq!(t.feasible(e + 1e-9, 0.8), Some(3));
        assert_eq!(t.feasible(e - 1e-6, 0.8), None);
    }
}
