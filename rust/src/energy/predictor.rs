//! Online environment prediction for the adaptive policy family.
//!
//! The offline [`estimator`](crate::energy::estimator) answers "what
//! does depth `k` cost?"; this module answers "what will the *next*
//! power cycle afford?". It is deliberately tiny: the paper's persistence
//! discipline allows the adaptive runtime only a few words of learned
//! state per power cycle, so the predictor is a pair of exponentially
//! weighted moving averages — realised per-cycle energy budget and
//! inter-boot gap — each one `f64`, updated **once per power cycle**
//! from the budget the engine actually realised. No trace history, no
//! allocation, no RNG: the estimate is a pure fold over observations,
//! which keeps adaptive sweeps bitwise deterministic.
//!
//! *Approxify* frames auto-tuning as matching the approximation setting
//! to the deployment's energy envelope; the EWMA is that envelope,
//! learned in place. *Intermittent Learning* shows this class of
//! constant-space online update survives intermittent power as long as
//! the state is persisted with the same care as application state — the
//! adaptive runtime bills every predictor word through the state ledger.

/// Exponentially weighted moving average over per-power-cycle
/// observations of the energy environment.
///
/// The whole struct is the adaptive policy's "world model": two floats
/// of estimate plus the bookkeeping needed to observe each cycle exactly
/// once. It is `Copy` so the runtime can persist/restore it as a value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EwmaPredictor {
    /// Smoothing factor in `(0, 1]`; higher tracks faster, lower
    /// averages harder. 1.0 degenerates to "last observation wins".
    pub alpha: f64,
    /// Estimated usable energy per power cycle, joules (NaN until the
    /// first observation).
    pub energy: f64,
    /// Estimated gap between consecutive boots, seconds (NaN until two
    /// boots have been seen).
    pub gap: f64,
    /// Boot timestamp of the last observed cycle, seconds.
    pub last_boot: f64,
    /// Power cycles folded in so far.
    pub cycles_seen: u64,
}

impl EwmaPredictor {
    pub fn new(alpha: f64) -> EwmaPredictor {
        assert!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "EWMA alpha must be in (0, 1], got {alpha}"
        );
        EwmaPredictor { alpha, energy: f64::NAN, gap: f64::NAN, last_boot: f64::NAN, cycles_seen: 0 }
    }

    /// Fold in one power cycle's realised budget. `budget` is the usable
    /// energy the engine reported at boot; `now` is the boot time. The
    /// caller guarantees one call per power cycle (the adaptive runtime
    /// keys on the engine's cycle counter).
    ///
    /// Non-finite observations are ignored rather than poisoning the
    /// estimate — a NaN budget can only come from a hostile device spec,
    /// and the estimator layer already clamps what such a budget affords.
    ///
    /// A non-finite clock also invalidates `last_boot`: the delta from
    /// the boot *before* the bad cycle to the boot *after* it spans two
    /// cycles, so folding it would inflate the gap estimate. The next
    /// finite boot re-anchors instead.
    pub fn observe(&mut self, budget: f64, now: f64) {
        let budget_ok = budget.is_finite() && budget >= 0.0;
        if budget_ok {
            if self.energy.is_nan() {
                // Seed directly: an EWMA warmed from zero under-predicts
                // for 1/alpha cycles, which would pin the bandit at the
                // shallowest arm exactly when it should be exploring.
                self.energy = budget;
            } else {
                self.energy = self.alpha * budget + (1.0 - self.alpha) * self.energy;
            }
        }
        if now.is_finite() {
            if self.last_boot.is_finite() {
                let delta = now - self.last_boot;
                if delta.is_finite() && delta >= 0.0 {
                    if self.gap.is_nan() {
                        self.gap = delta;
                    } else {
                        self.gap = self.alpha * delta + (1.0 - self.alpha) * self.gap;
                    }
                }
            }
            self.last_boot = now;
        } else {
            self.last_boot = f64::NAN;
        }
        // Count only cycles that actually folded something in: a cycle
        // whose budget and clock were both ignored left no trace in the
        // estimate, so it must not advance "power cycles folded in".
        if budget_ok || now.is_finite() {
            self.cycles_seen = self.cycles_seen.saturating_add(1);
        }
    }

    /// Best current estimate of next cycle's budget, or `fallback`
    /// before the first observation.
    pub fn energy_or(&self, fallback: f64) -> f64 {
        if self.energy.is_finite() {
            self.energy
        } else {
            fallback
        }
    }

    /// Best current estimate of the inter-boot gap, or `fallback` before
    /// two boots have been seen.
    pub fn gap_or(&self, fallback: f64) -> f64 {
        if self.gap.is_finite() {
            self.gap
        } else {
            fallback
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_from_the_first_observation() {
        let mut p = EwmaPredictor::new(0.2);
        assert!(p.energy.is_nan());
        assert_eq!(p.energy_or(7.0), 7.0);
        p.observe(3.0e-3, 10.0);
        assert_eq!(p.energy, 3.0e-3, "first observation seeds, not blends");
        assert_eq!(p.cycles_seen, 1);
        assert_eq!(p.gap_or(60.0), 60.0, "one boot gives no gap yet");
    }

    #[test]
    fn converges_to_a_constant_environment() {
        let mut p = EwmaPredictor::new(0.2);
        for cycle in 0..60 {
            p.observe(2.5e-3, cycle as f64 * 12.0);
        }
        assert!((p.energy - 2.5e-3).abs() < 1e-12);
        assert!((p.gap - 12.0).abs() < 1e-9);
        assert_eq!(p.cycles_seen, 60);
    }

    #[test]
    fn tracks_a_step_change_geometrically() {
        let mut p = EwmaPredictor::new(0.5);
        p.observe(1.0e-3, 0.0);
        p.observe(3.0e-3, 10.0);
        assert!((p.energy - 2.0e-3).abs() < 1e-12);
        p.observe(3.0e-3, 20.0);
        assert!((p.energy - 2.5e-3).abs() < 1e-12);
        // Half the remaining distance each cycle: within 2% in 6 cycles.
        for i in 0..4 {
            p.observe(3.0e-3, 30.0 + 10.0 * i as f64);
        }
        assert!((p.energy - 3.0e-3).abs() < 0.02 * 3.0e-3);
    }

    #[test]
    fn ignores_non_finite_observations() {
        let mut p = EwmaPredictor::new(0.3);
        p.observe(2.0e-3, 0.0);
        p.observe(f64::NAN, 5.0);
        p.observe(f64::INFINITY, 10.0);
        p.observe(-1.0, 15.0);
        assert_eq!(p.energy, 2.0e-3, "bad budgets must not poison the estimate");
        // Time still advances, so the gap keeps learning.
        assert!((p.gap - 5.0).abs() < 1e-9);
        p.observe(2.0e-3, f64::NAN);
        assert!(
            p.last_boot.is_nan(),
            "a non-finite clock must invalidate the boot anchor, got {}",
            p.last_boot
        );
    }

    #[test]
    fn hostile_clock_cycle_does_not_inflate_the_gap() {
        let mut p = EwmaPredictor::new(0.3);
        p.observe(1.0e-3, 0.0);
        p.observe(1.0e-3, 5.0);
        assert!((p.gap - 5.0).abs() < 1e-12, "gap seeded from the first delta");
        // One hostile-clock cycle in the middle: the 5.0 → 15.0 span
        // covers *two* cycles, so the pre-fix fold of delta = 10.0 would
        // read as a doubled gap. It must be skipped entirely.
        p.observe(1.0e-3, f64::NAN);
        p.observe(1.0e-3, 15.0);
        assert!(
            (p.gap - 5.0).abs() < 1e-12,
            "the spanning delta across a bad clock must not be folded, gap={}",
            p.gap
        );
        // Learning resumes from the re-anchored boot.
        p.observe(1.0e-3, 20.0);
        assert!((p.gap - 5.0).abs() < 1e-12);
        assert_eq!(p.last_boot, 20.0);
    }

    #[test]
    fn fully_ignored_cycles_are_not_counted() {
        let mut p = EwmaPredictor::new(0.3);
        p.observe(1.0e-3, 0.0);
        assert_eq!(p.cycles_seen, 1);
        // Budget and clock both hostile: nothing was folded in.
        p.observe(f64::NAN, f64::NAN);
        assert_eq!(p.cycles_seen, 1, "a fully ignored cycle must not count");
        // One usable half is enough to count the cycle.
        p.observe(f64::NAN, 5.0);
        assert_eq!(p.cycles_seen, 2);
        p.observe(1.0e-3, f64::NAN);
        assert_eq!(p.cycles_seen, 3);
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn zero_alpha_is_a_wiring_bug() {
        EwmaPredictor::new(0.0);
    }
}
