//! Synthetic ambient-energy traces.
//!
//! The paper's imaging evaluation (§6.3, Fig. 11) replays five recorded
//! traces: **RF** (Mementos, WISP device — most variable, least energy)
//! and four solar traces from EPIC — outdoor mobile (**SOM**, most stable,
//! most energy), indoor mobile (**SIM**), outdoor static (**SOR**), indoor
//! static (**SIR**). The recordings are not redistributable, so this
//! module generates seeded stochastic traces matching each profile's
//! qualitative shape; Fig. 14's analysis depends on two relative
//! properties we preserve by construction:
//!
//! 1. the energy-content ordering SOM > SOR ≫ SIM > SIR ≈ RF, and
//! 2. RF and SIR deliver (approximately) the *same total energy* with
//!    sharply different time dynamics (bursty vs smooth).

use crate::util::rng::Rng;

/// A power trace: harvester output sampled on a fixed grid.
#[derive(Clone, Debug)]
pub struct PowerTrace {
    /// Sample period, seconds.
    pub dt: f64,
    /// Instantaneous power at each sample, watts.
    pub samples: Vec<f64>,
}

impl PowerTrace {
    /// Trace duration in seconds.
    pub fn duration(&self) -> f64 {
        self.samples.len() as f64 * self.dt
    }

    /// Power at absolute time `t`, wrapping around the end (the paper's
    /// power supply replays traces in a loop for long experiments).
    #[inline]
    pub fn power_at(&self, t: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let idx = (t / self.dt) as usize % self.samples.len();
        self.samples[idx]
    }

    /// Mean power over the whole trace, watts.
    pub fn mean_power(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    /// Total energy content, joules.
    pub fn total_energy(&self) -> f64 {
        self.samples.iter().sum::<f64>() * self.dt
    }

    /// Coefficient of variation (σ/µ) — the "dynamics" of the trace.
    pub fn variability(&self) -> f64 {
        let m = self.mean_power();
        if m == 0.0 {
            return 0.0;
        }
        crate::util::stats::std_dev(&self.samples) / m
    }

    /// Piecewise-constant view of the trace: adjacent equal samples are
    /// run-length coalesced into constant-power segments. Segment `i`
    /// covers `[ends[i-1], ends[i])` (with `ends[-1] = 0`) at `powers[i]`
    /// watts, and the pattern repeats with `period` — exactly the
    /// wrapping replay [`PowerTrace::power_at`] implements. This is what
    /// the event-driven engine steps over: bursty traces (RF's long off
    /// runs) collapse to a handful of segments per burst cycle.
    pub fn piecewise(&self) -> Piecewise {
        if self.samples.is_empty() {
            return Piecewise::constant(0.0);
        }
        let n = self.samples.len();
        let mut ends = Vec::new();
        let mut powers = Vec::new();
        let mut i = 0usize;
        while i < n {
            let p = self.samples[i];
            let mut j = i + 1;
            while j < n && self.samples[j] == p {
                j += 1;
            }
            // Segment boundaries are exact grid multiples — no float
            // accumulation drift over long traces.
            ends.push(j as f64 * self.dt);
            powers.push(p);
            i = j;
        }
        let period = n as f64 * self.dt;
        Piecewise { ends, powers, period }
    }
}

/// Run-length-coalesced constant-power segments of a (wrapping) trace.
/// `period == f64::INFINITY` encodes a single never-ending segment (a
/// constant source).
#[derive(Clone, Debug)]
pub struct Piecewise {
    /// End time of each segment within one period, strictly increasing;
    /// the last entry equals `period` (or ∞ for a constant source).
    pub ends: Vec<f64>,
    /// Raw harvester power of each segment, watts.
    pub powers: Vec<f64>,
    /// Repetition period, seconds.
    pub period: f64,
}

impl Piecewise {
    /// A single infinite segment at `p` watts.
    pub fn constant(p: f64) -> Piecewise {
        Piecewise { ends: vec![f64::INFINITY], powers: vec![p], period: f64::INFINITY }
    }

    /// Number of segments per period.
    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    /// Start time of segment `i` within the period.
    #[inline]
    pub fn start(&self, i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            self.ends[i - 1]
        }
    }

    /// Locate absolute time `t ≥ 0` in the wrapping pattern: returns
    /// `(epoch, idx)` where `epoch` counts whole elapsed periods and
    /// `idx` is the covering segment, with the float-rounding of `t /
    /// period` corrected at the period seams. Shared by the segment
    /// iterator and the engine's stepping cursor so the wrap arithmetic
    /// lives in exactly one place.
    pub fn locate(&self, t: f64) -> (u64, usize) {
        if !self.period.is_finite() {
            return (0, 0);
        }
        let mut k = (t / self.period) as u64;
        let mut phase = t - k as f64 * self.period;
        if phase < 0.0 {
            k = k.saturating_sub(1);
            phase = (t - k as f64 * self.period).max(0.0);
        }
        if phase >= self.period {
            k += 1;
            phase = (t - k as f64 * self.period).max(0.0);
        }
        let idx = self.ends.partition_point(|&e| e <= phase).min(self.len() - 1);
        (k, idx)
    }

    /// Raw energy content of one period, joules (∑ pᵢ·lenᵢ; infinite
    /// sources report 0 — they have no finite period to sum).
    pub fn energy_per_period(&self) -> f64 {
        if !self.period.is_finite() {
            return 0.0;
        }
        (0..self.len()).map(|i| self.powers[i] * (self.ends[i] - self.start(i))).sum()
    }

    /// Power at absolute time `t`, wrapping with the period — the
    /// segment-native twin of [`PowerTrace::power_at`]. Generators that
    /// emit `Piecewise` directly (the `energy::synth` environments) have
    /// no sample grid to fall back on, so point sampling lives here.
    #[inline]
    pub fn power_at(&self, t: f64) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let (_, idx) = self.locate(t.max(0.0));
        self.powers[idx]
    }

    /// Mean power over one period, watts (the segment power itself for a
    /// constant source).
    pub fn mean_power(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        if self.period.is_finite() && self.period > 0.0 {
            self.energy_per_period() / self.period
        } else {
            self.powers[0]
        }
    }
}

/// The five paper traces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// RF harvesting (Mementos WISP): bursty, least energy.
    Rf,
    /// Solar outdoor mobile: most stable, most energy.
    Som,
    /// Solar indoor mobile: weak, moderately variable.
    Sim,
    /// Solar outdoor static: rich, slow cloud dynamics.
    Sor,
    /// Solar indoor static: weak, very smooth; total energy ≈ RF.
    Sir,
}

impl TraceKind {
    pub const ALL: [TraceKind; 5] =
        [TraceKind::Rf, TraceKind::Som, TraceKind::Sim, TraceKind::Sor, TraceKind::Sir];

    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Rf => "RF",
            TraceKind::Som => "SOM",
            TraceKind::Sim => "SIM",
            TraceKind::Sor => "SOR",
            TraceKind::Sir => "SIR",
        }
    }

    pub fn from_name(s: &str) -> Option<TraceKind> {
        match s.to_ascii_lowercase().as_str() {
            "rf" => Some(TraceKind::Rf),
            "som" => Some(TraceKind::Som),
            "sim" => Some(TraceKind::Sim),
            "sor" => Some(TraceKind::Sor),
            "sir" => Some(TraceKind::Sir),
            _ => None,
        }
    }
}

/// Parameters of an Ornstein-Uhlenbeck modulated solar profile.
struct SolarProfile {
    mean: f64,
    /// OU relative std-dev.
    sigma_rel: f64,
    /// OU relaxation time, seconds.
    tau: f64,
    /// Poisson rate of occlusion events (per second).
    dip_rate: f64,
    /// Occlusion depth range (fraction of power removed).
    dip_depth: (f64, f64),
    /// Occlusion duration range, seconds.
    dip_len: (f64, f64),
}

fn solar_profile(kind: TraceKind) -> SolarProfile {
    match kind {
        // SOM: "most stable and has highest energy content" (Fig. 11).
        TraceKind::Som => SolarProfile {
            mean: 3.0e-3,
            sigma_rel: 0.04,
            tau: 45.0,
            dip_rate: 1.0 / 300.0,
            dip_depth: (0.2, 0.5),
            dip_len: (2.0, 6.0),
        },
        TraceKind::Sor => SolarProfile {
            mean: 2.2e-3,
            sigma_rel: 0.10,
            tau: 60.0,
            dip_rate: 1.0 / 90.0,
            dip_depth: (0.3, 0.7),
            dip_len: (5.0, 20.0),
        },
        TraceKind::Sim => SolarProfile {
            mean: 0.45e-3,
            sigma_rel: 0.30,
            tau: 8.0,
            dip_rate: 1.0 / 20.0,
            dip_depth: (0.6, 0.95),
            dip_len: (1.0, 5.0),
        },
        TraceKind::Sir => SolarProfile {
            mean: 0.21e-3,
            sigma_rel: 0.05,
            tau: 120.0,
            dip_rate: 1.0 / 600.0,
            dip_depth: (0.1, 0.3),
            dip_len: (5.0, 15.0),
        },
        TraceKind::Rf => unreachable!("RF uses the burst generator"),
    }
}

/// Generate a seeded trace of the given kind.
///
/// `dt` of 10 ms resolves the RF bursts while keeping hour-long traces
/// affordable (360 k samples/h).
pub fn generate(kind: TraceKind, duration_secs: f64, dt: f64, seed: u64) -> PowerTrace {
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let n = (duration_secs / dt).ceil() as usize;
    match kind {
        TraceKind::Rf => generate_rf(n, dt, &mut rng),
        _ => generate_solar(solar_profile(kind), n, dt, &mut rng),
    }
}

/// RF bursts: exponential off periods (mean 4.5 s) interleaved with short
/// on bursts (mean 0.5 s) around 1.6 mW → mean ≈ 0.16 mW ≈ SIR.
fn generate_rf(n: usize, dt: f64, rng: &mut Rng) -> PowerTrace {
    let mut samples = vec![0.0; n];
    let mut t = 0usize;
    let mut on = false;
    while t < n {
        let (len_mean, level) = if on { (0.5, 1.6e-3) } else { (4.5, 0.0) };
        let len = (rng.exponential(1.0 / len_mean) / dt).ceil().max(1.0) as usize;
        let end = (t + len).min(n);
        if on {
            // In-burst jitter: RF field strength fluctuates fast.
            for s in samples.iter_mut().take(end).skip(t) {
                *s = (level * (1.0 + 0.35 * rng.gaussian())).max(0.0);
            }
        }
        t = end;
        on = !on;
    }
    PowerTrace { dt, samples }
}

/// Solar: OU-modulated mean with Poisson occlusion dips.
fn generate_solar(p: SolarProfile, n: usize, dt: f64, rng: &mut Rng) -> PowerTrace {
    let mut samples = vec![0.0; n];
    let mut x = p.mean;
    let sigma = p.sigma_rel * p.mean;
    let mut dip_until = 0usize;
    let mut dip_gain = 1.0;
    for (i, s) in samples.iter_mut().enumerate() {
        // OU step.
        x += (p.mean - x) * dt / p.tau
            + sigma * (2.0 * dt / p.tau).sqrt() * rng.gaussian();
        // Occlusion arrivals.
        if i >= dip_until && rng.chance(p.dip_rate * dt) {
            let depth = rng.range(p.dip_depth.0, p.dip_depth.1);
            let len = rng.range(p.dip_len.0, p.dip_len.1);
            dip_gain = 1.0 - depth;
            dip_until = i + (len / dt) as usize;
        }
        let gain = if i < dip_until { dip_gain } else { 1.0 };
        *s = (x * gain).max(0.0);
    }
    PowerTrace { dt, samples }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(kind: TraceKind) -> PowerTrace {
        generate(kind, 600.0, 0.01, 42)
    }

    #[test]
    fn energy_ordering_matches_paper() {
        let mp: Vec<(TraceKind, f64)> =
            TraceKind::ALL.iter().map(|&k| (k, trace(k).mean_power())).collect();
        let get = |k: TraceKind| mp.iter().find(|(kk, _)| *kk == k).unwrap().1;
        assert!(get(TraceKind::Som) > get(TraceKind::Sor));
        assert!(get(TraceKind::Sor) > get(TraceKind::Sim));
        assert!(get(TraceKind::Sim) > get(TraceKind::Sir));
        // SOM has by far the most energy.
        assert!(get(TraceKind::Som) > 4.0 * get(TraceKind::Sim));
    }

    #[test]
    fn rf_and_sir_have_similar_total_energy() {
        let rf = trace(TraceKind::Rf).total_energy();
        let sir = trace(TraceKind::Sir).total_energy();
        let ratio = rf / sir;
        assert!((0.6..1.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn rf_is_most_variable_sir_and_som_smooth() {
        let var_rf = trace(TraceKind::Rf).variability();
        let var_sir = trace(TraceKind::Sir).variability();
        let var_som = trace(TraceKind::Som).variability();
        assert!(var_rf > 1.5, "RF should be bursty, cv={var_rf}");
        assert!(var_sir < 0.35, "SIR should be smooth, cv={var_sir}");
        assert!(var_som < 0.35, "SOM should be stable, cv={var_som}");
        assert!(var_rf > 4.0 * var_sir);
    }

    #[test]
    fn deterministic_per_seed_distinct_across_seeds() {
        let a = generate(TraceKind::Sor, 10.0, 0.01, 1);
        let b = generate(TraceKind::Sor, 10.0, 0.01, 1);
        let c = generate(TraceKind::Sor, 10.0, 0.01, 2);
        assert_eq!(a.samples, b.samples);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn power_at_wraps() {
        let t = PowerTrace { dt: 1.0, samples: vec![1.0, 2.0, 3.0] };
        assert_eq!(t.power_at(0.5), 1.0);
        assert_eq!(t.power_at(2.5), 3.0);
        assert_eq!(t.power_at(3.5), 1.0); // wrapped
        assert!((t.total_energy() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn no_negative_power() {
        for kind in TraceKind::ALL {
            assert!(trace(kind).samples.iter().all(|&p| p >= 0.0), "{:?}", kind);
        }
    }

    #[test]
    fn piecewise_preserves_energy_and_matches_sampling() {
        for kind in TraceKind::ALL {
            let t = trace(kind);
            let pw = t.piecewise();
            assert!((pw.period - t.duration()).abs() < 1e-9, "{kind:?}");
            assert_eq!(*pw.ends.last().unwrap(), pw.period, "{kind:?}");
            // Energy per period equals the trace's total energy.
            let rel = (pw.energy_per_period() - t.total_energy()).abs()
                / t.total_energy().max(1e-18);
            assert!(rel < 1e-9, "{kind:?}: rel={rel}");
            // Segment powers agree with point sampling (probe mid-sample
            // to stay clear of boundary rounding).
            let mut seg = 0usize;
            for s in 0..t.samples.len() {
                let mid = (s as f64 + 0.5) * t.dt;
                while pw.ends[seg] <= mid {
                    seg += 1;
                }
                assert_eq!(pw.powers[seg], t.power_at(mid), "{kind:?} sample {s}");
            }
        }
    }

    #[test]
    fn piecewise_coalesces_rf_off_runs() {
        // RF is mostly exact-zero off time: run-length coalescing must
        // shrink it far below one segment per sample.
        let t = trace(TraceKind::Rf);
        let pw = t.piecewise();
        assert!(
            pw.len() * 4 < t.samples.len(),
            "RF: {} segments for {} samples",
            pw.len(),
            t.samples.len()
        );
    }

    #[test]
    fn piecewise_of_empty_trace_is_constant_zero() {
        let t = PowerTrace { dt: 0.01, samples: vec![] };
        let pw = t.piecewise();
        assert_eq!(pw.len(), 1);
        assert_eq!(pw.powers[0], 0.0);
        assert!(!pw.period.is_finite());
    }

    #[test]
    fn name_roundtrip() {
        for kind in TraceKind::ALL {
            assert_eq!(TraceKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(TraceKind::from_name("nope"), None);
    }
}
