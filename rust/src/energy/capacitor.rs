//! The capacitor energy buffer.
//!
//! The prototype uses a 1470 µF capacitor chosen "through a mixed
//! analytical and experimental approach" (§4.1): large enough for
//! worst-case single-cycle processing, small enough to recharge quickly.
//! The device operates while `v >= v_off` (brown-out threshold) and, after
//! dying, restarts only once `v >= v_on` (the booster's VBAT_OK rising
//! threshold), giving the classic intermittent duty cycle.
//!
//! State is the stored energy `e` (joules); voltage is the derived view
//! `v = sqrt(2e/C)`. Working in energy space makes the hot operations —
//! `charge`, `discharge`, `alive`, `can_boot`, `usable_energy` — straight
//! adds and compares with no square roots, and it is the coordinate in
//! which the analytic engine's segment stepping is *linear*: under a
//! constant net power `p` the trajectory is `e(t) = e₀ + p·t`, so every
//! threshold crossing has the closed form `t = (e_thr − e₀)/p` (see
//! [`Capacitor::time_to_energy`]).

/// Capacitor + supervisor thresholds.
#[derive(Clone, Debug)]
pub struct Capacitor {
    /// Capacitance in farads (paper: 1470e-6).
    pub capacitance: f64,
    /// Rail ceiling enforced by the charger (BQ25505 OV threshold).
    pub v_max: f64,
    /// Turn-on (VBAT_OK rising) threshold: device boots at/above this.
    pub v_on: f64,
    /// Brown-out threshold: device dies below this.
    pub v_off: f64,
    /// Current stored energy, joules (½CV²).
    e: f64,
    /// Cached energy levels of the three thresholds.
    e_max: f64,
    e_on: f64,
    e_off: f64,
}

impl Capacitor {
    /// The paper's buffer: 1470 µF, 3.6 V rail, boot at 3.0 V, die at 1.8 V
    /// (MSP430 minimum supply at 8 MHz).
    pub fn paper_default() -> Capacitor {
        Capacitor::new(1470e-6, 3.6, 3.0, 1.8)
    }

    pub fn new(capacitance: f64, v_max: f64, v_on: f64, v_off: f64) -> Capacitor {
        assert!(capacitance > 0.0);
        assert!(v_max >= v_on && v_on > v_off && v_off > 0.0);
        let half_c = 0.5 * capacitance;
        Capacitor {
            capacitance,
            v_max,
            v_on,
            v_off,
            e: 0.0,
            e_max: half_c * v_max * v_max,
            e_on: half_c * v_on * v_on,
            e_off: half_c * v_off * v_off,
        }
    }

    /// Current voltage (what the LTC1417 ADC reads).
    #[inline]
    pub fn voltage(&self) -> f64 {
        (2.0 * self.e / self.capacitance).sqrt()
    }

    /// Stored energy, joules.
    #[inline]
    pub fn energy(&self) -> f64 {
        self.e
    }

    /// Stored energy at an arbitrary voltage: ½Cv².
    #[inline]
    pub fn energy_at(&self, v: f64) -> f64 {
        0.5 * self.capacitance * v * v
    }

    /// Energy level of the rail ceiling `v_max`.
    #[inline]
    pub fn max_energy(&self) -> f64 {
        self.e_max
    }

    /// Energy level of the turn-on threshold `v_on`.
    #[inline]
    pub fn boot_energy_level(&self) -> f64 {
        self.e_on
    }

    /// Energy level of the brown-out threshold `v_off`.
    #[inline]
    pub fn brownout_energy_level(&self) -> f64 {
        self.e_off
    }

    /// Energy available before brown-out: `e − ½Cv_off²`, clamped at 0.
    ///
    /// This is the budget the GREEDY/SMART policies divide between useful
    /// computation and the final BLE transmission.
    #[inline]
    pub fn usable_energy(&self) -> f64 {
        (self.e - self.e_off).max(0.0)
    }

    /// Energy needed to charge from `v_off` to `v_on` (one recharge ramp).
    pub fn recharge_energy(&self) -> f64 {
        self.e_on - self.e_off
    }

    /// Closed-form threshold crossing: seconds until the buffer reaches
    /// `target` joules under a constant net power `net_power` (harvest
    /// minus load, watts). `Some(0.0)` if already there; `None` if the
    /// target is unreachable (net power pointing the wrong way or zero).
    /// Ignores the rail clamp — callers cap the result at the time the
    /// rail would be hit when `target > e_max` matters.
    ///
    /// This is the same `(e_thr − e₀)/p` arithmetic the analytic engine
    /// applies per segment (inlined there against its running energy
    /// local, with segment-boundary and horizon capping); this helper
    /// exposes the closed form for tests and tooling.
    pub fn time_to_energy(&self, target: f64, net_power: f64) -> Option<f64> {
        let gap = target - self.e;
        if gap == 0.0 {
            return Some(0.0);
        }
        if net_power == 0.0 || (gap > 0.0) != (net_power > 0.0) {
            return None;
        }
        Some(gap / net_power)
    }

    /// Deposit `joules` from the charger (clamped to the rail ceiling).
    #[inline]
    pub fn charge(&mut self, joules: f64) {
        debug_assert!(joules >= 0.0);
        self.e = (self.e + joules).min(self.e_max);
    }

    /// Withdraw `joules` for a load operation. Returns `false` (and drains
    /// to the floor) if the buffer held less than requested — the caller
    /// treats that as a brown-out mid-operation.
    #[must_use]
    #[inline]
    pub fn discharge(&mut self, joules: f64) -> bool {
        debug_assert!(joules >= 0.0);
        let e = self.e - joules;
        if e <= 0.0 {
            self.e = 0.0;
            return false;
        }
        self.e = e;
        true
    }

    /// True while the MCU can run.
    #[inline]
    pub fn alive(&self) -> bool {
        self.e >= self.e_off
    }

    /// True when a dead device may boot.
    #[inline]
    pub fn can_boot(&self) -> bool {
        self.e >= self.e_on
    }

    /// Force the voltage (test setup / cold start).
    pub fn set_voltage(&mut self, v: f64) {
        let v = v.clamp(0.0, self.v_max);
        self.e = self.energy_at(v);
    }

    /// Force the stored energy (the analytic engine's write-back path),
    /// clamped to `[0, e_max]`.
    pub fn set_energy(&mut self, e: f64) {
        self.e = e.clamp(0.0, self.e_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_matches_half_cv2() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.0);
        assert!((c.energy() - 0.5 * 1470e-6 * 9.0).abs() < 1e-12);
    }

    #[test]
    fn usable_energy_is_above_brownout_only() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(1.8);
        assert_eq!(c.usable_energy(), 0.0);
        c.set_voltage(3.0);
        let want = 0.5 * 1470e-6 * (9.0 - 1.8 * 1.8);
        assert!((c.usable_energy() - want).abs() < 1e-12);
    }

    #[test]
    fn charge_clamps_at_rail() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.5);
        c.charge(1.0); // a full joule, way past the rail
        assert!((c.voltage() - 3.6).abs() < 1e-12);
        assert_eq!(c.energy(), c.max_energy());
    }

    #[test]
    fn discharge_roundtrip() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.0);
        let e0 = c.energy();
        assert!(c.discharge(1e-3));
        assert!((c.energy() - (e0 - 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn overdraw_reports_failure() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(2.0);
        assert!(!c.discharge(1.0));
        assert_eq!(c.voltage(), 0.0);
        assert!(!c.alive());
    }

    #[test]
    fn lifecycle_thresholds() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(2.5);
        assert!(c.alive());
        assert!(!c.can_boot());
        c.set_voltage(3.05);
        assert!(c.can_boot());
        c.set_voltage(1.7);
        assert!(!c.alive());
    }

    #[test]
    fn recharge_energy_positive_and_consistent() {
        let c = Capacitor::paper_default();
        let want = 0.5 * 1470e-6 * (9.0 - 3.24);
        assert!((c.recharge_energy() - want).abs() < 1e-12);
    }

    #[test]
    fn energy_levels_match_threshold_voltages() {
        let c = Capacitor::paper_default();
        assert!((c.boot_energy_level() - c.energy_at(c.v_on)).abs() < 1e-18);
        assert!((c.brownout_energy_level() - c.energy_at(c.v_off)).abs() < 1e-18);
        assert!((c.max_energy() - c.energy_at(c.v_max)).abs() < 1e-18);
    }

    #[test]
    fn set_energy_roundtrips_and_clamps() {
        let mut c = Capacitor::paper_default();
        c.set_energy(3e-3);
        assert!((c.energy() - 3e-3).abs() < 1e-15);
        c.set_energy(1.0); // way past the rail
        assert_eq!(c.energy(), c.max_energy());
        c.set_energy(-1.0);
        assert_eq!(c.energy(), 0.0);
    }

    #[test]
    fn time_to_energy_closed_form() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(2.0);
        let e0 = c.energy();
        // Charging up: gap / net power.
        let t = c.time_to_energy(c.boot_energy_level(), 1e-3).unwrap();
        assert!((t - (c.boot_energy_level() - e0) / 1e-3).abs() < 1e-9);
        // Unreachable: no power, or wrong sign.
        assert!(c.time_to_energy(c.boot_energy_level(), 0.0).is_none());
        assert!(c.time_to_energy(c.boot_energy_level(), -1e-3).is_none());
        // Draining down to brown-out.
        let td = c.time_to_energy(c.brownout_energy_level(), -1e-6).unwrap();
        assert!((td - (e0 - c.brownout_energy_level()) / 1e-6).abs() < 1e-6);
        // Already there.
        assert_eq!(c.time_to_energy(e0, 1e-3), Some(0.0));
    }
}
