//! The capacitor energy buffer.
//!
//! The prototype uses a 1470 µF capacitor chosen "through a mixed
//! analytical and experimental approach" (§4.1): large enough for
//! worst-case single-cycle processing, small enough to recharge quickly.
//! State is the voltage `v`; energy is ½CV². The device operates while
//! `v >= v_off` (brown-out threshold) and, after dying, restarts only once
//! `v >= v_on` (the booster's VBAT_OK rising threshold), giving the
//! classic intermittent duty cycle.

/// Capacitor + supervisor thresholds.
#[derive(Clone, Debug)]
pub struct Capacitor {
    /// Capacitance in farads (paper: 1470e-6).
    pub capacitance: f64,
    /// Rail ceiling enforced by the charger (BQ25505 OV threshold).
    pub v_max: f64,
    /// Turn-on (VBAT_OK rising) threshold: device boots at/above this.
    pub v_on: f64,
    /// Brown-out threshold: device dies below this.
    pub v_off: f64,
    /// Current voltage.
    v: f64,
}

impl Capacitor {
    /// The paper's buffer: 1470 µF, 3.6 V rail, boot at 3.0 V, die at 1.8 V
    /// (MSP430 minimum supply at 8 MHz).
    pub fn paper_default() -> Capacitor {
        Capacitor::new(1470e-6, 3.6, 3.0, 1.8)
    }

    pub fn new(capacitance: f64, v_max: f64, v_on: f64, v_off: f64) -> Capacitor {
        assert!(capacitance > 0.0);
        assert!(v_max >= v_on && v_on > v_off && v_off > 0.0);
        Capacitor { capacitance, v_max, v_on, v_off, v: 0.0 }
    }

    /// Current voltage (what the LTC1417 ADC reads).
    #[inline]
    pub fn voltage(&self) -> f64 {
        self.v
    }

    /// Stored energy, joules.
    #[inline]
    pub fn energy(&self) -> f64 {
        0.5 * self.capacitance * self.v * self.v
    }

    /// Energy available before brown-out: ½C(v² − v_off²), clamped at 0.
    ///
    /// This is the budget the GREEDY/SMART policies divide between useful
    /// computation and the final BLE transmission.
    #[inline]
    pub fn usable_energy(&self) -> f64 {
        let e = 0.5 * self.capacitance * (self.v * self.v - self.v_off * self.v_off);
        e.max(0.0)
    }

    /// Energy needed to charge from `v_off` to `v_on` (one recharge ramp).
    pub fn recharge_energy(&self) -> f64 {
        0.5 * self.capacitance * (self.v_on * self.v_on - self.v_off * self.v_off)
    }

    /// Deposit `joules` from the charger (clamped to the rail ceiling).
    pub fn charge(&mut self, joules: f64) {
        debug_assert!(joules >= 0.0);
        let e = self.energy() + joules;
        self.v = (2.0 * e / self.capacitance).sqrt().min(self.v_max);
    }

    /// Withdraw `joules` for a load operation. Returns `false` (and drains
    /// to the floor) if the buffer held less than requested — the caller
    /// treats that as a brown-out mid-operation.
    #[must_use]
    pub fn discharge(&mut self, joules: f64) -> bool {
        debug_assert!(joules >= 0.0);
        let e = self.energy() - joules;
        if e <= 0.0 {
            self.v = 0.0;
            return false;
        }
        self.v = (2.0 * e / self.capacitance).sqrt();
        true
    }

    /// True while the MCU can run.
    #[inline]
    pub fn alive(&self) -> bool {
        self.v >= self.v_off
    }

    /// True when a dead device may boot.
    #[inline]
    pub fn can_boot(&self) -> bool {
        self.v >= self.v_on
    }

    /// Force the voltage (test setup / cold start).
    pub fn set_voltage(&mut self, v: f64) {
        self.v = v.clamp(0.0, self.v_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_matches_half_cv2() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.0);
        assert!((c.energy() - 0.5 * 1470e-6 * 9.0).abs() < 1e-12);
    }

    #[test]
    fn usable_energy_is_above_brownout_only() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(1.8);
        assert_eq!(c.usable_energy(), 0.0);
        c.set_voltage(3.0);
        let want = 0.5 * 1470e-6 * (9.0 - 1.8 * 1.8);
        assert!((c.usable_energy() - want).abs() < 1e-12);
    }

    #[test]
    fn charge_clamps_at_rail() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.5);
        c.charge(1.0); // a full joule, way past the rail
        assert!((c.voltage() - 3.6).abs() < 1e-12);
    }

    #[test]
    fn discharge_roundtrip() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(3.0);
        let e0 = c.energy();
        assert!(c.discharge(1e-3));
        assert!((c.energy() - (e0 - 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn overdraw_reports_failure() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(2.0);
        assert!(!c.discharge(1.0));
        assert_eq!(c.voltage(), 0.0);
        assert!(!c.alive());
    }

    #[test]
    fn lifecycle_thresholds() {
        let mut c = Capacitor::paper_default();
        c.set_voltage(2.5);
        assert!(c.alive());
        assert!(!c.can_boot());
        c.set_voltage(3.05);
        assert!(c.can_boot());
        c.set_voltage(1.7);
        assert!(!c.alive());
    }

    #[test]
    fn recharge_energy_positive_and_consistent() {
        let c = Capacitor::paper_default();
        let want = 0.5 * 1470e-6 * (9.0 - 3.24);
        assert!((c.recharge_energy() - want).abs() < 1e-12);
    }
}
