//! Harvester front-ends.
//!
//! A [`Harvester`] answers "how much raw power is the transducer producing
//! at time `t`"; the engine multiplies through the [`super::booster`] and
//! integrates into the [`super::capacitor`]. Three sources:
//!
//! * [`Harvester::Constant`] — bench/test source.
//! * [`Harvester::Replay`] — replays a [`PowerTrace`] (the paper's Renesas
//!   trace-replay supply, §6.3).
//! * [`Harvester::Synth`] — a pre-generated run-length [`Piecewise`]
//!   pattern, wrapping at its period. The `energy::synth` environment
//!   generator emits these natively, so synthetic supplies reach the
//!   analytic engine with no sampled intermediate.
//! * [`kinetic_power_trace`] — converts a wrist-acceleration signal into
//!   the output of a resonant electromagnetic transducer (ReVibe modelQ,
//!   §4.1): band-pass around the customised resonance frequency, power
//!   proportional to the squared filtered velocity, saturating at the
//!   transducer's rated output.

use crate::energy::traces::{Piecewise, PowerTrace};
use crate::util::dsp::Biquad;

/// A source of ambient power.
#[derive(Clone, Debug)]
pub enum Harvester {
    /// Constant raw power, watts.
    Constant(f64),
    /// Replay a trace, wrapping at the end.
    Replay(PowerTrace),
    /// A generated segment pattern, wrapping at its period (the
    /// `energy::synth` stochastic environments).
    Synth(Piecewise),
}

impl Harvester {
    /// Raw transducer output power at absolute time `t`, watts.
    #[inline]
    pub fn power_at(&self, t: f64) -> f64 {
        match self {
            Harvester::Constant(p) => *p,
            Harvester::Replay(trace) => trace.power_at(t),
            Harvester::Synth(pw) => pw.power_at(t),
        }
    }

    /// Mean raw power, watts.
    pub fn mean_power(&self) -> f64 {
        match self {
            Harvester::Constant(p) => *p,
            Harvester::Replay(trace) => trace.mean_power(),
            Harvester::Synth(pw) => pw.mean_power(),
        }
    }

    /// The harvester's output as run-length-coalesced constant-power
    /// segments (one infinite segment for [`Harvester::Constant`]). The
    /// event-driven engine builds its stepping tables from this.
    pub fn piecewise(&self) -> Piecewise {
        match self {
            Harvester::Constant(p) => Piecewise::constant(*p),
            Harvester::Replay(trace) => trace.piecewise(),
            Harvester::Synth(pw) => pw.clone(),
        }
    }

    /// Infinite iterator of constant-power segments covering `[t, ∞)`,
    /// wrapping around the trace end exactly like
    /// [`Harvester::power_at`]. The first yielded segment is the one
    /// containing `t` (its `start` may precede `t`).
    pub fn segments(&self, t: f64) -> Segments {
        Segments::new(self.piecewise(), t)
    }
}

/// One constant-power span of harvester output, in absolute time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Segment {
    /// Absolute start time, seconds.
    pub start: f64,
    /// Absolute end time, seconds (`f64::INFINITY` for a constant source).
    pub end: f64,
    /// Raw harvester power over the span, watts.
    pub power: f64,
}

/// Infinite segment iterator over a (wrapping) harvester — see
/// [`Harvester::segments`].
#[derive(Clone, Debug)]
pub struct Segments {
    pw: Piecewise,
    idx: usize,
    epoch: u64,
}

impl Segments {
    fn new(pw: Piecewise, t: f64) -> Segments {
        let (epoch, idx) = pw.locate(t);
        Segments { pw, idx, epoch }
    }

    fn epoch_start(&self) -> f64 {
        if self.epoch == 0 {
            0.0
        } else {
            self.epoch as f64 * self.pw.period
        }
    }
}

impl Iterator for Segments {
    type Item = Segment;

    fn next(&mut self) -> Option<Segment> {
        let base = self.epoch_start();
        // The last segment of a period ends exactly at (epoch+1)·period
        // so consecutive periods tile with no float seam — the same rule
        // the engine's stepping cursor applies.
        let end = if self.pw.period.is_finite() && self.idx + 1 == self.pw.len() {
            (self.epoch + 1) as f64 * self.pw.period
        } else {
            base + self.pw.ends[self.idx]
        };
        let seg = Segment {
            start: base + self.pw.start(self.idx),
            end,
            power: self.pw.powers[self.idx],
        };
        if self.idx + 1 < self.pw.len() {
            self.idx += 1;
        } else if self.pw.period.is_finite() {
            self.idx = 0;
            self.epoch += 1;
        }
        // A never-ending segment (constant source) is yielded forever.
        Some(seg)
    }
}

/// Parameters of the kinetic transducer model.
#[derive(Clone, Debug)]
pub struct KineticConfig {
    /// Resonance frequency, Hz. The paper orders the modelQ with a
    /// customised resonance matched to the wrist-motion spectrum; human
    /// gait concentrates energy around ~2 Hz.
    pub resonance_hz: f64,
    /// Resonator quality factor.
    pub q: f64,
    /// Electromechanical conversion gain: watts per (m/s²)² of filtered
    /// acceleration. Calibrated so brisk walking yields ~1-2 mW, matching
    /// wrist-worn electromagnetic harvester measurements.
    pub gain: f64,
    /// Transducer rated (saturation) output, watts.
    pub max_power: f64,
}

impl Default for KineticConfig {
    fn default() -> KineticConfig {
        KineticConfig { resonance_hz: 2.1, q: 2.5, gain: 2.5e-4, max_power: 8.0e-3 }
    }
}

/// Convert an acceleration-magnitude signal (m/s², gravity removed,
/// sampled at `fs` Hz) into the transducer's output power trace.
pub fn kinetic_power_trace(accel: &[f64], fs: f64, cfg: &KineticConfig) -> PowerTrace {
    let mut bp = Biquad::bandpass(cfg.resonance_hz, fs, cfg.q);
    let samples = accel
        .iter()
        .map(|&a| {
            let v = bp.step(a);
            (cfg.gain * v * v).min(cfg.max_power)
        })
        .collect();
    PowerTrace { dt: 1.0 / fs, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::f64::consts::PI;

    /// Synthetic "walking" acceleration: 2 Hz gait plus noise.
    fn walking(fs: f64, secs: f64, amp: f64) -> Vec<f64> {
        let mut rng = Rng::new(31);
        (0..(fs * secs) as usize)
            .map(|i| {
                let t = i as f64 / fs;
                amp * (2.0 * PI * 2.0 * t).sin() + 0.3 * rng.gaussian()
            })
            .collect()
    }

    #[test]
    fn constant_harvester() {
        let h = Harvester::Constant(1e-3);
        assert_eq!(h.power_at(0.0), 1e-3);
        assert_eq!(h.power_at(1e6), 1e-3);
        assert_eq!(h.mean_power(), 1e-3);
    }

    #[test]
    fn constant_segments_are_one_infinite_span() {
        let h = Harvester::Constant(2e-3);
        let mut segs = h.segments(123.0);
        let s = segs.next().unwrap();
        assert_eq!(s.start, 0.0);
        assert!(s.end.is_infinite());
        assert_eq!(s.power, 2e-3);
        // The iterator never ends.
        assert_eq!(segs.next().unwrap().power, 2e-3);
    }

    #[test]
    fn replay_segments_tile_time_and_match_power_at() {
        let trace = PowerTrace { dt: 0.5, samples: vec![1.0, 1.0, 3.0, 0.0] };
        let h = Harvester::Replay(trace);
        // From t=0: [0,1)@1, [1,1.5)@3, [1.5,2)@0, then the wrap.
        let segs: Vec<Segment> = h.segments(0.0).take(5).collect();
        assert_eq!(segs[0], Segment { start: 0.0, end: 1.0, power: 1.0 });
        assert_eq!(segs[1], Segment { start: 1.0, end: 1.5, power: 3.0 });
        assert_eq!(segs[2], Segment { start: 1.5, end: 2.0, power: 0.0 });
        assert_eq!(segs[3], Segment { start: 2.0, end: 3.0, power: 1.0 });
        // Contiguous tiling, and powers agree with point sampling.
        for w in segs.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        for s in &segs {
            let mid = 0.5 * (s.start + s.end.min(s.start + 1.0));
            assert_eq!(s.power, h.power_at(mid), "segment {s:?}");
        }
        // Seeking into the middle starts at the covering segment.
        let first = h.segments(1.2).next().unwrap();
        assert_eq!(first, Segment { start: 1.0, end: 1.5, power: 3.0 });
        // Seeking past one period wraps.
        let wrapped = h.segments(2.7).next().unwrap();
        assert_eq!(wrapped, Segment { start: 2.0, end: 3.0, power: 1.0 });
    }

    #[test]
    fn synth_harvester_wraps_like_replay() {
        let pw = Piecewise { ends: vec![1.0, 3.0], powers: vec![2e-3, 0.0], period: 3.0 };
        let h = Harvester::Synth(pw.clone());
        assert_eq!(h.power_at(0.5), 2e-3);
        assert_eq!(h.power_at(2.0), 0.0);
        assert_eq!(h.power_at(3.5), 2e-3); // wrapped
        assert!((h.mean_power() - 2e-3 / 3.0).abs() < 1e-18);
        // The engine-facing views agree with the stored pattern.
        assert_eq!(h.piecewise().ends, pw.ends);
        let segs: Vec<Segment> = h.segments(0.0).take(3).collect();
        assert_eq!(segs[0], Segment { start: 0.0, end: 1.0, power: 2e-3 });
        assert_eq!(segs[1], Segment { start: 1.0, end: 3.0, power: 0.0 });
        assert_eq!(segs[2], Segment { start: 3.0, end: 4.0, power: 2e-3 });
    }

    #[test]
    fn walking_beats_stillness() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let walk = kinetic_power_trace(&walking(fs, 60.0, 8.0), fs, &cfg);
        let still: Vec<f64> = {
            let mut rng = Rng::new(5);
            (0..3000).map(|_| 0.05 * rng.gaussian()).collect()
        };
        let rest = kinetic_power_trace(&still, fs, &cfg);
        assert!(
            walk.mean_power() > 50.0 * rest.mean_power(),
            "walk={} rest={}",
            walk.mean_power(),
            rest.mean_power()
        );
        // Walking lands in the ~mW regime.
        assert!(walk.mean_power() > 0.3e-3, "mean={}", walk.mean_power());
    }

    #[test]
    fn resonance_selectivity() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let make_tone = |f: f64| -> Vec<f64> {
            (0..3000).map(|i| 8.0 * (2.0 * PI * f * i as f64 / fs).sin()).collect()
        };
        let at_res = kinetic_power_trace(&make_tone(2.1), fs, &cfg).mean_power();
        let off_res = kinetic_power_trace(&make_tone(10.0), fs, &cfg).mean_power();
        assert!(at_res > 5.0 * off_res, "at={at_res} off={off_res}");
    }

    #[test]
    fn saturation_respected() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let violent: Vec<f64> =
            (0..1000).map(|i| 100.0 * (2.0 * PI * 2.1 * i as f64 / fs).sin()).collect();
        let trace = kinetic_power_trace(&violent, fs, &cfg);
        assert!(trace.samples.iter().all(|&p| p <= cfg.max_power + 1e-15));
    }
}
