//! Harvester front-ends.
//!
//! A [`Harvester`] answers "how much raw power is the transducer producing
//! at time `t`"; the engine multiplies through the [`super::booster`] and
//! integrates into the [`super::capacitor`]. Three sources:
//!
//! * [`Harvester::Constant`] — bench/test source.
//! * [`Harvester::Replay`] — replays a [`PowerTrace`] (the paper's Renesas
//!   trace-replay supply, §6.3).
//! * [`kinetic_power_trace`] — converts a wrist-acceleration signal into
//!   the output of a resonant electromagnetic transducer (ReVibe modelQ,
//!   §4.1): band-pass around the customised resonance frequency, power
//!   proportional to the squared filtered velocity, saturating at the
//!   transducer's rated output.

use crate::energy::traces::PowerTrace;
use crate::util::dsp::Biquad;

/// A source of ambient power.
#[derive(Clone, Debug)]
pub enum Harvester {
    /// Constant raw power, watts.
    Constant(f64),
    /// Replay a trace, wrapping at the end.
    Replay(PowerTrace),
}

impl Harvester {
    /// Raw transducer output power at absolute time `t`, watts.
    #[inline]
    pub fn power_at(&self, t: f64) -> f64 {
        match self {
            Harvester::Constant(p) => *p,
            Harvester::Replay(trace) => trace.power_at(t),
        }
    }

    /// Mean raw power, watts.
    pub fn mean_power(&self) -> f64 {
        match self {
            Harvester::Constant(p) => *p,
            Harvester::Replay(trace) => trace.mean_power(),
        }
    }
}

/// Parameters of the kinetic transducer model.
#[derive(Clone, Debug)]
pub struct KineticConfig {
    /// Resonance frequency, Hz. The paper orders the modelQ with a
    /// customised resonance matched to the wrist-motion spectrum; human
    /// gait concentrates energy around ~2 Hz.
    pub resonance_hz: f64,
    /// Resonator quality factor.
    pub q: f64,
    /// Electromechanical conversion gain: watts per (m/s²)² of filtered
    /// acceleration. Calibrated so brisk walking yields ~1-2 mW, matching
    /// wrist-worn electromagnetic harvester measurements.
    pub gain: f64,
    /// Transducer rated (saturation) output, watts.
    pub max_power: f64,
}

impl Default for KineticConfig {
    fn default() -> KineticConfig {
        KineticConfig { resonance_hz: 2.1, q: 2.5, gain: 2.5e-4, max_power: 8.0e-3 }
    }
}

/// Convert an acceleration-magnitude signal (m/s², gravity removed,
/// sampled at `fs` Hz) into the transducer's output power trace.
pub fn kinetic_power_trace(accel: &[f64], fs: f64, cfg: &KineticConfig) -> PowerTrace {
    let mut bp = Biquad::bandpass(cfg.resonance_hz, fs, cfg.q);
    let samples = accel
        .iter()
        .map(|&a| {
            let v = bp.step(a);
            (cfg.gain * v * v).min(cfg.max_power)
        })
        .collect();
    PowerTrace { dt: 1.0 / fs, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::f64::consts::PI;

    /// Synthetic "walking" acceleration: 2 Hz gait plus noise.
    fn walking(fs: f64, secs: f64, amp: f64) -> Vec<f64> {
        let mut rng = Rng::new(31);
        (0..(fs * secs) as usize)
            .map(|i| {
                let t = i as f64 / fs;
                amp * (2.0 * PI * 2.0 * t).sin() + 0.3 * rng.gaussian()
            })
            .collect()
    }

    #[test]
    fn constant_harvester() {
        let h = Harvester::Constant(1e-3);
        assert_eq!(h.power_at(0.0), 1e-3);
        assert_eq!(h.power_at(1e6), 1e-3);
        assert_eq!(h.mean_power(), 1e-3);
    }

    #[test]
    fn walking_beats_stillness() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let walk = kinetic_power_trace(&walking(fs, 60.0, 8.0), fs, &cfg);
        let still: Vec<f64> = {
            let mut rng = Rng::new(5);
            (0..3000).map(|_| 0.05 * rng.gaussian()).collect()
        };
        let rest = kinetic_power_trace(&still, fs, &cfg);
        assert!(
            walk.mean_power() > 50.0 * rest.mean_power(),
            "walk={} rest={}",
            walk.mean_power(),
            rest.mean_power()
        );
        // Walking lands in the ~mW regime.
        assert!(walk.mean_power() > 0.3e-3, "mean={}", walk.mean_power());
    }

    #[test]
    fn resonance_selectivity() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let make_tone = |f: f64| -> Vec<f64> {
            (0..3000).map(|i| 8.0 * (2.0 * PI * f * i as f64 / fs).sin()).collect()
        };
        let at_res = kinetic_power_trace(&make_tone(2.1), fs, &cfg).mean_power();
        let off_res = kinetic_power_trace(&make_tone(10.0), fs, &cfg).mean_power();
        assert!(at_res > 5.0 * off_res, "at={at_res} off={off_res}");
    }

    #[test]
    fn saturation_respected() {
        let fs = 50.0;
        let cfg = KineticConfig::default();
        let violent: Vec<f64> =
            (0..1000).map(|i| 100.0 * (2.0 * PI * 2.1 * i as f64 / fs).sin()).collect();
        let trace = kinetic_power_trace(&violent, fs, &cfg);
        assert!(trace.samples.iter().all(|&p| p <= cfg.max_power + 1e-15));
    }
}
