//! BQ25505-like boost charger / power-management model.
//!
//! The harvester's raw output passes through a boost converter with a
//! power-dependent efficiency before reaching the capacitor. Efficiency is
//! low in the µW regime (quiescent losses dominate) and saturates in the
//! mW regime, matching the BQ25505 datasheet curves qualitatively. A
//! cold-start threshold models the charger's inability to boost from a
//! fully dead buffer below a minimum input power.

/// Boost charger model.
#[derive(Clone, Debug)]
pub struct Booster {
    /// Peak conversion efficiency (fraction), reached at high input power.
    pub eta_max: f64,
    /// Input power (W) at which efficiency reaches half of `eta_max`
    /// above `eta_min` — the knee of the efficiency curve.
    pub knee_power: f64,
    /// Efficiency floor at vanishing input power.
    pub eta_min: f64,
    /// Quiescent power drawn by the charger itself, W.
    pub quiescent: f64,
    /// Below this input power a cold (0 V) buffer cannot start charging.
    pub cold_start_power: f64,
}

impl Booster {
    /// Buffer voltage below which the cold-start gate can engage. Above
    /// it, [`Booster::output_power`] does not depend on the buffer
    /// voltage at all — the property that makes the capacitor's energy
    /// trajectory *linear* within one constant-power harvester segment
    /// and gives the analytic engine its closed-form threshold crossings.
    pub const COLD_GATE_V: f64 = 0.05;

    /// Parameters in the regime of the BQ25505 used by the prototype.
    pub fn paper_default() -> Booster {
        Booster {
            eta_max: 0.85,
            knee_power: 80e-6,
            eta_min: 0.30,
            quiescent: 0.4e-6,
            cold_start_power: 15e-6,
        }
    }

    /// Conversion efficiency at the given input power.
    pub fn efficiency(&self, p_in: f64) -> f64 {
        if p_in <= 0.0 {
            return 0.0;
        }
        // Saturating curve: eta_min + (eta_max - eta_min) * p/(p + knee).
        self.eta_min + (self.eta_max - self.eta_min) * p_in / (p_in + self.knee_power)
    }

    /// Power delivered to the capacitor for `p_in` watts harvested once
    /// the buffer is warm (above [`Booster::COLD_GATE_V`]). Voltage-
    /// independent: constant within a constant-power harvester segment.
    #[inline]
    pub fn warm_output_power(&self, p_in: f64) -> f64 {
        (p_in * self.efficiency(p_in) - self.quiescent).max(0.0)
    }

    /// Power delivered to the capacitor for `p_in` watts harvested.
    ///
    /// `buffer_voltage` gates cold start: a dead buffer needs
    /// `cold_start_power` before any charge accumulates.
    pub fn output_power(&self, p_in: f64, buffer_voltage: f64) -> f64 {
        if buffer_voltage <= Booster::COLD_GATE_V && p_in < self.cold_start_power {
            return 0.0;
        }
        self.warm_output_power(p_in)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_monotone_and_bounded() {
        let b = Booster::paper_default();
        let mut last = 0.0;
        for i in 1..200 {
            let p = i as f64 * 20e-6;
            let eta = b.efficiency(p);
            assert!(eta >= last - 1e-12, "efficiency must not decrease");
            assert!(eta <= b.eta_max);
            last = eta;
        }
        assert!(b.efficiency(10e-3) > 0.8);
    }

    #[test]
    fn cold_start_gating() {
        let b = Booster::paper_default();
        assert_eq!(b.output_power(10e-6, 0.0), 0.0); // too weak to cold-start
        assert!(b.output_power(10e-6, 2.0) > 0.0); // warm buffer: fine
        assert!(b.output_power(100e-6, 0.0) > 0.0); // strong enough to cold-start
    }

    #[test]
    fn output_is_voltage_independent_above_the_cold_gate() {
        // The linearity property the analytic engine relies on: for any
        // warm buffer voltage the output depends on input power only.
        let b = Booster::paper_default();
        for p in [0.0, 1e-6, 10e-6, 100e-6, 1e-3, 5e-3] {
            let warm = b.warm_output_power(p);
            for v in [0.06, 0.5, 1.8, 3.0, 3.6] {
                assert_eq!(b.output_power(p, v), warm, "p={p} v={v}");
            }
        }
    }

    #[test]
    fn quiescent_subtracted() {
        let b = Booster::paper_default();
        let p = 1e-6;
        assert!(b.output_power(p, 2.0) < p * b.efficiency(p));
        assert_eq!(b.output_power(0.0, 2.0), 0.0);
    }
}
