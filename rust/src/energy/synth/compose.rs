//! Multi-source composition — amalgamated harvesting.
//!
//! Devices that draw from several transducers at once combine them in
//! one of three ways, mirrored here as pointwise operators over the
//! sources' piecewise patterns:
//!
//! * [`Combine::Sum`] — independent converters, outputs added (each
//!   source has its own charger feeding the shared buffer).
//! * [`Combine::Max`] — ideal power-ORing: a lossless switch always
//!   connects the strongest source.
//! * [`Combine::Switchover`] — power-ORing through a real switch
//!   matrix: the strongest source scaled by a conversion efficiency.
//!
//! [`merge`] is a k-way boundary merge: the output has one segment per
//! *union* boundary, adjacent equal powers are re-coalesced, and the
//! result is again a native [`Piecewise`] — composition never introduces
//! a sample grid, so composite environments stay O(events) through the
//! analytic engine.

use super::sources::SegBuf;
use crate::energy::traces::Piecewise;

/// How a multi-source environment combines its sources.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    /// Outputs added.
    Sum,
    /// Ideal power-ORing: pointwise maximum.
    Max,
    /// Power-ORing through a switch matrix: maximum scaled by
    /// `switch_efficiency`.
    Switchover,
}

impl Combine {
    pub fn name(&self) -> &'static str {
        match self {
            Combine::Sum => "sum",
            Combine::Max => "max",
            Combine::Switchover => "switchover",
        }
    }

    pub fn from_name(s: &str) -> Option<Combine> {
        match s {
            "sum" => Some(Combine::Sum),
            "max" => Some(Combine::Max),
            "switchover" => Some(Combine::Switchover),
            _ => None,
        }
    }
}

/// Merge the sources' patterns into one composite pattern over
/// `[0, period)`. Every input must span exactly `period` (the synth
/// builder generates all sources over the spec's duration, so their
/// last ends are bit-equal to it). `switch_efficiency` only applies to
/// [`Combine::Switchover`].
///
/// # Panics
///
/// Panics when `parts` is empty or an input's last segment does not end
/// exactly at `period` — a hard assert (not `debug_assert`): a
/// violating input would otherwise pin the boundary cursor below
/// `period` and spin this loop forever in release builds.
pub fn merge(
    parts: &[Piecewise],
    combine: Combine,
    switch_efficiency: f64,
    period: f64,
) -> Piecewise {
    assert!(!parts.is_empty(), "merge needs at least one source pattern");
    for p in parts {
        assert_eq!(p.period, period, "merge inputs must share the period");
        assert_eq!(*p.ends.last().unwrap(), period, "merge inputs must span the period");
    }
    let mut idx = vec![0usize; parts.len()];
    let mut buf = SegBuf::new();
    let mut t = 0.0;
    while t < period {
        let power = match combine {
            Combine::Sum => parts.iter().zip(&idx).map(|(p, &j)| p.powers[j]).sum(),
            Combine::Max => {
                parts.iter().zip(&idx).map(|(p, &j)| p.powers[j]).fold(0.0, f64::max)
            }
            Combine::Switchover => {
                switch_efficiency
                    * parts.iter().zip(&idx).map(|(p, &j)| p.powers[j]).fold(0.0, f64::max)
            }
        };
        // Next union boundary strictly after t (each part's last end is
        // exactly `period`, so the fold can never exceed it).
        let next = parts
            .iter()
            .zip(&idx)
            .map(|(p, &j)| p.ends[j])
            .fold(period, f64::min);
        buf.push(next - t, power);
        t = next;
        for (p, j) in parts.iter().zip(idx.iter_mut()) {
            while *j + 1 < p.len() && p.ends[*j] <= t {
                *j += 1;
            }
        }
    }
    buf.finish(period)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Piecewise {
        Piecewise { ends: vec![2.0, 6.0, 10.0], powers: vec![1.0e-3, 0.0, 2.0e-3], period: 10.0 }
    }

    fn b() -> Piecewise {
        Piecewise { ends: vec![5.0, 10.0], powers: vec![0.5e-3, 1.5e-3], period: 10.0 }
    }

    #[test]
    fn sum_merges_union_boundaries() {
        let m = merge(&[a(), b()], Combine::Sum, 1.0, 10.0);
        assert_eq!(m.ends, vec![2.0, 5.0, 6.0, 10.0]);
        assert_eq!(m.powers, vec![1.5e-3, 0.5e-3, 1.5e-3, 3.5e-3]);
        // Energy is additive under Sum.
        let want = a().energy_per_period() + b().energy_per_period();
        assert!((m.energy_per_period() - want).abs() < 1e-15);
    }

    #[test]
    fn max_selects_the_strongest_source() {
        let m = merge(&[a(), b()], Combine::Max, 1.0, 10.0);
        assert_eq!(m.ends, vec![2.0, 5.0, 6.0, 10.0]);
        assert_eq!(m.powers, vec![1.0e-3, 0.5e-3, 1.5e-3, 2.0e-3]);
        // Pointwise: max dominates each source, never exceeds the sum.
        for t in [0.5, 3.0, 5.5, 8.0] {
            assert!(m.power_at(t) >= a().power_at(t).max(b().power_at(t)) - 1e-18);
            assert!(m.power_at(t) <= a().power_at(t) + b().power_at(t) + 1e-18);
        }
    }

    #[test]
    fn switchover_scales_the_max_by_the_switch_efficiency() {
        let m = merge(&[a(), b()], Combine::Switchover, 0.5, 10.0);
        assert_eq!(m.powers, vec![0.5e-3, 0.25e-3, 0.75e-3, 1.0e-3]);
        let ideal = merge(&[a(), b()], Combine::Max, 1.0, 10.0);
        for (got, want) in m.powers.iter().zip(&ideal.powers) {
            assert_eq!(*got, 0.5 * want);
        }
    }

    #[test]
    fn single_source_sum_is_identity() {
        let m = merge(&[a()], Combine::Sum, 1.0, 10.0);
        assert_eq!(m.ends, a().ends);
        assert_eq!(m.powers, a().powers);
    }

    #[test]
    fn equal_powers_recoalesce_across_boundaries() {
        // Two complementary square waves sum to a constant: the merge
        // must coalesce back to a single segment.
        let x = Piecewise { ends: vec![1.0, 2.0], powers: vec![1e-3, 2e-3], period: 2.0 };
        let y = Piecewise { ends: vec![1.0, 2.0], powers: vec![2e-3, 1e-3], period: 2.0 };
        let m = merge(&[x, y], Combine::Sum, 1.0, 2.0);
        assert_eq!(m.ends, vec![2.0]);
        assert_eq!(m.powers, vec![3e-3]);
    }
}
