//! Parametric ambient-energy source models.
//!
//! Each model turns a handful of physical knobs plus a seeded [`Rng`]
//! stream into run-length-coalesced constant-power segments — the
//! [`Piecewise`] representation the analytic engine steps over — with
//! **no sampled intermediate**: segment boundaries fall only where the
//! model actually changes (envelope quantisation ticks, Markov state
//! flips, burst edges), so a generated environment costs the engine
//! O(events), never O(seconds/dt).
//!
//! The four models cover the harvesting families the paper and the
//! related amalgamated-harvesting literature draw from:
//!
//! * [`SolarSpec`] — diurnal irradiance envelope (sin² day arc, dark
//!   night) with Markov-modulated two-state cloud occlusion.
//! * [`RfBurstSpec`] — duty-cycled RF: exponential off gaps interleaved
//!   with short bursts, optional per-burst field-strength jitter.
//! * [`ThermalSpec`] — slow thermal-gradient ramp: a raised-cosine cycle
//!   quantised at a coarse tick, with optional per-tick noise.
//! * [`KineticSurrogateSpec`] — shaped-noise surrogate of a wrist
//!   transducer: two-state activity bouts whose in-bout intensity is an
//!   Ornstein-Uhlenbeck level sampled per tick, saturating at the rated
//!   output.

use crate::energy::traces::Piecewise;
use crate::util::json::{self, opt_f64, Value};
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Dwell floor, seconds: no generated state (burst, gap, cloud dwell)
/// is shorter than this, which bounds worst-case segment counts.
pub const MIN_DWELL: f64 = 0.05;

/// Segment accumulator: push `(duration, power)` spans, adjacent equal
/// powers are run-length coalesced, and [`SegBuf::finish`] pins the last
/// end to the exact pattern duration (no float-accumulation drift at the
/// wrap seam).
pub(crate) struct SegBuf {
    ends: Vec<f64>,
    powers: Vec<f64>,
    t: f64,
}

impl SegBuf {
    pub(crate) fn new() -> SegBuf {
        SegBuf { ends: Vec::new(), powers: Vec::new(), t: 0.0 }
    }

    pub(crate) fn push(&mut self, duration: f64, power: f64) {
        if duration <= 0.0 {
            return;
        }
        let end = self.t + duration;
        if let Some(&last_end) = self.ends.last() {
            if end <= last_end {
                // A sub-ulp span: `t + duration` rounded back onto the
                // previous end. Dropping it keeps ends strictly
                // increasing; the energy lost is below float resolution.
                return;
            }
            if *self.powers.last().unwrap() == power {
                self.t = end;
                *self.ends.last_mut().unwrap() = end;
                return;
            }
        }
        self.t = end;
        self.ends.push(end);
        self.powers.push(power);
    }

    /// Close the pattern at exactly `duration` seconds. The accumulated
    /// end may differ from `duration` by float noise; the final segment
    /// absorbs it so `ends.last() == duration` holds bit-exactly (the
    /// invariant [`Piecewise`] wrapping relies on).
    pub(crate) fn finish(mut self, duration: f64) -> Piecewise {
        if self.ends.is_empty() {
            return Piecewise { ends: vec![duration], powers: vec![0.0], period: duration };
        }
        *self.ends.last_mut().unwrap() = duration;
        // Float drift could leave the penultimate end at/above the pinned
        // last end; drop any such degenerate tail segments.
        while self.ends.len() >= 2 && self.ends[self.ends.len() - 2] >= duration {
            let last = self.ends.len() - 1;
            self.ends.remove(last - 1);
            self.powers.remove(last - 1);
            *self.ends.last_mut().unwrap() = duration;
        }
        Piecewise { ends: self.ends, powers: self.powers, period: duration }
    }
}

/// One ambient source inside a [`super::SynthSpec`].
#[derive(Clone, Debug, PartialEq)]
pub enum SourceSpec {
    Solar(SolarSpec),
    Rf(RfBurstSpec),
    Thermal(ThermalSpec),
    Kinetic(KineticSurrogateSpec),
}

/// Diurnal solar with Markov-modulated cloud occlusion.
///
/// The clear-sky envelope over one diurnal `period` is a sin² arc across
/// the daylight window (`day_fraction` of the period) and exactly zero at
/// night. A two-state Markov chain (exponential dwells `mean_clear` /
/// `mean_cloud`) multiplies the envelope by 1 or `cloud_attenuation`.
/// The envelope is quantised at `env_dt` ticks (segment power = envelope
/// at the tick midpoint), so a generated day is O(period/env_dt)
/// segments — nights coalesce to single zero segments.
#[derive(Clone, Debug, PartialEq)]
pub struct SolarSpec {
    /// Clear-sky peak output at solar noon, watts.
    pub peak: f64,
    /// Fraction of the diurnal period with daylight, (0, 1].
    pub day_fraction: f64,
    /// Diurnal period, seconds. Builtin scenarios compress the day so a
    /// campaign horizon sees several light/dark cycles.
    pub period: f64,
    /// Envelope quantisation tick, seconds.
    pub env_dt: f64,
    /// Fraction of power surviving an occlusion, [0, 1].
    pub cloud_attenuation: f64,
    /// Mean clear-sky dwell, seconds (exponential).
    pub mean_clear: f64,
    /// Mean occluded dwell, seconds (exponential).
    pub mean_cloud: f64,
}

/// Duty-cycled RF bursts (Mementos/WISP-like): exponential off gaps of
/// mean `mean_off` interleaved with bursts of mean `mean_on` at
/// `burst_power`, each burst's level jittered by `1 + jitter·N(0,1)`
/// (clamped at zero). One burst is one segment.
#[derive(Clone, Debug, PartialEq)]
pub struct RfBurstSpec {
    /// Nominal in-burst output, watts.
    pub burst_power: f64,
    /// Mean burst length, seconds (exponential).
    pub mean_on: f64,
    /// Mean gap length, seconds (exponential).
    pub mean_off: f64,
    /// Relative per-burst amplitude jitter (0 disables).
    pub jitter: f64,
}

/// Slow thermal-gradient ramp: `base + amplitude·½(1 − cos 2πt/period)`
/// quantised at `env_dt`, with optional relative per-tick noise — the
/// day-scale TEG drift of a device strapped to a warm machine.
#[derive(Clone, Debug, PartialEq)]
pub struct ThermalSpec {
    /// Output floor, watts.
    pub base: f64,
    /// Peak rise above the floor, watts.
    pub amplitude: f64,
    /// Ramp cycle, seconds.
    pub period: f64,
    /// Quantisation tick, seconds.
    pub env_dt: f64,
    /// Relative per-tick noise (0 disables).
    pub noise: f64,
}

/// Shaped-noise kinetic surrogate: two-state activity (exponential
/// `mean_active` / `mean_rest` bouts); within a bout the intensity is an
/// Ornstein-Uhlenbeck level around `mean_power` (relaxation `tau`,
/// relative std-dev `rel_sigma`) sampled every `env_dt` and clamped to
/// `[0, max_power]`; rest bouts are exactly zero. A statistical stand-in
/// for the band-passed wrist-acceleration transducer that needs no
/// recorded acceleration signal.
#[derive(Clone, Debug, PartialEq)]
pub struct KineticSurrogateSpec {
    /// Mean in-bout output, watts.
    pub mean_power: f64,
    /// Transducer rated (saturation) output, watts.
    pub max_power: f64,
    /// Mean activity bout, seconds (exponential).
    pub mean_active: f64,
    /// Mean rest bout, seconds (exponential).
    pub mean_rest: f64,
    /// OU relaxation time, seconds.
    pub tau: f64,
    /// OU relative std-dev.
    pub rel_sigma: f64,
    /// Intensity sampling tick, seconds.
    pub env_dt: f64,
}

impl SourceSpec {
    /// JSON discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            SourceSpec::Solar(_) => "solar",
            SourceSpec::Rf(_) => "rf",
            SourceSpec::Thermal(_) => "thermal",
            SourceSpec::Kinetic(_) => "kinetic",
        }
    }

    /// Analytic expectation of the source's long-horizon mean power,
    /// watts — the centre of the statistical band `tests/
    /// synth_properties.rs` gates generated environments against.
    pub fn expected_mean_power(&self) -> f64 {
        match self {
            SourceSpec::Solar(s) => {
                // sin² averages to ½ over the day arc; the Markov gain
                // averages to its stationary mix.
                let gain = (s.mean_clear + s.cloud_attenuation * s.mean_cloud)
                    / (s.mean_clear + s.mean_cloud);
                s.peak * 0.5 * s.day_fraction * gain
            }
            SourceSpec::Rf(s) => s.burst_power * s.mean_on / (s.mean_on + s.mean_off),
            SourceSpec::Thermal(s) => s.base + 0.5 * s.amplitude,
            SourceSpec::Kinetic(s) => {
                s.mean_power * s.mean_active / (s.mean_active + s.mean_rest)
            }
        }
    }

    /// Expected number of segments a `duration`-second pattern emits —
    /// what [`super::SynthSpec::validate`] budgets against so a hostile
    /// spec cannot demand unbounded generation work.
    pub fn expected_segments(&self, duration: f64) -> f64 {
        match self {
            SourceSpec::Solar(s) => {
                duration / s.env_dt
                    + 2.0 * duration / s.mean_clear.min(s.mean_cloud)
                    + 4.0
            }
            SourceSpec::Rf(s) => 2.0 * duration / s.mean_on.min(s.mean_off) + 4.0,
            SourceSpec::Thermal(s) => duration / s.env_dt + 4.0,
            SourceSpec::Kinetic(s) => {
                duration / s.env_dt + 2.0 * duration / s.mean_active.min(s.mean_rest) + 4.0
            }
        }
    }

    /// Parameter validation (everything the JSON parser's finiteness
    /// guarantee does not already cover).
    pub fn validate(&self) -> Result<(), String> {
        fn range(name: &str, x: f64, lo: f64, hi: f64) -> Result<(), String> {
            if (lo..=hi).contains(&x) {
                Ok(())
            } else {
                Err(format!("{name} must be in [{lo}, {hi}] (got {x})"))
            }
        }
        match self {
            SourceSpec::Solar(s) => {
                range("solar peak", s.peak, 0.0, 10.0)?;
                if !(s.day_fraction > 0.0 && s.day_fraction <= 1.0) {
                    return Err(format!(
                        "solar day_fraction must be in (0, 1] (got {})",
                        s.day_fraction
                    ));
                }
                range("solar period", s.period, 10.0, 604800.0)?;
                range("solar env_dt", s.env_dt, MIN_DWELL, s.period)?;
                range("solar cloud_attenuation", s.cloud_attenuation, 0.0, 1.0)?;
                range("solar mean_clear", s.mean_clear, 0.5, 1e6)?;
                range("solar mean_cloud", s.mean_cloud, 0.5, 1e6)?;
            }
            SourceSpec::Rf(s) => {
                range("rf burst_power", s.burst_power, 0.0, 10.0)?;
                range("rf mean_on", s.mean_on, MIN_DWELL, 1e6)?;
                range("rf mean_off", s.mean_off, MIN_DWELL, 1e6)?;
                range("rf jitter", s.jitter, 0.0, 3.0)?;
            }
            SourceSpec::Thermal(s) => {
                range("thermal base", s.base, 0.0, 10.0)?;
                range("thermal amplitude", s.amplitude, 0.0, 10.0)?;
                range("thermal period", s.period, 10.0, 604800.0)?;
                range("thermal env_dt", s.env_dt, MIN_DWELL, s.period)?;
                range("thermal noise", s.noise, 0.0, 3.0)?;
            }
            SourceSpec::Kinetic(s) => {
                range("kinetic mean_power", s.mean_power, 0.0, 10.0)?;
                if !(s.max_power > 0.0 && s.max_power <= 10.0) {
                    return Err(format!(
                        "kinetic max_power must be in (0, 10] (got {})",
                        s.max_power
                    ));
                }
                range("kinetic mean_active", s.mean_active, 0.5, 1e6)?;
                range("kinetic mean_rest", s.mean_rest, 0.5, 1e6)?;
                range("kinetic tau", s.tau, MIN_DWELL, 1e6)?;
                range("kinetic rel_sigma", s.rel_sigma, 0.0, 3.0)?;
                range("kinetic env_dt", s.env_dt, MIN_DWELL, 1e6)?;
            }
        }
        Ok(())
    }

    /// Generate one `duration`-second pattern from this source's own
    /// seeded stream. Callers pass a stream forked per source index by
    /// [`super::SynthSpec::build`].
    pub fn generate(&self, duration: f64, rng: &mut Rng) -> Piecewise {
        match self {
            SourceSpec::Solar(s) => generate_solar(s, duration, rng),
            SourceSpec::Rf(s) => generate_rf(s, duration, rng),
            SourceSpec::Thermal(s) => generate_thermal(s, duration, rng),
            SourceSpec::Kinetic(s) => generate_kinetic(s, duration, rng),
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            SourceSpec::Solar(s) => Value::obj(vec![
                ("kind", "solar".into()),
                ("peak", s.peak.into()),
                ("day_fraction", s.day_fraction.into()),
                ("period", s.period.into()),
                ("env_dt", s.env_dt.into()),
                ("cloud_attenuation", s.cloud_attenuation.into()),
                ("mean_clear", s.mean_clear.into()),
                ("mean_cloud", s.mean_cloud.into()),
            ]),
            SourceSpec::Rf(s) => Value::obj(vec![
                ("kind", "rf".into()),
                ("burst_power", s.burst_power.into()),
                ("mean_on", s.mean_on.into()),
                ("mean_off", s.mean_off.into()),
                ("jitter", s.jitter.into()),
            ]),
            SourceSpec::Thermal(s) => Value::obj(vec![
                ("kind", "thermal".into()),
                ("base", s.base.into()),
                ("amplitude", s.amplitude.into()),
                ("period", s.period.into()),
                ("env_dt", s.env_dt.into()),
                ("noise", s.noise.into()),
            ]),
            SourceSpec::Kinetic(s) => Value::obj(vec![
                ("kind", "kinetic".into()),
                ("mean_power", s.mean_power.into()),
                ("max_power", s.max_power.into()),
                ("mean_active", s.mean_active.into()),
                ("mean_rest", s.mean_rest.into()),
                ("tau", s.tau.into()),
                ("rel_sigma", s.rel_sigma.into()),
                ("env_dt", s.env_dt.into()),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<SourceSpec, String> {
        let obj = v.as_obj().ok_or("source must be a JSON object")?;
        let kind = v.get("kind").as_str().ok_or("source needs a string 'kind'")?;
        let keys: &[&str] = match kind {
            "solar" => &[
                "kind", "peak", "day_fraction", "period", "env_dt", "cloud_attenuation",
                "mean_clear", "mean_cloud",
            ],
            "rf" => &["kind", "burst_power", "mean_on", "mean_off", "jitter"],
            "thermal" => &["kind", "base", "amplitude", "period", "env_dt", "noise"],
            "kinetic" => &[
                "kind", "mean_power", "max_power", "mean_active", "mean_rest", "tau",
                "rel_sigma", "env_dt",
            ],
            _ => {
                return Err(format!(
                    "unknown source kind '{kind}' (expected solar|rf|thermal|kinetic)"
                ))
            }
        };
        for key in obj.keys() {
            if !keys.contains(&key.as_str()) {
                return Err(format!("unknown {kind} source key '{key}'"));
            }
        }
        // Every numeric field is required: a synth source is a physical
        // model, and silent defaults would make two specs that look
        // different generate identical environments.
        let req = |key: &str| -> Result<f64, String> {
            opt_f64(v, key)?.ok_or_else(|| format!("{kind} source needs a number '{key}'"))
        };
        let spec = match kind {
            "solar" => SourceSpec::Solar(SolarSpec {
                peak: req("peak")?,
                day_fraction: req("day_fraction")?,
                period: req("period")?,
                env_dt: req("env_dt")?,
                cloud_attenuation: req("cloud_attenuation")?,
                mean_clear: req("mean_clear")?,
                mean_cloud: req("mean_cloud")?,
            }),
            "rf" => SourceSpec::Rf(RfBurstSpec {
                burst_power: req("burst_power")?,
                mean_on: req("mean_on")?,
                mean_off: req("mean_off")?,
                jitter: req("jitter")?,
            }),
            "thermal" => SourceSpec::Thermal(ThermalSpec {
                base: req("base")?,
                amplitude: req("amplitude")?,
                period: req("period")?,
                env_dt: req("env_dt")?,
                noise: req("noise")?,
            }),
            _ => SourceSpec::Kinetic(KineticSurrogateSpec {
                mean_power: req("mean_power")?,
                max_power: req("max_power")?,
                mean_active: req("mean_active")?,
                mean_rest: req("mean_rest")?,
                tau: req("tau")?,
                rel_sigma: req("rel_sigma")?,
                env_dt: req("env_dt")?,
            }),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Round-trip helper for diagnostics.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }
}

/// Diurnal clear-sky envelope at phase `t ∈ [0, period)`.
fn solar_envelope(s: &SolarSpec, phase: f64) -> f64 {
    let day_len = s.day_fraction * s.period;
    if phase < day_len {
        let x = phase / day_len;
        s.peak * (PI * x).sin().powi(2)
    } else {
        0.0
    }
}

fn generate_solar(s: &SolarSpec, duration: f64, rng: &mut Rng) -> Piecewise {
    let day_len = s.day_fraction * s.period;
    // Start in the stationary state mix so short patterns are unbiased.
    let p_cloud = s.mean_cloud / (s.mean_clear + s.mean_cloud);
    let mut clear = !rng.chance(p_cloud);
    let dwell = |rng: &mut Rng, clear: bool| -> f64 {
        let mean = if clear { s.mean_clear } else { s.mean_cloud };
        rng.exponential(1.0 / mean).max(MIN_DWELL)
    };
    let mut flip_at = dwell(rng, clear);
    let mut buf = SegBuf::new();
    let mut t = 0.0;
    while t < duration {
        let phase = t - (t / s.period).floor() * s.period;
        // Next model event: envelope tick, cloud flip, or day/night edge.
        let day_edge = if phase < day_len {
            t + (day_len - phase)
        } else {
            t + (s.period - phase)
        };
        let mut end = (t + s.env_dt).min(flip_at).min(day_edge).min(duration);
        if end <= t {
            end = (t + MIN_DWELL).min(duration);
        }
        let mid = 0.5 * (t + end);
        let pm = mid - (mid / s.period).floor() * s.period;
        let gain = if clear { 1.0 } else { s.cloud_attenuation };
        buf.push(end - t, (solar_envelope(s, pm) * gain).max(0.0));
        t = end;
        if t >= flip_at {
            clear = !clear;
            flip_at = t + dwell(rng, clear);
        }
    }
    buf.finish(duration)
}

fn generate_rf(s: &RfBurstSpec, duration: f64, rng: &mut Rng) -> Piecewise {
    let mut buf = SegBuf::new();
    let mut t = 0.0;
    let mut on = false; // gaps lead, matching the committed RF trace
    while t < duration {
        let remaining = duration - t;
        let mean = if on { s.mean_on } else { s.mean_off };
        let drawn = rng.exponential(1.0 / mean).max(MIN_DWELL);
        // The ≥ MIN_DWELL floor guarantees strict progress; a draw that
        // reaches the end closes the pattern exactly at `duration`.
        let (len, next_t) =
            if drawn >= remaining { (remaining, duration) } else { (drawn, t + drawn) };
        let power = if on {
            (s.burst_power * (1.0 + s.jitter * rng.gaussian())).max(0.0)
        } else {
            0.0
        };
        buf.push(len, power);
        t = next_t;
        on = !on;
    }
    buf.finish(duration)
}

fn generate_thermal(s: &ThermalSpec, duration: f64, rng: &mut Rng) -> Piecewise {
    let mut buf = SegBuf::new();
    let mut t = 0.0;
    while t < duration {
        let end = (t + s.env_dt).min(duration);
        let mid = 0.5 * (t + end);
        let pm = mid - (mid / s.period).floor() * s.period;
        let curve = s.base + 0.5 * s.amplitude * (1.0 - (2.0 * PI * pm / s.period).cos());
        let noisy = if s.noise > 0.0 { curve * (1.0 + s.noise * rng.gaussian()) } else { curve };
        buf.push(end - t, noisy.max(0.0));
        t = end;
    }
    buf.finish(duration)
}

fn generate_kinetic(s: &KineticSurrogateSpec, duration: f64, rng: &mut Rng) -> Piecewise {
    let duty = s.mean_active / (s.mean_active + s.mean_rest);
    let mut active = rng.chance(duty);
    let bout = |rng: &mut Rng, active: bool| -> f64 {
        let mean = if active { s.mean_active } else { s.mean_rest };
        rng.exponential(1.0 / mean).max(MIN_DWELL)
    };
    let mut bout_end = bout(rng, active);
    let sigma = s.rel_sigma * s.mean_power;
    let mut level = s.mean_power;
    let mut buf = SegBuf::new();
    let mut t = 0.0;
    while t < duration {
        if active {
            let end = (t + s.env_dt).min(bout_end).min(duration);
            let dt = end - t;
            // OU step toward the bout mean (same discretisation as the
            // committed solar traces). The *state* is clamped, not just
            // the emitted power: with env_dt > 2·tau the explicit Euler
            // step is amplifying (|1 − dt/τ| > 1) and an unclamped level
            // would diverge to ±inf — physically the transducer
            // saturates, so the state pins to the rails instead.
            level += (s.mean_power - level) * dt / s.tau
                + sigma * (2.0 * dt / s.tau).sqrt() * rng.gaussian();
            level = level.clamp(0.0, s.max_power);
            buf.push(dt, level);
            t = end;
        } else {
            let end = bout_end.min(duration);
            buf.push(end - t, 0.0);
            t = end;
        }
        if t >= bout_end && t < duration {
            active = !active;
            bout_end = t + bout(rng, active);
            level = s.mean_power; // each bout re-centres the intensity
        }
    }
    buf.finish(duration)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segbuf_coalesces_and_pins_the_end() {
        let mut b = SegBuf::new();
        b.push(1.0, 0.0);
        b.push(2.0, 0.0); // coalesces
        b.push(1.5, 2e-3);
        b.push(0.0, 9.0); // zero-length: dropped
        b.push(0.5, 2e-3); // coalesces
        let pw = b.finish(5.0);
        assert_eq!(pw.ends, vec![3.0, 5.0]);
        assert_eq!(pw.powers, vec![0.0, 2e-3]);
        assert_eq!(pw.period, 5.0);
    }

    #[test]
    fn empty_buf_finishes_as_zero_pattern() {
        let pw = SegBuf::new().finish(7.0);
        assert_eq!(pw.ends, vec![7.0]);
        assert_eq!(pw.powers, vec![0.0]);
    }

    #[test]
    fn solar_night_is_dark_and_day_peaks_at_noon() {
        let s = SolarSpec {
            peak: 3e-3,
            day_fraction: 0.5,
            period: 600.0,
            env_dt: 5.0,
            cloud_attenuation: 1.0, // clouds change nothing: pure envelope
            mean_clear: 100.0,
            mean_cloud: 100.0,
        };
        let pw = generate_solar(&s, 600.0, &mut Rng::new(1));
        // Night half of the cycle is exactly zero.
        assert_eq!(pw.power_at(450.0), 0.0);
        assert_eq!(pw.power_at(599.0), 0.0);
        // Noon (t=150) beats morning (t=30) and is near the peak.
        assert!(pw.power_at(150.0) > 0.9 * s.peak);
        assert!(pw.power_at(150.0) > pw.power_at(30.0));
        assert!(pw.power_at(150.0) <= s.peak + 1e-15);
    }

    #[test]
    fn solar_clouds_attenuate() {
        let clear = SolarSpec {
            peak: 3e-3,
            day_fraction: 1.0,
            period: 600.0,
            env_dt: 5.0,
            cloud_attenuation: 1.0,
            mean_clear: 1e6,
            mean_cloud: 0.5,
            // mean_clear ≫: effectively always clear
        };
        let cloudy = SolarSpec {
            cloud_attenuation: 0.2,
            mean_clear: 10.0,
            mean_cloud: 30.0,
            ..clear.clone()
        };
        let a = generate_solar(&clear, 1800.0, &mut Rng::new(3)).mean_power();
        let b = generate_solar(&cloudy, 1800.0, &mut Rng::new(3)).mean_power();
        assert!(b < 0.8 * a, "clouds must bite: clear={a} cloudy={b}");
    }

    #[test]
    fn rf_bursts_are_sparse_segments() {
        let s = RfBurstSpec { burst_power: 1.6e-3, mean_on: 0.5, mean_off: 4.5, jitter: 0.35 };
        let pw = generate_rf(&s, 1800.0, &mut Rng::new(5));
        // ~2 segments per on/off pair: far fewer than a 10 ms sample grid.
        assert!(pw.len() < 3000, "{} segments", pw.len());
        assert!(pw.powers.iter().all(|&p| p >= 0.0));
        // Mean lands near the duty-cycled expectation.
        let expect = SourceSpec::Rf(s).expected_mean_power();
        let got = pw.mean_power();
        assert!((0.5 * expect..2.0 * expect).contains(&got), "mean {got} vs {expect}");
        // Off time dominates: the zero segments cover most of the pattern.
        let zero_time: f64 = (0..pw.len())
            .filter(|&i| pw.powers[i] == 0.0)
            .map(|i| pw.ends[i] - pw.start(i))
            .sum();
        assert!(zero_time > 0.7 * 1800.0, "zero time {zero_time}");
    }

    #[test]
    fn thermal_ramp_cycles_between_base_and_peak() {
        let s = ThermalSpec {
            base: 1e-4,
            amplitude: 4e-4,
            period: 600.0,
            env_dt: 10.0,
            noise: 0.0,
        };
        let pw = generate_thermal(&s, 600.0, &mut Rng::new(7));
        assert_eq!(pw.len(), 60);
        // Trough near the base, crest near base+amplitude.
        assert!(pw.power_at(5.0) < s.base + 0.1 * s.amplitude);
        assert!(pw.power_at(300.0) > s.base + 0.9 * s.amplitude);
    }

    #[test]
    fn kinetic_rests_are_zero_and_bouts_saturate() {
        let s = KineticSurrogateSpec {
            mean_power: 1.2e-3,
            max_power: 2e-3,
            mean_active: 60.0,
            mean_rest: 60.0,
            tau: 10.0,
            rel_sigma: 1.0, // violent: exercises both clamps
            env_dt: 2.0,
        };
        let pw = generate_kinetic(&s, 3600.0, &mut Rng::new(9));
        assert!(pw.powers.iter().all(|&p| (0.0..=s.max_power).contains(&p)));
        assert!(pw.powers.iter().any(|&p| p == 0.0), "no rest bout in an hour");
        assert!(pw.powers.iter().any(|&p| p > 0.5e-3), "no active bout in an hour");
    }

    #[test]
    fn kinetic_stays_finite_when_the_euler_step_is_amplifying() {
        // env_dt ≫ tau makes the explicit OU step amplifying
        // (|1 − dt/τ| ≫ 1); the clamped state must pin to the rails
        // instead of diverging to ±inf/NaN.
        let s = KineticSurrogateSpec {
            mean_power: 1e-3,
            max_power: 8e-3,
            mean_active: 1000.0,
            mean_rest: 0.5,
            tau: 0.05,
            rel_sigma: 0.5,
            env_dt: 10.0,
        };
        let pw = generate_kinetic(&s, 1800.0, &mut Rng::new(13));
        assert!(
            pw.powers.iter().all(|&p| p.is_finite() && (0.0..=s.max_power).contains(&p)),
            "amplifying OU step escaped the rails"
        );
    }

    #[test]
    fn source_json_round_trips() {
        let sources = [
            SourceSpec::Solar(SolarSpec {
                peak: 3e-3,
                day_fraction: 0.5,
                period: 900.0,
                env_dt: 5.0,
                cloud_attenuation: 0.25,
                mean_clear: 90.0,
                mean_cloud: 30.0,
            }),
            SourceSpec::Rf(RfBurstSpec {
                burst_power: 1.6e-3,
                mean_on: 0.5,
                mean_off: 4.5,
                jitter: 0.35,
            }),
            SourceSpec::Thermal(ThermalSpec {
                base: 1e-4,
                amplitude: 3e-4,
                period: 450.0,
                env_dt: 10.0,
                noise: 0.1,
            }),
            SourceSpec::Kinetic(KineticSurrogateSpec {
                mean_power: 1.2e-3,
                max_power: 8e-3,
                mean_active: 120.0,
                mean_rest: 90.0,
                tau: 10.0,
                rel_sigma: 0.5,
                env_dt: 2.0,
            }),
        ];
        for src in sources {
            let v = src.to_json();
            let back = SourceSpec::from_json(&v).expect("round trip");
            assert_eq!(back, src);
        }
    }

    #[test]
    fn source_json_rejects_bad_input() {
        let bad = [
            r#"{"kind": "plasma"}"#,
            r#"{"kind": "rf", "burst_power": 0.001, "mean_on": 0.5, "mean_off": 4.5}"#,
            r#"{"kind": "rf", "burst_power": 0.001, "mean_on": 0.5, "mean_off": 4.5, "jitter": 0.1, "extra": 1}"#,
            r#"{"kind": "rf", "burst_power": -1, "mean_on": 0.5, "mean_off": 4.5, "jitter": 0}"#,
            r#"{"kind": "rf", "burst_power": "x", "mean_on": 0.5, "mean_off": 4.5, "jitter": 0}"#,
            r#"{"kind": "thermal", "base": 0.0001, "amplitude": 0.0003, "period": 1, "env_dt": 10, "noise": 0}"#,
            r#"{"kind": "solar", "peak": 0.003, "day_fraction": 0, "period": 600, "env_dt": 5, "cloud_attenuation": 0.3, "mean_clear": 60, "mean_cloud": 20}"#,
            r#"{"kind": "kinetic", "mean_power": 0.001, "max_power": 0, "mean_active": 60, "mean_rest": 60, "tau": 10, "rel_sigma": 0.5, "env_dt": 2}"#,
            r#"[]"#,
        ];
        for text in bad {
            let v = json::parse(text).expect("valid JSON");
            assert!(SourceSpec::from_json(&v).is_err(), "accepted: {text}");
        }
    }
}
