//! Seeded stochastic energy-environment generator.
//!
//! The paper demonstrates its claims on five recorded ambient traces and
//! one kinetic model; the scenario grid can only be as diverse as the
//! supplies it can name. This module removes that bottleneck: a
//! [`SynthSpec`] is a small, JSON-round-trippable description of a
//! *family* of harvesting environments — parametric sources
//! ([`sources`]) combined multi-source style ([`compose`]) — and
//! `build(seed)` deterministically realises one member of the family as
//! a native run-length [`Piecewise`] pattern. Sweeps over hundreds of
//! generated environments are therefore declarative: put a synth
//! harvester in a scenario file and list the seeds.
//!
//! # Seeding discipline
//!
//! Determinism is layered so every consumer sees the same environment:
//!
//! * the **spec seed** names the family realisation baseline (committed
//!   scenario files pin it, so a file names one exact environment set);
//! * the **cell seed** (`build`'s argument — a scenario's per-cell seed)
//!   is mixed in by multiplication with the golden-ratio constant, so
//!   seed axes `[1, 2, 3…]` yield decorrelated environments;
//! * each source forks its own independent [`Rng`] substream, so adding
//!   a source to a composite never perturbs the streams of the others.
//!
//! Generation is a pure function of `(spec, seed)` — no globals, no
//! thread state — which is what makes synth sweeps bit-identical for
//! any `AIC_WORKERS` value (gated by `tests/synth_properties.rs`).
//!
//! # Why `Piecewise` natively
//!
//! The PR-2 analytic engine is O(events) because the supply is a short
//! list of constant-power segments. The generators here emit segments
//! only where the model changes (burst edges, Markov flips, coarse
//! envelope ticks), so a synthetic hour is hundreds-to-thousands of
//! segments — never the 360 000 samples a 10 ms grid would force — and
//! the engine keeps its event-driven complexity with **no sampled
//! intermediate** anywhere in the chain.

pub mod compose;
pub mod sources;

pub use compose::{merge, Combine};
pub use sources::{
    KineticSurrogateSpec, RfBurstSpec, SolarSpec, SourceSpec, ThermalSpec, MIN_DWELL,
};

use crate::energy::traces::Piecewise;
use crate::util::json::{self, opt_f64, opt_str, opt_u64, Value};
use crate::util::rng::Rng;

/// Cap on the *expected* total segment count of one generated pattern.
/// Parsed specs beyond it are rejected, so hostile scenario files cannot
/// demand unbounded generation work or memory.
pub const MAX_SEGMENTS: f64 = 2_000_000.0;

/// A seeded stochastic energy environment: one or more parametric
/// sources over a repeating pattern of `duration` seconds, combined per
/// [`Combine`]. See the module docs for the seeding discipline.
#[derive(Clone, Debug, PartialEq)]
pub struct SynthSpec {
    /// Display name (scenario tables, CLI output).
    pub name: String,
    /// Family realisation baseline; mixed with the per-cell seed.
    pub seed: u64,
    /// Pattern length, seconds; the environment repeats after it exactly
    /// like a replayed trace.
    pub duration: f64,
    /// Multi-source combination operator.
    pub combine: Combine,
    /// Switch-matrix conversion efficiency, (0, 1]; only
    /// [`Combine::Switchover`] uses it.
    pub switch_efficiency: f64,
    pub sources: Vec<SourceSpec>,
}

impl SynthSpec {
    /// Realise the environment for one device cell. Deterministic in
    /// `(self, cell_seed)`; different cell seeds give statistically
    /// independent members of the same family.
    pub fn build(&self, cell_seed: u64) -> Piecewise {
        debug_assert!(self.validate().is_ok(), "building an unvalidated synth spec");
        let root = self.seed ^ cell_seed.wrapping_mul(0x9E3779B97F4A7C15);
        let mut base = Rng::new(root);
        let parts: Vec<Piecewise> = self
            .sources
            .iter()
            .enumerate()
            .map(|(i, src)| {
                let mut rng = base.fork(i as u64 + 1);
                src.generate(self.duration, &mut rng)
            })
            .collect();
        merge(&parts, self.combine, self.switch_efficiency, self.duration)
    }

    /// Analytic `(lo, hi)` band for the environment's long-horizon mean
    /// power, watts: `Sum` is exactly the sum of source means; for the
    /// power-ORing combinators the pointwise max of non-negative sources
    /// is bounded below by the largest source mean and above by the sum.
    /// The statistical gate (`tests/synth_properties.rs`) asserts
    /// realised means stay within a sampling-tolerance factor of this
    /// band.
    pub fn mean_power_band(&self) -> (f64, f64) {
        let means: Vec<f64> =
            self.sources.iter().map(|s| s.expected_mean_power()).collect();
        let sum: f64 = means.iter().sum();
        let max = means.iter().fold(0.0, |a: f64, &b| a.max(b));
        match self.combine {
            Combine::Sum => (sum, sum),
            Combine::Max => (max, sum),
            Combine::Switchover => {
                (self.switch_efficiency * max, self.switch_efficiency * sum)
            }
        }
    }

    /// Structural + physical validation. Called by the JSON reader, the
    /// scenario validator and (debug) by [`SynthSpec::build`].
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("synth spec needs a non-empty name".to_string());
        }
        // Seeds round-trip through JSON numbers (f64): above 2^53 the
        // written value would silently change on parse and realise a
        // *different* environment from the same-looking spec.
        if self.seed > (1u64 << 53) {
            return Err(format!(
                "synth seed {} exceeds 2^53 and cannot round-trip through JSON",
                self.seed
            ));
        }
        if !(self.duration > 0.0 && self.duration <= 604800.0) {
            return Err(format!(
                "synth duration must be in (0, 604800] seconds (got {})",
                self.duration
            ));
        }
        if self.sources.is_empty() {
            return Err("synth spec has no sources".to_string());
        }
        if self.sources.len() > 8 {
            return Err(format!("synth spec has {} sources (max 8)", self.sources.len()));
        }
        if !(self.switch_efficiency > 0.0 && self.switch_efficiency <= 1.0) {
            return Err(format!(
                "switch_efficiency must be in (0, 1] (got {})",
                self.switch_efficiency
            ));
        }
        let mut budget = 0.0;
        for (i, src) in self.sources.iter().enumerate() {
            src.validate().map_err(|e| format!("source {i}: {e}"))?;
            budget += src.expected_segments(self.duration);
        }
        if budget > MAX_SEGMENTS {
            return Err(format!(
                "synth spec expects ~{budget:.0} segments (max {MAX_SEGMENTS:.0}); \
                 shorten the duration or coarsen env_dt"
            ));
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // JSON (the `aic simulate --supply synth:<spec.json>` and scenario
    // harvester-object format).
    // -----------------------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("seed", Value::Num(self.seed as f64)),
            ("duration", self.duration.into()),
            ("combine", self.combine.name().into()),
            ("switch_efficiency", self.switch_efficiency.into()),
            (
                "sources",
                Value::Arr(self.sources.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }

    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    pub fn from_json(v: &Value) -> Result<SynthSpec, String> {
        const KEYS: [&str; 6] =
            ["name", "seed", "duration", "combine", "switch_efficiency", "sources"];
        let obj = v.as_obj().ok_or("synth spec must be a JSON object")?;
        for key in obj.keys() {
            if !KEYS.contains(&key.as_str()) {
                return Err(format!("unknown synth key '{key}'"));
            }
        }
        let name = v.get("name").as_str().ok_or("synth spec needs a string 'name'")?;
        let combine_name =
            opt_str(v, "combine")?.ok_or("synth spec needs a 'combine' of sum|max|switchover")?;
        let combine = Combine::from_name(combine_name).ok_or_else(|| {
            format!("unknown combine '{combine_name}' (expected sum|max|switchover)")
        })?;
        let sources = v
            .get("sources")
            .as_arr()
            .ok_or("synth spec needs a 'sources' array")?
            .iter()
            .map(SourceSpec::from_json)
            .collect::<Result<Vec<SourceSpec>, String>>()?;
        let spec = SynthSpec {
            name: name.to_string(),
            seed: opt_u64(v, "seed")?.ok_or("synth spec needs an unsigned integer 'seed'")?,
            duration: opt_f64(v, "duration")?.ok_or("synth spec needs a number 'duration'")?,
            combine,
            switch_efficiency: opt_f64(v, "switch_efficiency")?.unwrap_or(1.0),
            sources,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a standalone synth spec document.
    pub fn parse(text: &str) -> Result<SynthSpec, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        SynthSpec::from_json(&v)
    }

    // -----------------------------------------------------------------
    // The builtin environment families (scenario registry, benches,
    // committed example scenarios — one definition for all three).
    // -----------------------------------------------------------------

    /// Compressed-day diurnal solar with cloud occlusion (`synth_solar`).
    pub fn builtin_solar() -> SynthSpec {
        SynthSpec {
            name: "synth-solar".to_string(),
            seed: 11,
            duration: 1800.0,
            combine: Combine::Sum,
            switch_efficiency: 1.0,
            sources: vec![SourceSpec::Solar(SolarSpec {
                peak: 0.003,
                day_fraction: 0.5,
                period: 900.0,
                env_dt: 5.0,
                cloud_attenuation: 0.25,
                mean_clear: 90.0,
                mean_cloud: 30.0,
            })],
        }
    }

    /// Duty-cycled RF bursts in the committed RF trace's regime
    /// (`synth_rf`).
    pub fn builtin_rf() -> SynthSpec {
        SynthSpec {
            name: "synth-rf".to_string(),
            seed: 23,
            duration: 1800.0,
            combine: Combine::Sum,
            switch_efficiency: 1.0,
            sources: vec![SourceSpec::Rf(RfBurstSpec {
                burst_power: 0.0016,
                mean_on: 0.5,
                mean_off: 4.5,
                jitter: 0.35,
            })],
        }
    }

    /// Four-source amalgamated device (`synth_multi`): compressed-day
    /// solar, RF bursts, a kinetic surrogate and a thermal floor behind
    /// a 90 %-efficient switchover matrix.
    pub fn builtin_multi() -> SynthSpec {
        SynthSpec {
            name: "synth-multi".to_string(),
            seed: 37,
            duration: 1800.0,
            combine: Combine::Switchover,
            switch_efficiency: 0.9,
            sources: vec![
                SourceSpec::Solar(SolarSpec {
                    peak: 0.002,
                    day_fraction: 0.5,
                    period: 600.0,
                    env_dt: 5.0,
                    cloud_attenuation: 0.3,
                    mean_clear: 60.0,
                    mean_cloud: 20.0,
                }),
                SourceSpec::Rf(RfBurstSpec {
                    burst_power: 0.0016,
                    mean_on: 0.5,
                    mean_off: 4.5,
                    jitter: 0.35,
                }),
                SourceSpec::Kinetic(KineticSurrogateSpec {
                    mean_power: 0.0012,
                    max_power: 0.008,
                    mean_active: 120.0,
                    mean_rest: 90.0,
                    tau: 10.0,
                    rel_sigma: 0.5,
                    env_dt: 2.0,
                }),
                SourceSpec::Thermal(ThermalSpec {
                    base: 0.0001,
                    amplitude: 0.0003,
                    period: 450.0,
                    env_dt: 10.0,
                    noise: 0.1,
                }),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_validate_and_build() {
        for spec in [
            SynthSpec::builtin_solar(),
            SynthSpec::builtin_rf(),
            SynthSpec::builtin_multi(),
        ] {
            spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            let pw = spec.build(1);
            assert_eq!(*pw.ends.last().unwrap(), spec.duration, "{}", spec.name);
            assert_eq!(pw.period, spec.duration, "{}", spec.name);
            assert!(pw.powers.iter().all(|&p| p.is_finite() && p >= 0.0), "{}", spec.name);
        }
    }

    #[test]
    fn build_is_deterministic_and_seed_sensitive() {
        let spec = SynthSpec::builtin_multi();
        let a = spec.build(3);
        let b = spec.build(3);
        assert_eq!(a.ends, b.ends);
        assert_eq!(a.powers, b.powers);
        let c = spec.build(4);
        assert_ne!(a.powers, c.powers, "cell seeds must vary the environment");
        let mut other = spec.clone();
        other.seed ^= 1;
        let d = other.build(3);
        assert_ne!(a.powers, d.powers, "the spec seed must vary the environment");
    }

    #[test]
    fn adding_a_source_does_not_perturb_the_others() {
        // Forked substreams: source 0 of a 1-source spec and source 0 of
        // a 2-source spec see the same rng stream.
        let solo = SynthSpec::builtin_rf();
        let mut duo = solo.clone();
        duo.sources.push(SourceSpec::Thermal(ThermalSpec {
            base: 0.0,
            amplitude: 0.0,
            period: 450.0,
            env_dt: 450.0,
            noise: 0.0,
        }));
        // A zero-power second source under Sum leaves the composite
        // equal to the solo build (modulo the extra merge boundaries,
        // which coalesce away because the powers match).
        let a = solo.build(5);
        let b = duo.build(5);
        assert_eq!(a.ends, b.ends);
        assert_eq!(a.powers, b.powers);
    }

    #[test]
    fn json_round_trips_losslessly() {
        for spec in [
            SynthSpec::builtin_solar(),
            SynthSpec::builtin_rf(),
            SynthSpec::builtin_multi(),
        ] {
            let back = SynthSpec::parse(&spec.to_json_string()).expect("round trip");
            assert_eq!(back, spec);
            // Same spec bytes ⇒ same environment, bit for bit.
            let (x, y) = (spec.build(9), back.build(9));
            assert_eq!(x.ends, y.ends);
            assert_eq!(x.powers, y.powers);
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        let base = SynthSpec::builtin_rf();
        let mut no_sources = base.clone();
        no_sources.sources.clear();
        assert!(no_sources.validate().is_err());
        let mut bad_duration = base.clone();
        bad_duration.duration = 0.0;
        assert!(bad_duration.validate().is_err());
        let mut too_long = base.clone();
        too_long.duration = 1e9;
        assert!(too_long.validate().is_err());
        let mut bad_eff = base.clone();
        bad_eff.switch_efficiency = 0.0;
        assert!(bad_eff.validate().is_err());
        let mut big_seed = base.clone();
        big_seed.seed = (1u64 << 53) + 1;
        assert!(big_seed.validate().is_err(), "seeds beyond 2^53 cannot round-trip");
        let mut hostile = base.clone();
        hostile.duration = 604800.0;
        if let SourceSpec::Rf(rf) = &mut hostile.sources[0] {
            rf.mean_on = MIN_DWELL;
            rf.mean_off = MIN_DWELL;
        }
        assert!(hostile.validate().is_err(), "segment budget must cap hostile specs");
        assert!(SynthSpec::parse("{").is_err());
        assert!(SynthSpec::parse(r#"{"name":"x"}"#).is_err());
        assert!(SynthSpec::parse(
            r#"{"name":"x","seed":1.5,"duration":60,"combine":"sum","sources":[]}"#
        )
        .is_err());
    }

    #[test]
    fn mean_power_band_orders_combinators() {
        let mut spec = SynthSpec::builtin_multi();
        let (lo_sw, hi_sw) = spec.mean_power_band();
        assert!(lo_sw > 0.0 && lo_sw <= hi_sw);
        spec.combine = Combine::Sum;
        let (lo_sum, hi_sum) = spec.mean_power_band();
        assert_eq!(lo_sum, hi_sum);
        // Switchover at 90 % efficiency can never beat the sum.
        assert!(hi_sw <= hi_sum);
    }
}
