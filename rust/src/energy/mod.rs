//! Energy substrate: everything between the ambient environment and the
//! MCU's energy ledger.
//!
//! The paper's testbed is a kinetic/solar/RF harvester feeding a BQ25505
//! booster that charges a 1470 µF capacitor powering an MSP430-FR5659.
//! This module models that chain:
//!
//! * [`harvester`] — ambient power sources (trace replay, kinetic
//!   transducer, constant, generated synthetic environments), fed by
//!   [`traces`] (synthetic RF / solar profiles matching the paper's five
//!   traces) and [`synth`] (the seeded stochastic environment generator:
//!   parametric solar/RF/thermal/kinetic families and multi-source
//!   composites, emitted as native run-length segments).
//! * [`booster`] — BQ25505-like boost charger efficiency model.
//! * [`capacitor`] — the energy buffer: ½CV², turn-on / brown-out
//!   thresholds, usable-energy queries (the "ADC read" the SMART policy
//!   performs).
//! * [`mcu`] — MSP430-class cost model: CPU cycles, FRAM reads/writes with
//!   wait-state penalties, ADC, BLE, sensors. Single source of truth for
//!   every nanojoule charged anywhere in the simulator.
//! * [`estimator`] — the offline energy-estimation tool (the paper uses
//!   EPIC): profiles a step program against the MCU model and builds the
//!   lookup tables the SMART policy consults at run time.
//! * [`predictor`] — the online counterpart: a tiny EWMA estimator of
//!   per-cycle harvest and inter-burst gaps that the adaptive policy
//!   updates once per power cycle from the engine's realised budget.

pub mod booster;
pub mod capacitor;
pub mod estimator;
pub mod harvester;
pub mod mcu;
pub mod predictor;
pub mod synth;
pub mod traces;
