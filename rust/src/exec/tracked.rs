//! Access-tracking correctness harness for the intermittent runtimes.
//!
//! *Towards a Formal Foundation of Intermittent Computing* (PAPERS.md)
//! shows that the bugs that silently corrupt intermittent systems are
//! WAR hazards and non-idempotent re-execution — exactly the properties
//! this repo's results rest on. This module checks them mechanically:
//!
//! * [`Probe`] — a shared trace buffer the engine and the program
//!   wrapper both write into: every operation attempt (with its ledger,
//!   cost shape, outcome and fault-injection flag), every boot, every
//!   brown-out, and every program-level event (load / plan / step /
//!   reset) in one totally ordered log.
//! * [`TrackedProgram`] — wraps any [`StepProgram`], shadowing each call
//!   with always-on contract checks (step order, plan bounds, mid-round
//!   plan shrink). Violating calls are recorded and **not forwarded**,
//!   so the inner program — and its `debug_assert!`s — stay protected
//!   while the harness observes the broken runtime misbehaving.
//! * [`check_trace`] — the invariant checker: WAR-hazard freedom (every
//!   billed non-idempotent step is preceded by a versioning write of at
//!   least `war_words`), replay idempotence (replayed prefixes are
//!   contiguous, never exceed billed progress, rebuild bitwise-identical
//!   shadow state, and results are never double-emitted), monotone
//!   commit (the inferred committed prefix never regresses across
//!   reboots), and volatility discipline (single-cycle runtimes touch no
//!   persistent state and never stretch a round across power cycles).
//! * [`run_checked`] — one-call harness: arm a
//!   [`FaultPlan`](crate::exec::faultplan::FaultPlan), run a campaign
//!   under any [`Runtime`], return the campaign plus the checked trace.
//!
//! How the checker classifies steps: a `Step` event is *billed* when the
//! most recent engine operation was a successful App-ledger CPU burst
//! (its "fuel"); brown-outs, reboots and `reset_round` clear fuel, so
//! the free replay loops inside `ChinchillaRuntime::restore` /
//! `AlpacaRuntime::reenter` — which issue no per-step ops by design —
//! are recognised as *replay* and checked against the replay invariants
//! instead of the billing ones.

use crate::energy::mcu::OpCost;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::faultplan::FaultPlan;
use crate::exec::program::StepProgram;
use crate::exec::runtime::Runtime;
use crate::exec::Campaign;
use std::sync::{Arc, Mutex};

/// One entry of the totally ordered execution trace.
#[derive(Clone, Debug)]
pub enum Event {
    /// `load_next` succeeded: sample `sample` is live.
    Load { sample: u64, cycle: u64, now: f64, num_steps: usize },
    /// `plan(k)` accepted and forwarded.
    Plan { sample: u64, k: usize },
    /// `execute_step(j)` forwarded. `war` is the step's declared WAR
    /// word count; `state` the shadow-state signature after the step
    /// (`state_words(j + 1)`), used to verify replay idempotence.
    Step { sample: u64, j: usize, war: u64, state: u64, cycle: u64 },
    /// `reset_round`: all volatile round state dropped.
    Reset { sample: u64, cycle: u64 },
    /// One engine operation attempt (the fault-point ordinal space).
    Op {
        ordinal: u64,
        ledger: Ledger,
        cycles: u64,
        fram_reads: u64,
        fram_writes: u64,
        ble_bytes: u64,
        adc_reads: u64,
        sensor: bool,
        outcome: OpOutcome,
        /// True when the brown-out was forced by the armed fault plan.
        injected: bool,
        cycle: u64,
    },
    /// Successful boot: power cycle `cycle` begins.
    Boot { cycle: u64, now: f64 },
    /// Brown-out number `failures` (injected or physical).
    Fail { failures: u64, now: f64 },
}

/// An invariant violation — found online by [`TrackedProgram`] or
/// offline by [`check_trace`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// `execute_step(j)` with `j` ≠ the next expected step.
    OutOfOrderStep { sample: u64, expected: usize, got: usize },
    /// `execute_step(j)` beyond the accepted plan.
    StepBeyondPlan { sample: u64, j: usize, planned: usize },
    /// `plan(k)` with `k > num_steps()`.
    OversizedPlan { sample: u64, k: usize, total: usize },
    /// `plan(k)` shrank the plan after execution began.
    ShrunkPlanMidRound { sample: u64, from: usize, to: usize, executed: usize },
    /// A billed non-idempotent step ran without a versioning write
    /// covering its `war_words` (WAR hazard: a reboot replays the step
    /// against already-overwritten state).
    UnversionedWarWrite { sample: u64, j: usize, war: u64, covered: u64 },
    /// A replayed prefix was longer than any prefix ever billed — the
    /// runtime "restored" work it never did.
    ReplayBeyondCommit { sample: u64, replayed: usize, executed: usize },
    /// The inferred committed prefix shrank across reboots.
    CommitRegression { sample: u64, from: usize, to: usize },
    /// Re-execution rebuilt different shadow state than first execution.
    ShadowDivergence { sample: u64, j: usize, first: u64, replayed: u64 },
    /// More than one successful emission for one sample.
    DoubleEmit { sample: u64, emits: u64 },
    /// A single-cycle runtime issued a persistent-state (State-ledger)
    /// operation.
    StatefulVolatileRuntime { sample: u64, ordinal: u64 },
    /// A single-cycle runtime stretched a round across power cycles.
    CrossCycleRound { sample: u64, j: usize, started: u64, continued: u64 },
    /// A single-cycle runtime re-executed steps after a reset.
    ReplayInVolatileRuntime { sample: u64, replayed: usize },
    /// A step ran with no preceding billed CPU burst and no open replay.
    UnbilledStep { sample: u64, j: usize },
    /// A replaying runtime emitted before rebuilding the full result.
    IncompleteEmit { sample: u64, at: usize, total: usize },
}

impl Violation {
    /// Stable short label (mutation-gate assertions key on this).
    pub fn kind(&self) -> &'static str {
        match self {
            Violation::OutOfOrderStep { .. } => "out-of-order-step",
            Violation::StepBeyondPlan { .. } => "step-beyond-plan",
            Violation::OversizedPlan { .. } => "oversized-plan",
            Violation::ShrunkPlanMidRound { .. } => "shrunk-plan-mid-round",
            Violation::UnversionedWarWrite { .. } => "unversioned-war-write",
            Violation::ReplayBeyondCommit { .. } => "replay-beyond-commit",
            Violation::CommitRegression { .. } => "commit-regression",
            Violation::ShadowDivergence { .. } => "shadow-divergence",
            Violation::DoubleEmit { .. } => "double-emit",
            Violation::StatefulVolatileRuntime { .. } => "stateful-volatile-runtime",
            Violation::CrossCycleRound { .. } => "cross-cycle-round",
            Violation::ReplayInVolatileRuntime { .. } => "replay-in-volatile-runtime",
            Violation::UnbilledStep { .. } => "unbilled-step",
            Violation::IncompleteEmit { .. } => "incomplete-emit",
        }
    }
}

/// The collected execution trace of one campaign.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Contract violations [`TrackedProgram`] caught online (always-on,
    /// release builds included).
    pub online: Vec<Violation>,
}

impl Trace {
    /// Every replay run in the trace: maximal sequences of `Step` events
    /// following a `Reset` with no engine operation in between (the free
    /// state-rebuild loops of restore/reenter), as `(sample, length)`.
    /// Zero-length runs (a reset not followed by replay) are included.
    pub fn replay_runs(&self) -> Vec<(u64, usize)> {
        let mut runs = Vec::new();
        let mut open: Option<(u64, usize)> = None;
        for ev in &self.events {
            match ev {
                Event::Reset { sample, .. } => {
                    if let Some(run) = open.take() {
                        runs.push(run);
                    }
                    open = Some((*sample, 0));
                }
                Event::Step { .. } => {
                    if let Some((_, len)) = open.as_mut() {
                        *len += 1;
                    }
                }
                Event::Op { .. } | Event::Load { .. } => {
                    if let Some(run) = open.take() {
                        runs.push(run);
                    }
                }
                _ => {}
            }
        }
        if let Some(run) = open {
            runs.push(run);
        }
        runs
    }

    /// Successful emissions (Done App ops with BLE payload).
    pub fn emits(&self) -> usize {
        self.events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    Event::Op { ledger: Ledger::App, ble_bytes, outcome: OpOutcome::Done, .. }
                        if *ble_bytes > 0
                )
            })
            .count()
    }
}

#[derive(Debug, Default)]
struct ProbeState {
    trace: Trace,
    cycle: u64,
}

/// Shared handle to the trace buffer: cloned into the engine (op/boot/
/// fail events) and the [`TrackedProgram`] (program events). `Arc` +
/// `Mutex` so engines stay `Send` for the fleet threads; the lock is
/// uncontended (one engine, one program, one thread per campaign).
#[derive(Clone, Debug, Default)]
pub struct Probe {
    state: Arc<Mutex<ProbeState>>,
}

impl Probe {
    pub fn new() -> Probe {
        Probe::default()
    }

    pub fn record(&self, ev: Event) {
        self.state.lock().unwrap().trace.events.push(ev);
    }

    pub fn online_violation(&self, v: Violation) {
        self.state.lock().unwrap().trace.online.push(v);
    }

    /// The engine publishes its power-cycle counter here so program
    /// events can be stamped with the cycle they ran in.
    pub fn set_cycle(&self, cycle: u64) {
        self.state.lock().unwrap().cycle = cycle;
    }

    pub fn cycle(&self) -> u64 {
        self.state.lock().unwrap().cycle
    }

    /// Take the trace out (leaves an empty one behind).
    pub fn take(&self) -> Trace {
        std::mem::take(&mut self.state.lock().unwrap().trace)
    }
}

/// Wraps a [`StepProgram`] with shadow access tracking and always-on
/// contract enforcement. The inner program only ever sees calls that
/// respect the `StepProgram` contract: out-of-order steps, oversized
/// plans and mid-round plan shrinks are recorded as [`Violation`]s and
/// dropped instead of forwarded (promoting `SyntheticProgram`'s
/// `debug_assert!`s to release-mode checks, without UB-by-convention).
pub struct TrackedProgram<P: StepProgram> {
    inner: P,
    probe: Probe,
    sample: u64,
    any_loaded: bool,
    executed: usize,
    planned: usize,
}

impl<P: StepProgram> TrackedProgram<P> {
    pub fn new(inner: P, probe: Probe) -> TrackedProgram<P> {
        TrackedProgram { inner, probe, sample: 0, any_loaded: false, executed: 0, planned: 0 }
    }

    pub fn inner(&self) -> &P {
        &self.inner
    }

    pub fn into_inner(self) -> P {
        self.inner
    }
}

impl<P: StepProgram> StepProgram for TrackedProgram<P> {
    type Output = P::Output;

    fn load_next(&mut self, now: f64) -> bool {
        if !self.inner.load_next(now) {
            return false;
        }
        if self.any_loaded {
            self.sample += 1;
        } else {
            self.any_loaded = true;
        }
        self.executed = 0;
        self.planned = self.inner.planned_steps();
        self.probe.record(Event::Load {
            sample: self.sample,
            cycle: self.probe.cycle(),
            now,
            num_steps: self.inner.num_steps(),
        });
        true
    }

    fn acquire_cost(&self) -> OpCost {
        self.inner.acquire_cost()
    }

    fn num_steps(&self) -> usize {
        self.inner.num_steps()
    }

    fn plan(&mut self, k: usize) {
        let total = self.inner.num_steps();
        if k > total {
            self.probe.online_violation(Violation::OversizedPlan {
                sample: self.sample,
                k,
                total,
            });
            return;
        }
        if k < self.executed || (self.executed > 0 && k < self.planned) {
            self.probe.online_violation(Violation::ShrunkPlanMidRound {
                sample: self.sample,
                from: self.planned,
                to: k,
                executed: self.executed,
            });
            return;
        }
        self.inner.plan(k);
        self.planned = k;
        self.probe.record(Event::Plan { sample: self.sample, k });
    }

    fn planned_steps(&self) -> usize {
        self.inner.planned_steps()
    }

    fn step_cost(&self, j: usize) -> OpCost {
        self.inner.step_cost(j)
    }

    fn execute_step(&mut self, j: usize) {
        if j != self.executed {
            self.probe.online_violation(Violation::OutOfOrderStep {
                sample: self.sample,
                expected: self.executed,
                got: j,
            });
            return;
        }
        if j >= self.planned {
            self.probe.online_violation(Violation::StepBeyondPlan {
                sample: self.sample,
                j,
                planned: self.planned,
            });
            return;
        }
        let war = self.inner.war_words(j);
        self.inner.execute_step(j);
        self.executed = j + 1;
        self.probe.record(Event::Step {
            sample: self.sample,
            j,
            war,
            state: self.inner.state_words(j + 1),
            cycle: self.probe.cycle(),
        });
    }

    fn state_words(&self, j: usize) -> u64 {
        self.inner.state_words(j)
    }

    fn war_words(&self, j: usize) -> u64 {
        self.inner.war_words(j)
    }

    fn emit_cost(&self) -> OpCost {
        self.inner.emit_cost()
    }

    fn output(&self) -> P::Output {
        self.inner.output()
    }

    fn reset_round(&mut self) {
        self.inner.reset_round();
        self.executed = 0;
        self.probe.record(Event::Reset { sample: self.sample, cycle: self.probe.cycle() });
    }
}

/// What the checker may assume about a runtime — each shipping runtime
/// publishes its profile (`approx::profile()`, `chinchilla::profile()`,
/// …; [`Policy::profile`](crate::exec::Policy::profile) dispatches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RuntimeProfile {
    pub name: &'static str,
    /// May rebuild state by re-executing a committed prefix after a
    /// reboot and may stretch one sample across power cycles
    /// (Chinchilla / Alpaca). When false, every round must complete
    /// within a single power cycle and never replay.
    pub replays: bool,
    /// Manages persistent state: State-ledger operations are expected.
    /// When false, any State-ledger op is a volatility violation (the
    /// approximate runtimes' "no persistent state at all" guarantee).
    pub persists: bool,
}

/// Offline invariant checker: walks the trace and returns every
/// violation (online contract breaches included).
pub fn check_trace(trace: &Trace, profile: &RuntimeProfile) -> Vec<Violation> {
    let mut chk = Checker::new(*profile, trace.online.clone());
    for ev in &trace.events {
        match *ev {
            Event::Load { sample, num_steps, .. } => chk.load(sample, num_steps),
            Event::Plan { .. } => {}
            Event::Reset { .. } => chk.reset(),
            Event::Step { j, war, state, cycle, .. } => chk.step(j, war, state, cycle),
            Event::Op {
                ordinal,
                ledger,
                cycles,
                fram_writes,
                ble_bytes,
                adc_reads,
                sensor,
                outcome,
                cycle,
                ..
            } => chk.op(ordinal, ledger, cycles, fram_writes, ble_bytes, adc_reads, sensor,
                outcome, cycle),
            Event::Boot { .. } | Event::Fail { .. } => chk.power_event(),
        }
    }
    chk.finish()
}

struct Checker {
    profile: RuntimeProfile,
    out: Vec<Violation>,
    sample: u64,
    num_steps: usize,
    /// Billed high-water progress: the longest prefix ever executed on
    /// billed fuel (the energy-accounted ground truth of "work done").
    progress: usize,
    /// Largest replay base seen — the inferred committed prefix.
    commit_floor: usize,
    emits: u64,
    /// Current rebuilt position within the round.
    cur_pos: usize,
    first_step_cycle: Option<u64>,
    /// `Some(war_cover)` while an unconsumed App CPU burst is pending.
    fuel: Option<u64>,
    /// `Some(len)` while a replay run (post-reset, op-free) is open.
    replay: Option<usize>,
    /// Shadow-state signature of each step's first execution.
    sigs: Vec<(u64, u64)>,
}

impl Checker {
    fn new(profile: RuntimeProfile, online: Vec<Violation>) -> Checker {
        Checker {
            profile,
            out: online,
            sample: 0,
            num_steps: 0,
            progress: 0,
            commit_floor: 0,
            emits: 0,
            cur_pos: 0,
            first_step_cycle: None,
            fuel: None,
            replay: None,
            sigs: Vec::new(),
        }
    }

    fn close_replay(&mut self) {
        if let Some(len) = self.replay.take() {
            if len > self.progress {
                self.out.push(Violation::ReplayBeyondCommit {
                    sample: self.sample,
                    replayed: len,
                    executed: self.progress,
                });
            }
            if len > 0 && !self.profile.replays {
                self.out.push(Violation::ReplayInVolatileRuntime {
                    sample: self.sample,
                    replayed: len,
                });
            }
            if len < self.commit_floor {
                self.out.push(Violation::CommitRegression {
                    sample: self.sample,
                    from: self.commit_floor,
                    to: len,
                });
            }
            self.commit_floor = self.commit_floor.max(len);
        }
    }

    fn load(&mut self, sample: u64, num_steps: usize) {
        self.close_replay();
        self.sample = sample;
        self.num_steps = num_steps;
        self.progress = 0;
        self.commit_floor = 0;
        self.emits = 0;
        self.cur_pos = 0;
        self.first_step_cycle = None;
        self.fuel = None;
        self.sigs.clear();
    }

    fn reset(&mut self) {
        self.close_replay();
        self.cur_pos = 0;
        self.fuel = None;
        self.replay = Some(0);
    }

    fn power_event(&mut self) {
        self.fuel = None;
    }

    fn step(&mut self, j: usize, war: u64, state: u64, cycle: u64) {
        // Shadow idempotence: any re-execution of step j must rebuild
        // the signature its first execution produced.
        if j < self.sigs.len() {
            let (first_state, first_war) = self.sigs[j];
            if first_state != state || first_war != war {
                self.out.push(Violation::ShadowDivergence {
                    sample: self.sample,
                    j,
                    first: first_state,
                    replayed: state,
                });
            }
        } else if j == self.sigs.len() {
            self.sigs.push((state, war));
        }
        if let Some(covered) = self.fuel.take() {
            // Billed step.
            self.close_replay();
            if self.profile.replays && war > 0 && covered < war {
                self.out.push(Violation::UnversionedWarWrite {
                    sample: self.sample,
                    j,
                    war,
                    covered,
                });
            }
            if !self.profile.replays {
                match self.first_step_cycle {
                    None => self.first_step_cycle = Some(cycle),
                    Some(c0) if c0 != cycle => self.out.push(Violation::CrossCycleRound {
                        sample: self.sample,
                        j,
                        started: c0,
                        continued: cycle,
                    }),
                    _ => {}
                }
            }
            self.cur_pos = j + 1;
            self.progress = self.progress.max(j + 1);
        } else if let Some(len) = self.replay.as_mut() {
            // Replay step (free rebuild after restore/reenter).
            *len += 1;
            self.cur_pos = j + 1;
        } else {
            self.out.push(Violation::UnbilledStep { sample: self.sample, j });
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn op(
        &mut self,
        ordinal: u64,
        ledger: Ledger,
        cycles: u64,
        fram_writes: u64,
        ble_bytes: u64,
        adc_reads: u64,
        sensor: bool,
        outcome: OpOutcome,
        cycle: u64,
    ) {
        if ledger == Ledger::State && !self.profile.persists {
            self.out.push(Violation::StatefulVolatileRuntime {
                sample: self.sample,
                ordinal,
            });
        }
        self.close_replay();
        if outcome == OpOutcome::BrownOut {
            self.fuel = None;
            return;
        }
        match ledger {
            Ledger::App => {
                if ble_bytes > 0 {
                    // Successful emission.
                    self.fuel = None;
                    self.emits += 1;
                    if self.emits > 1 {
                        self.out.push(Violation::DoubleEmit {
                            sample: self.sample,
                            emits: self.emits,
                        });
                    }
                    if self.profile.replays && self.cur_pos != self.num_steps {
                        self.out.push(Violation::IncompleteEmit {
                            sample: self.sample,
                            at: self.cur_pos,
                            total: self.num_steps,
                        });
                    }
                    if !self.profile.replays {
                        if let Some(c0) = self.first_step_cycle {
                            if c0 != cycle {
                                self.out.push(Violation::CrossCycleRound {
                                    sample: self.sample,
                                    j: self.cur_pos,
                                    started: c0,
                                    continued: cycle,
                                });
                            }
                        }
                    }
                } else if !sensor && adc_reads == 0 && cycles > 0 {
                    // An App CPU burst: fuel for exactly one billed step.
                    self.fuel = Some(0);
                }
            }
            Ledger::State => {
                // A versioning/privatization write between a step's CPU
                // burst and its execution covers the step's WAR words.
                if let Some(cover) = self.fuel.as_mut() {
                    *cover = (*cover).max(fram_writes);
                }
            }
        }
    }

    fn finish(mut self) -> Vec<Violation> {
        self.close_replay();
        self.out
    }
}

/// Outcome of one tracked, fault-injected campaign.
pub struct CheckedRun<O> {
    pub campaign: Campaign<O>,
    pub trace: Trace,
    /// Online + offline violations, in trace order.
    pub violations: Vec<Violation>,
    /// Failures the armed plan actually injected.
    pub injected: u64,
    /// Total operations attempted (the fault-point space for
    /// exhaustive enumeration).
    pub ops: u64,
}

/// Run `runtime` over `program` on `engine` with `plan` armed, tracking
/// every access, and check the trace against `profile`.
pub fn run_checked<P: StepProgram>(
    program: P,
    mut engine: Engine,
    runtime: &dyn Runtime<TrackedProgram<P>>,
    plan: FaultPlan,
    profile: &RuntimeProfile,
) -> CheckedRun<P::Output> {
    let probe = Probe::new();
    engine.attach_probe(probe.clone());
    engine.arm_faults(plan);
    let mut tracked = TrackedProgram::new(program, probe.clone());
    let campaign = runtime.run(&mut tracked, &mut engine);
    let trace = probe.take();
    let violations = check_trace(&trace, profile);
    CheckedRun {
        campaign,
        trace,
        violations,
        injected: engine.injected_faults(),
        ops: engine.ops_attempted(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::program::SyntheticProgram;

    fn tracked() -> (TrackedProgram<SyntheticProgram>, Probe) {
        let probe = Probe::new();
        let p = TrackedProgram::new(SyntheticProgram::new(3, 10, 1_000), probe.clone());
        (p, probe)
    }

    #[test]
    fn contract_violations_are_recorded_not_forwarded() {
        let (mut p, probe) = tracked();
        assert!(p.load_next(0.0));
        // Oversized plan: rejected, inner plan unchanged.
        p.plan(11);
        assert_eq!(p.planned_steps(), 10);
        // In-order execution is forwarded.
        p.plan(4);
        p.execute_step(0);
        // Out-of-order step: rejected, inner state protected (the inner
        // debug_assert would have panicked had it been forwarded).
        p.execute_step(2);
        assert_eq!(p.output(), 1);
        // Mid-round shrink: rejected.
        p.plan(2);
        assert_eq!(p.planned_steps(), 4);
        // Beyond-plan step: rejected.
        p.execute_step(1);
        p.execute_step(2);
        p.execute_step(3);
        p.execute_step(4);
        assert_eq!(p.output(), 4);
        let trace = probe.take();
        let kinds: Vec<&str> = trace.online.iter().map(|v| v.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                "oversized-plan",
                "out-of-order-step",
                "shrunk-plan-mid-round",
                "step-beyond-plan"
            ]
        );
    }

    #[test]
    fn growing_replan_is_allowed_and_round_start_shrink_too() {
        let (mut p, probe) = tracked();
        assert!(p.load_next(0.0));
        p.plan(1); // round-start narrowing (GREEDY) is fine
        p.execute_step(0);
        p.plan(2); // mid-round growth (GREEDY refinement) is fine
        p.execute_step(1);
        assert!(probe.take().online.is_empty());
    }

    fn approx_profile() -> RuntimeProfile {
        RuntimeProfile { name: "approx", replays: false, persists: false }
    }

    fn persistent_profile() -> RuntimeProfile {
        RuntimeProfile { name: "persistent", replays: true, persists: true }
    }

    fn cpu_op(ordinal: u64, cycle: u64) -> Event {
        Event::Op {
            ordinal,
            ledger: Ledger::App,
            cycles: 1_000,
            fram_reads: 0,
            fram_writes: 0,
            ble_bytes: 0,
            adc_reads: 0,
            sensor: false,
            outcome: OpOutcome::Done,
            injected: false,
            cycle,
        }
    }

    fn state_op(ordinal: u64, fram_writes: u64, cycle: u64) -> Event {
        Event::Op {
            ordinal,
            ledger: Ledger::State,
            cycles: 100,
            fram_reads: 0,
            fram_writes,
            ble_bytes: 0,
            adc_reads: 0,
            sensor: false,
            outcome: OpOutcome::Done,
            injected: false,
            cycle,
        }
    }

    fn step(sample: u64, j: usize, war: u64, cycle: u64) -> Event {
        Event::Step { sample, j, war, state: 100 + j as u64, cycle }
    }

    #[test]
    fn checker_flags_unversioned_war_rewrite() {
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 2 },
                cpu_op(0, 1),
                state_op(1, 4, 1), // covers war=4
                step(0, 0, 4, 1),
                cpu_op(2, 1),
                step(0, 1, 4, 1), // war=4 with no versioning write
            ],
            online: vec![],
        };
        let vs = check_trace(&trace, &persistent_profile());
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].kind(), "unversioned-war-write");
    }

    #[test]
    fn checker_flags_replay_beyond_billed_progress() {
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 3 },
                cpu_op(0, 1),
                step(0, 0, 0, 1),
                Event::Fail { failures: 1, now: 1.0 },
                Event::Boot { cycle: 2, now: 2.0 },
                state_op(1, 0, 2), // restore
                Event::Reset { sample: 0, cycle: 2 },
                step(0, 0, 0, 2),
                step(0, 1, 0, 2), // replayed 2 > billed 1
                cpu_op(2, 2),
            ],
            online: vec![],
        };
        let vs = check_trace(&trace, &persistent_profile());
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].kind(), "replay-beyond-commit");
    }

    #[test]
    fn checker_flags_commit_regression_and_double_emit() {
        let emit = |ordinal, cycle| Event::Op {
            ordinal,
            ledger: Ledger::App,
            cycles: 500,
            fram_reads: 0,
            fram_writes: 0,
            ble_bytes: 1,
            adc_reads: 0,
            sensor: false,
            outcome: OpOutcome::Done,
            injected: false,
            cycle,
        };
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 2 },
                cpu_op(0, 1),
                step(0, 0, 0, 1),
                cpu_op(1, 1),
                step(0, 1, 0, 1),
                // Replay of the full prefix, then a shorter one: the
                // committed prefix regressed.
                Event::Reset { sample: 0, cycle: 2 },
                step(0, 0, 0, 2),
                step(0, 1, 0, 2),
                state_op(2, 0, 2),
                Event::Reset { sample: 0, cycle: 3 },
                step(0, 0, 0, 3),
                state_op(3, 0, 3),
                // Rebuild and emit twice.
                Event::Reset { sample: 0, cycle: 3 },
                step(0, 0, 0, 3),
                step(0, 1, 0, 3),
                emit(4, 3),
                emit(5, 3),
            ],
            online: vec![],
        };
        let kinds: Vec<&str> =
            check_trace(&trace, &persistent_profile()).iter().map(|v| v.kind()).collect();
        assert!(kinds.contains(&"commit-regression"), "{kinds:?}");
        assert!(kinds.contains(&"double-emit"), "{kinds:?}");
    }

    #[test]
    fn checker_flags_persistence_and_cross_cycle_in_volatile_profile() {
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 2 },
                cpu_op(0, 1),
                step(0, 0, 2, 1),
                state_op(1, 8, 1), // State op under a volatile profile
                Event::Fail { failures: 1, now: 1.0 },
                Event::Boot { cycle: 2, now: 2.0 },
                cpu_op(2, 2),
                step(0, 1, 2, 2), // continued in a later power cycle
            ],
            online: vec![],
        };
        let kinds: Vec<&str> =
            check_trace(&trace, &approx_profile()).iter().map(|v| v.kind()).collect();
        assert!(kinds.contains(&"stateful-volatile-runtime"), "{kinds:?}");
        assert!(kinds.contains(&"cross-cycle-round"), "{kinds:?}");
    }

    #[test]
    fn checker_flags_shadow_divergence_on_replay() {
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 2 },
                cpu_op(0, 1),
                Event::Step { sample: 0, j: 0, war: 0, state: 100, cycle: 1 },
                Event::Reset { sample: 0, cycle: 2 },
                // Replay rebuilds a different signature.
                Event::Step { sample: 0, j: 0, war: 0, state: 101, cycle: 2 },
                cpu_op(1, 2),
            ],
            online: vec![],
        };
        let vs = check_trace(&trace, &persistent_profile());
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].kind(), "shadow-divergence");
    }

    #[test]
    fn clean_single_cycle_trace_passes_both_profiles_appropriately() {
        let emit = Event::Op {
            ordinal: 2,
            ledger: Ledger::App,
            cycles: 500,
            fram_reads: 0,
            fram_writes: 0,
            ble_bytes: 1,
            adc_reads: 0,
            sensor: false,
            outcome: OpOutcome::Done,
            injected: false,
            cycle: 1,
        };
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 2 },
                cpu_op(0, 1),
                step(0, 0, 0, 1),
                cpu_op(1, 1),
                step(0, 1, 0, 1),
                emit,
            ],
            online: vec![],
        };
        assert!(check_trace(&trace, &approx_profile()).is_empty());
        assert!(check_trace(&trace, &persistent_profile()).is_empty());
    }

    #[test]
    fn replay_runs_helper_extracts_post_reset_runs() {
        let trace = Trace {
            events: vec![
                Event::Load { sample: 0, cycle: 1, now: 0.0, num_steps: 3 },
                cpu_op(0, 1),
                step(0, 0, 0, 1),
                Event::Reset { sample: 0, cycle: 2 },
                step(0, 0, 0, 2),
                cpu_op(1, 2),
                step(0, 1, 0, 2),
                Event::Reset { sample: 0, cycle: 3 },
            ],
            online: vec![],
        };
        assert_eq!(trace.replay_runs(), vec![(0, 1), (0, 0)]);
    }
}
