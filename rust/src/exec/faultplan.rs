//! Deterministic power-failure injection.
//!
//! A [`FaultPlan`] describes *where* the correctness harness forces the
//! device to brown out, in the coordinate system the engine already has:
//! the ordinal of each [`Engine::run_op`](crate::exec::engine::Engine::run_op)
//! call. Every operation the runtime issues — acquisition, a step's CPU
//! burst, a WAR versioning write, a checkpoint, a commit, the BLE
//! emission, a restore — is one fault point, so enumerating ordinals
//! `0..ops_attempted()` systematically covers every cycle boundary a
//! short campaign can reach (mid-step, between execute and commit,
//! during emit, during restore). Randomised schedules are seeded
//! Bernoulli processes over the same ordinals and are bitwise
//! reproducible: the same plan on the same campaign yields the same
//! trace.
//!
//! An injected failure behaves exactly like a physical brown-out: time
//! still advances over the doomed operation's window (harvesting
//! included), nothing is billed, the buffer is left just under the
//! brown-out threshold, and the runtime must recharge to boot.

use crate::util::rng::Rng;

/// Where to force power failures, in `run_op` ordinals.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum FaultPlan {
    /// Physics only — no injected failures.
    #[default]
    None,
    /// Brown out exactly at these op ordinals (0-based, sorted
    /// ascending; ordinals already passed when armed are ignored).
    AtOps(Vec<u64>),
    /// Seeded Bernoulli schedule: each op browns out with probability
    /// `rate`, up to `max_faults` injections.
    Random { seed: u64, rate: f64, max_faults: u64 },
    /// Every `period`-th op starting at `offset` (a metronome of
    /// adversity for soak runs).
    EveryN { period: u64, offset: u64 },
}

impl FaultPlan {
    /// A single forced failure at op `ordinal`.
    pub fn single(ordinal: u64) -> FaultPlan {
        FaultPlan::AtOps(vec![ordinal])
    }

    /// An unbounded seeded Bernoulli schedule.
    pub fn random(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan::Random { seed, rate, max_faults: u64::MAX }
    }
}

/// The stateful, engine-side form of a [`FaultPlan`]: consulted once per
/// operation, in order.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Rng,
    cursor: usize,
    injected: u64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let rng = match &plan {
            FaultPlan::Random { seed, .. } => Rng::new(seed ^ 0xFA17_0B57_AC1E_5EED),
            _ => Rng::new(0),
        };
        FaultInjector { plan, rng, cursor: 0, injected: 0 }
    }

    /// Decide whether operation `ordinal` browns out. Must be called
    /// exactly once per operation with strictly increasing ordinals —
    /// the engine is the only intended caller.
    pub fn strike(&mut self, ordinal: u64) -> bool {
        let hit = match &self.plan {
            FaultPlan::None => false,
            FaultPlan::AtOps(ops) => {
                let mut c = self.cursor;
                while c < ops.len() && ops[c] < ordinal {
                    c += 1;
                }
                let hit = c < ops.len() && ops[c] == ordinal;
                self.cursor = if hit { c + 1 } else { c };
                hit
            }
            FaultPlan::Random { rate, max_faults, .. } => {
                // Draw unconditionally so the schedule depends only on
                // the ordinal sequence, not on how many faults fired.
                let draw = self.rng.chance(*rate);
                draw && self.injected < *max_faults
            }
            FaultPlan::EveryN { period, offset } => {
                *period > 0 && ordinal >= *offset && (ordinal - offset) % period == 0
            }
        };
        if hit {
            self.injected += 1;
        }
        hit
    }

    /// Failures injected so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_ops_fires_exactly_once_per_listed_ordinal() {
        let mut inj = FaultInjector::new(FaultPlan::AtOps(vec![2, 5, 5, 9]));
        let fired: Vec<u64> = (0..12).filter(|&i| inj.strike(i)).collect();
        // Duplicate entries cannot double-fire a single ordinal pass.
        assert_eq!(fired, vec![2, 5, 9]);
        assert_eq!(inj.injected(), 3);
    }

    #[test]
    fn random_is_reproducible_and_capped() {
        let plan = FaultPlan::Random { seed: 7, rate: 0.3, max_faults: 4 };
        let mut a = FaultInjector::new(plan.clone());
        let mut b = FaultInjector::new(plan);
        let fa: Vec<bool> = (0..200).map(|i| a.strike(i)).collect();
        let fb: Vec<bool> = (0..200).map(|i| b.strike(i)).collect();
        assert_eq!(fa, fb, "same seed, same schedule");
        assert_eq!(a.injected(), 4, "max_faults caps the schedule");
    }

    #[test]
    fn every_n_is_a_metronome() {
        let mut inj = FaultInjector::new(FaultPlan::EveryN { period: 4, offset: 3 });
        let fired: Vec<u64> = (0..14).filter(|&i| inj.strike(i)).collect();
        assert_eq!(fired, vec![3, 7, 11]);
    }

    #[test]
    fn none_never_fires() {
        let mut inj = FaultInjector::new(FaultPlan::None);
        assert!((0..100).all(|i| !inj.strike(i)));
        assert_eq!(inj.injected(), 0);
    }
}
