//! Battery-powered continuous baseline.
//!
//! The ceiling every paper figure normalises against: processes every
//! sampling slot with *all* steps (maximum accuracy), never browns out.
//! Time still flows through the MCU model so throughput is measured in the
//! same units as the intermittent runtimes.

use crate::energy::mcu::McuModel;
use crate::exec::{Campaign, RoundResult, StepProgram};

/// Run the continuous baseline: one full-precision round every
/// `sample_period` seconds until `max_time` or the input stream ends.
pub fn run<P: StepProgram>(
    program: &mut P,
    mcu: &McuModel,
    sample_period: f64,
    max_time: f64,
) -> Campaign<P::Output> {
    let mut rounds = Vec::new();
    let mut now = 0.0;
    let mut sample_id = 0u64;
    let mut app_energy = 0.0;
    while now < max_time && program.load_next(now) {
        let acquired_at = now;
        // Acquire.
        let ac = program.acquire_cost();
        now += mcu.duration(&ac);
        app_energy += mcu.energy(&ac);
        // All steps.
        program.plan(program.num_steps());
        for j in 0..program.planned_steps() {
            let cost = program.step_cost(j);
            now += mcu.duration(&cost);
            app_energy += mcu.energy(&cost);
            program.execute_step(j);
        }
        // Emit.
        let ec = program.emit_cost();
        now += mcu.duration(&ec);
        app_energy += mcu.energy(&ec);
        rounds.push(RoundResult {
            sample_id,
            acquired_at,
            emitted_at: Some(now),
            latency_cycles: 0,
            steps_executed: program.planned_steps(),
            output: Some(program.output()),
        });
        sample_id += 1;
        // Sleep to the next sampling slot.
        let next = ((now / sample_period).floor() + 1.0) * sample_period;
        now = next;
    }
    Campaign {
        rounds,
        duration: now.min(max_time),
        power_failures: 0,
        power_cycles: 0,
        app_energy,
        state_energy: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::program::SyntheticProgram;

    #[test]
    fn processes_every_slot_fully() {
        let mut p = SyntheticProgram::new(1000, 10, 10_000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 600.0);
        // 600 s / 60 s slots → 10 rounds (first at t=0).
        assert_eq!(c.rounds.len(), 10);
        assert!(c.rounds.iter().all(|r| r.steps_executed == 10));
        assert!(c.rounds.iter().all(|r| r.output == Some(10)));
        assert!(c.rounds.iter().all(|r| r.latency_cycles == 0));
        assert_eq!(c.power_failures, 0);
    }

    #[test]
    fn stops_when_inputs_exhausted() {
        let mut p = SyntheticProgram::new(3, 5, 1000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 1e6);
        assert_eq!(c.rounds.len(), 3);
    }

    #[test]
    fn energy_is_all_app() {
        let mut p = SyntheticProgram::new(5, 5, 1000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 1e6);
        assert!(c.app_energy > 0.0);
        assert_eq!(c.state_energy, 0.0);
    }
}
