//! Battery-powered continuous baseline.
//!
//! The ceiling every paper figure normalises against: processes every
//! sampling slot with *all* steps (maximum accuracy), never browns out.
//! Time still flows through the MCU model so throughput is measured in the
//! same units as the intermittent runtimes. Through [`Engine::powered`]
//! the baseline shares the [`RoundDriver`] with every other policy — the
//! only per-round behaviour it contributes is "run everything, emit".

use crate::energy::mcu::McuModel;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::tracked::RuntimeProfile;
use crate::exec::{Campaign, StepProgram};

/// The invariant profile the correctness harness holds the continuous
/// baseline to: a battery-powered run never replays and never manages
/// persistent state — any State-ledger operation or cross-cycle round
/// is a violation.
pub fn profile() -> RuntimeProfile {
    RuntimeProfile { name: "continuous", replays: false, persists: false }
}

/// The continuous (battery-powered) executor in [`Runtime`] form. Pair
/// it with an [`Engine::powered`] engine; on a harvesting engine it
/// behaves like an unprotected runtime and loses every sample a
/// brown-out touches.
pub struct ContinuousRuntime {
    /// Seconds between sampling slots.
    pub sample_period: f64,
}

impl ContinuousRuntime {
    pub fn new(sample_period: f64) -> ContinuousRuntime {
        ContinuousRuntime { sample_period }
    }
}

impl<P: StepProgram> RoundStrategy<P> for ContinuousRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::BrownOut {
            return RoundOutcome::Dropped { steps: 0, sleep: false };
        }
        // All steps, maximum accuracy.
        program.plan(program.num_steps());
        for j in 0..program.planned_steps() {
            let cost = program.step_cost(j);
            if engine.run_op(&cost, Ledger::App) == OpOutcome::BrownOut {
                return RoundOutcome::Dropped { steps: j, sleep: false };
            }
            program.execute_step(j);
        }
        match engine.run_op(&program.emit_cost(), Ledger::App) {
            OpOutcome::Done => RoundOutcome::Emitted {
                emitted_at: engine.now,
                steps: program.planned_steps(),
                output: program.output(),
            },
            OpOutcome::BrownOut => {
                RoundOutcome::Dropped { steps: program.planned_steps(), sleep: true }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for ContinuousRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.sample_period).drive(program, engine, self)
    }
}

/// Run the continuous baseline: one full-precision round every
/// `sample_period` seconds until `max_time` or the input stream ends.
/// Thin wrapper over [`ContinuousRuntime`] on a powered engine.
pub fn run<P: StepProgram>(
    program: &mut P,
    mcu: &McuModel,
    sample_period: f64,
    max_time: f64,
) -> Campaign<P::Output> {
    let mut engine = Engine::powered(mcu.clone(), max_time);
    ContinuousRuntime::new(sample_period).run(program, &mut engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::program::SyntheticProgram;

    #[test]
    fn processes_every_slot_fully() {
        let mut p = SyntheticProgram::new(1000, 10, 10_000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 600.0);
        // 600 s / 60 s slots → 10 rounds (first at t=0).
        assert_eq!(c.rounds.len(), 10);
        assert!(c.rounds.iter().all(|r| r.steps_executed == 10));
        assert!(c.rounds.iter().all(|r| r.output == Some(10)));
        assert!(c.rounds.iter().all(|r| r.latency_cycles == 0));
        assert_eq!(c.power_failures, 0);
    }

    #[test]
    fn stops_when_inputs_exhausted() {
        let mut p = SyntheticProgram::new(3, 5, 1000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 1e6);
        assert_eq!(c.rounds.len(), 3);
    }

    #[test]
    fn energy_is_all_app() {
        let mut p = SyntheticProgram::new(5, 5, 1000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 1e6);
        assert!(c.app_energy > 0.0);
        assert_eq!(c.state_energy, 0.0);
    }

    #[test]
    fn powered_campaign_counts_no_power_cycles() {
        let mut p = SyntheticProgram::new(4, 5, 1000);
        let mcu = McuModel::paper_default();
        let c = run(&mut p, &mcu, 60.0, 1e6);
        assert_eq!(c.power_cycles, 0);
        assert!(c.rounds.iter().all(|r| r.emitted_at.is_some()));
    }
}
