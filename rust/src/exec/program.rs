//! The step-program model.
//!
//! All three applications — HAR classification, Harris corner detection
//! and acoustic event detection — are expressed as a sequence of atomic
//! *steps* with per-step cost vectors. The approximation knob of the
//! paper (Fig. 10) maps onto the model uniformly:
//!
//! | | Anytime SVM | Loop perforation | Spectral refinement |
//! |---|---|---|---|
//! | knob | number of features | loop iterations | spectral probes |
//! | energy estimation | single feature | single loop iteration | single Goertzel pass |
//! | output | activity class | number/position of corners | event class |
//!
//! [`StepProgram::plan`] selects how many steps the current round will run
//! (a feature prefix, a spread subset of loop rows, or a probe prefix of
//! the coarse-to-fine refinement schedule); the runtimes then execute
//! planned steps one at a time, each atomically charged to the capacitor
//! by the engine.

use crate::energy::mcu::OpCost;

/// A stateful computation over a stream of inputs, broken into atomic,
/// energy-accounted steps with an approximation plan.
pub trait StepProgram {
    /// Application output (activity class, corner list, ...).
    type Output: Clone;

    /// Acquire the next input sample at absolute time `now` (inputs may
    /// be time-dependent, e.g. a volunteer's activity script). Returns
    /// `false` when the input stream is exhausted (campaign over).
    fn load_next(&mut self, now: f64) -> bool;

    /// Sensor/acquisition cost for one input.
    fn acquire_cost(&self) -> OpCost;

    /// Total number of steps a *precise* execution runs for this input.
    fn num_steps(&self) -> usize;

    /// Restrict this round to `k <= num_steps()` steps. For HAR this is
    /// the anytime feature prefix; for imaging a uniformly-spread subset
    /// of loop iterations. May be called again mid-round with a larger
    /// `k` (GREEDY refining as energy arrives).
    ///
    /// Contract: once execution has begun (`execute_step` ran and no
    /// `load_next`/`reset_round` since), `k` must not shrink below the
    /// accepted plan — already-executed steps cannot be unplanned.
    /// Programs enforce this with `debug_assert!`;
    /// [`TrackedProgram`](crate::exec::tracked::TrackedProgram) makes
    /// both bounds always-on in release builds too, rejecting the call
    /// and recording a
    /// [`Violation`](crate::exec::tracked::Violation) instead of
    /// forwarding it.
    fn plan(&mut self, k: usize);

    /// Steps currently planned.
    fn planned_steps(&self) -> usize;

    /// Cost vector of planned step `j` (`j < planned_steps()`).
    fn step_cost(&self, j: usize) -> OpCost;

    /// Execute planned step `j`, mutating the round state.
    fn execute_step(&mut self, j: usize);

    /// Live state after `j` planned steps, in 16-bit words — what a
    /// checkpointing runtime must persist (input + partial results).
    fn state_words(&self, j: usize) -> u64;

    /// Words written by step `j` that need WAR (write-after-read)
    /// versioning under a mixed-volatility runtime; the intermittence-
    /// anomaly protection cost charged by Chinchilla per executed step.
    fn war_words(&self, j: usize) -> u64 {
        let _ = j;
        0
    }

    /// Cost of emitting the result (BLE packet).
    fn emit_cost(&self) -> OpCost;

    /// Current output given the steps executed so far.
    fn output(&self) -> Self::Output;

    /// Drop all volatile round state (reboot without a checkpoint, or
    /// starting over on the same input).
    fn reset_round(&mut self);
}

/// A synthetic program for engine/runtime tests: `n` equal-cost steps;
/// the output is the number of steps executed (so tests can assert
/// exactly how much work survived).
#[derive(Clone, Debug)]
pub struct SyntheticProgram {
    pub total_inputs: u64,
    pub steps: usize,
    pub cycles_per_step: u64,
    pub state_words_per_step: u64,
    loaded: u64,
    planned: usize,
    executed: usize,
}

impl SyntheticProgram {
    pub fn new(total_inputs: u64, steps: usize, cycles_per_step: u64) -> SyntheticProgram {
        SyntheticProgram {
            total_inputs,
            steps,
            cycles_per_step,
            state_words_per_step: 8,
            loaded: 0,
            planned: 0,
            executed: 0,
        }
    }
}

impl StepProgram for SyntheticProgram {
    type Output = usize;

    fn load_next(&mut self, _now: f64) -> bool {
        if self.loaded >= self.total_inputs {
            return false;
        }
        self.loaded += 1;
        self.executed = 0;
        self.planned = self.steps;
        true
    }

    fn acquire_cost(&self) -> OpCost {
        OpCost { cycles: 2_000, sensor_secs: 0.01, ..Default::default() }
    }

    fn num_steps(&self) -> usize {
        self.steps
    }

    fn plan(&mut self, k: usize) {
        debug_assert!(k <= self.steps, "plan {k} exceeds {} total steps", self.steps);
        debug_assert!(
            self.executed == 0 || k >= self.planned,
            "plan shrank mid-round: {} -> {k} with {} steps executed",
            self.planned,
            self.executed
        );
        self.planned = k;
    }

    fn planned_steps(&self) -> usize {
        self.planned
    }

    fn step_cost(&self, _j: usize) -> OpCost {
        OpCost::cycles(self.cycles_per_step)
    }

    fn execute_step(&mut self, j: usize) {
        debug_assert_eq!(j, self.executed, "steps must run in order");
        self.executed += 1;
    }

    fn state_words(&self, j: usize) -> u64 {
        16 + self.state_words_per_step * j as u64
    }

    fn war_words(&self, _j: usize) -> u64 {
        2
    }

    fn emit_cost(&self) -> OpCost {
        OpCost { cycles: 500, ble_bytes: 1, ..Default::default() }
    }

    fn output(&self) -> usize {
        self.executed
    }

    fn reset_round(&mut self) {
        self.executed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_program_lifecycle() {
        let mut p = SyntheticProgram::new(2, 5, 1000);
        assert!(p.load_next(0.0));
        assert_eq!(p.planned_steps(), 5);
        p.plan(3);
        assert_eq!(p.planned_steps(), 3);
        p.execute_step(0);
        p.execute_step(1);
        assert_eq!(p.output(), 2);
        p.reset_round();
        assert_eq!(p.output(), 0);
        assert!(p.load_next(0.0));
        assert!(!p.load_next(0.0));
    }

    #[test]
    fn state_grows_with_progress() {
        let p = SyntheticProgram::new(1, 10, 100);
        assert!(p.state_words(5) > p.state_words(0));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "plan shrank mid-round")]
    fn mid_round_plan_shrink_is_rejected() {
        let mut p = SyntheticProgram::new(1, 5, 100);
        assert!(p.load_next(0.0));
        p.plan(4);
        p.execute_step(0);
        p.plan(2); // shrinking after execution began: contract breach
    }

    #[test]
    fn round_start_narrowing_is_fine() {
        let mut p = SyntheticProgram::new(2, 5, 100);
        assert!(p.load_next(0.0));
        p.plan(2); // before any execution: allowed (GREEDY round start)
        p.execute_step(0);
        p.plan(4); // growth mid-round: allowed (GREEDY refinement)
        assert!(p.load_next(0.0));
        p.plan(1); // a new input resets the contract
        assert_eq!(p.planned_steps(), 1);
    }
}
