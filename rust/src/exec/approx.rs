//! Approximate intermittent computing — the paper's contribution.
//!
//! Both policies bound every stateful computation to the current power
//! cycle: the approximation knob (feature count / perforated iterations)
//! is chosen so that the result is **emitted before the first power
//! failure**, so no persistent state ever exists and every joule goes to
//! useful processing.
//!
//! * **GREEDY** (§4.3): keeps adding steps while the remaining budget
//!   covers the next step *plus* the final BLE emission, then emits. Any
//!   energy harvested while running is captured automatically because the
//!   budget is re-read from the capacitor before every step.
//! * **SMART** (§4.3): reads the capacitor through the ADC, consults the
//!   offline [`SmartTable`] for the minimum step count `p'` meeting the
//!   user accuracy bound `A`; skips the round if infeasible, otherwise
//!   runs `p'` steps unconditionally and then continues in GREEDY mode.

use crate::energy::estimator::SmartTable;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::tracked::RuntimeProfile;
use crate::exec::{Campaign, StepProgram};

/// The invariant profile the correctness harness holds GREEDY and SMART
/// to: every round completes (and emits) within a single power cycle,
/// no replay ever happens, and **no persistent state exists at all** —
/// any State-ledger operation is a violation. This is the paper's
/// headline guarantee, checked mechanically.
pub fn profile() -> RuntimeProfile {
    RuntimeProfile { name: "approx", replays: false, persists: false }
}

/// Approximate runtime configuration.
#[derive(Clone, Debug)]
pub struct ApproxConfig {
    /// Seconds between sampling slots (the paper's "one minute").
    pub sample_period: f64,
    /// Safety margin multiplier on the look-ahead (step + emit) cost;
    /// models the prototype's conservative tuning so the emission
    /// reliably precedes the power failure.
    pub margin: f64,
    /// SMART's accuracy lower bound; `None` = GREEDY.
    pub smart: Option<SmartPolicy>,
}

/// SMART's offline-provisioned decision inputs.
#[derive(Clone, Debug)]
pub struct SmartPolicy {
    /// User accuracy bound `A`.
    pub bound: f64,
    /// Offline lookup table from the estimator + Eq. 7 analysis.
    pub table: SmartTable,
}

impl ApproxConfig {
    pub fn greedy(sample_period: f64) -> ApproxConfig {
        ApproxConfig { sample_period, margin: 1.05, smart: None }
    }

    pub fn smart(sample_period: f64, bound: f64, table: SmartTable) -> ApproxConfig {
        ApproxConfig {
            sample_period,
            margin: 1.05,
            smart: Some(SmartPolicy { bound, table }),
        }
    }
}

/// The GREEDY/SMART executor in [`Runtime`] form.
pub struct ApproxRuntime {
    pub cfg: ApproxConfig,
}

impl ApproxRuntime {
    pub fn new(cfg: ApproxConfig) -> ApproxRuntime {
        ApproxRuntime { cfg }
    }
}

impl<P: StepProgram> RoundStrategy<P> for ApproxRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        let cfg = &self.cfg;
        // Acquire the sensor window. A brown-out here loses the sample;
        // there is no retry state — we just move on after recharging.
        if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::BrownOut {
            return RoundOutcome::Dropped { steps: 0, sleep: false };
        }

        let emit_energy = engine.mcu.energy(&program.emit_cost());
        let total = program.num_steps();
        let mut k = 0usize; // steps executed so far

        // SMART gate: is the budget enough for the accuracy bound?
        if let Some(smart) = &cfg.smart {
            let budget = match engine.read_budget() {
                Some(b) => b,
                None => return RoundOutcome::Dropped { steps: 0, sleep: false },
            };
            match smart.table.feasible(budget, smart.bound) {
                // Infeasible: skip this round deliberately and wait for
                // the next sampling slot.
                None => return RoundOutcome::Dropped { steps: 0, sleep: true },
                Some(p_required) => {
                    // Run p' steps unconditionally; the table guarantees
                    // they plus the emission fit the budget.
                    program.plan(p_required.min(total));
                    while k < program.planned_steps() {
                        let cost = program.step_cost(k);
                        if engine.run_op(&cost, Ledger::App) == OpOutcome::BrownOut {
                            return RoundOutcome::Dropped { steps: k, sleep: false };
                        }
                        program.execute_step(k);
                        k += 1;
                    }
                }
            }
        }

        // GREEDY refinement: extend the plan step by step while the live
        // budget covers (next step + emission) with margin. Planned steps
        // are nested prefixes, so previewing step k's cost before
        // planning it is exact.
        while k < total {
            let next_cost = engine.mcu.energy(&program.step_cost(k));
            let needed = (next_cost + emit_energy) * cfg.margin;
            if engine.cap.usable_energy() < needed {
                break;
            }
            program.plan(k + 1);
            let cost = program.step_cost(k);
            if engine.run_op(&cost, Ledger::App) == OpOutcome::BrownOut {
                return RoundOutcome::Dropped { steps: k, sleep: false };
            }
            program.execute_step(k);
            k += 1;
        }

        // Emit — by construction within the same power cycle.
        match engine.run_op(&program.emit_cost(), Ledger::App) {
            OpOutcome::Done => RoundOutcome::Emitted {
                emitted_at: engine.now,
                steps: k,
                output: program.output(),
            },
            OpOutcome::BrownOut => RoundOutcome::Dropped { steps: k, sleep: true },
        }
    }
}

impl<P: StepProgram> Runtime<P> for ApproxRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.cfg.sample_period).drive(program, engine, self)
    }
}

/// Run the approximate-intermittent runtime until the campaign horizon or
/// the end of the input stream. Thin wrapper over [`ApproxRuntime`].
pub fn run<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    cfg: &ApproxConfig,
) -> Campaign<P::Output> {
    ApproxRuntime::new(cfg.clone()).run(program, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::estimator::{EnergyProfile, SmartTable};
    use crate::energy::harvester::Harvester;
    use crate::energy::mcu::{McuModel, OpCost};
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;

    fn engine(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    #[test]
    fn greedy_always_emits_within_same_cycle() {
        // Expensive program (17 mJ > buffer): GREEDY must truncate.
        let mut p = SyntheticProgram::new(20, 140, 400_000);
        let mut e = engine(1.5e-3, 3600.0 * 2.0);
        let c = run(&mut p, &mut e, &ApproxConfig::greedy(60.0));
        let emitted: Vec<_> = c.rounds.iter().filter(|r| r.emitted_at.is_some()).collect();
        assert!(!emitted.is_empty());
        // The paper's key guarantee: latency is zero power cycles.
        assert!(emitted.iter().all(|r| r.latency_cycles == 0));
        // And the plan was truncated below full precision.
        assert!(emitted.iter().any(|r| r.steps_executed < 140));
        // No persistent state was ever managed.
        assert_eq!(c.state_energy, 0.0);
    }

    #[test]
    fn greedy_uses_all_steps_when_energy_abounds() {
        let mut p = SyntheticProgram::new(5, 10, 10_000);
        let mut e = engine(3e-3, 3600.0);
        let c = run(&mut p, &mut e, &ApproxConfig::greedy(60.0));
        assert!(c.rounds.iter().all(|r| r.steps_executed == 10));
    }

    fn smart_table(steps: usize, cycles: u64, acc_at_full: f64) -> SmartTable {
        let mcu = McuModel::paper_default();
        let costs: Vec<OpCost> = (0..steps).map(|_| OpCost::cycles(cycles)).collect();
        let profile = EnergyProfile::from_costs(&mcu, &costs);
        // Linear accuracy curve from 1/6 to acc_at_full.
        let acc: Vec<f64> = (0..=steps)
            .map(|p| 1.0 / 6.0 + (acc_at_full - 1.0 / 6.0) * p as f64 / steps as f64)
            .collect();
        let emit = mcu.energy(&OpCost { cycles: 500, ble_bytes: 1, ..Default::default() });
        SmartTable::new(acc, &profile, emit)
    }

    #[test]
    fn smart_skips_when_budget_insufficient() {
        let mut p = SyntheticProgram::new(10, 140, 400_000);
        // Tiny harvest: buffer starts at v_on and barely recharges.
        let mut e = engine(5e-6, 3600.0 * 2.0);
        let table = smart_table(140, 400_000, 0.88);
        // Demand an accuracy needing ~all features: infeasible per cycle.
        let c = run(&mut p, &mut e, &ApproxConfig::smart(60.0, 0.87, table));
        let skipped = c.rounds.iter().filter(|r| r.emitted_at.is_none()).count();
        assert!(skipped > 0, "SMART should skip under energy scarcity");
    }

    #[test]
    fn smart_meets_bound_on_processed_samples() {
        let mut p = SyntheticProgram::new(10, 140, 100_000);
        let mut e = engine(2e-3, 3600.0);
        let table = smart_table(140, 100_000, 0.88);
        let bound = 0.60;
        let required = table.min_features_for(bound).unwrap();
        let c = run(&mut p, &mut e, &ApproxConfig::smart(60.0, bound, table));
        for r in c.rounds.iter().filter(|r| r.emitted_at.is_some()) {
            assert!(
                r.steps_executed >= required,
                "emitted with {} < required {}",
                r.steps_executed,
                required
            );
        }
    }

    #[test]
    fn greedy_beats_chinchilla_throughput() {
        // The paper's headline: same program, same energy, approx emits
        // far more results.
        let horizon = 3600.0 * 2.0;
        let mut pg = SyntheticProgram::new(100_000, 140, 400_000);
        let mut eg = engine(0.12e-3, horizon);
        let greedy = run(&mut pg, &mut eg, &ApproxConfig::greedy(60.0));

        let mut pc = SyntheticProgram::new(100_000, 140, 400_000);
        let mut ec = engine(0.12e-3, horizon);
        let chin = crate::exec::chinchilla::run(
            &mut pc,
            &mut ec,
            &crate::exec::chinchilla::ChinchillaConfig::default(),
        );
        let tg = greedy.emitted().count();
        let tc = chin.emitted().count();
        assert!(
            tg as f64 >= 2.0 * tc.max(1) as f64,
            "greedy={tg} chinchilla={tc}"
        );
    }
}
