//! The intermittent device engine.
//!
//! A discrete-event simulation of one energy-harvesting device: the
//! harvester output flows through the booster into the capacitor; every
//! operation the runtime performs is charged atomically against the
//! buffer; crossing the brown-out threshold kills the device; the engine
//! then replays the recharge ramp until the turn-on threshold and counts a
//! new power cycle. This is the role MSPSim + the Ekho-style replay supply
//! play in the paper (§5, §6.3).
//!
//! # Event-driven analytic stepping
//!
//! The engine's three hot loops — recharge-to-boot, in-operation
//! harvesting, and LPM3 sleep — are *piecewise-analytic*: the harvester
//! is a sequence of constant-power segments ([`Harvester::piecewise`];
//! [`Harvester::segments`] is the same view as an iterator),
//! the booster's output is voltage-independent above its cold-start gate
//! ([`Booster::warm_output_power`]), so within one segment the buffer's
//! energy evolves **linearly**, `e(t) = e₀ + (p_out − p_load)·t` clamped
//! at the rail, and every threshold crossing (V_on, V_off, rail) has a
//! closed form. Instead of integrating with a fixed `charge_dt` stride,
//! the engine jumps straight to the next event — segment boundary,
//! threshold crossing, operation end, or campaign horizon — turning
//! O(simulated-seconds / dt) work into O(events). Runs of segments are
//! additionally skipped in O(1) blocks via precomputed prefix energies
//! (see [`Supply`]).
//!
//! The original fixed-step stepping algorithm is preserved unchanged as
//! the **reference engine** (numerics shift at the ULP level only: the
//! capacitor now stores energy rather than voltage, dropping a sqrt
//! round-trip per stride). Select it with [`EngineConfig::reference`],
//! `EngineKind::FixedStep`, the `AIC_ENGINE=step` environment variable,
//! or the CLI's `--engine step`. Golden-trajectory tests
//! (`tests/engine_equivalence.rs`) gate the analytic engine on agreement
//! with it across all five ambient traces and the kinetic harvester.

use crate::energy::booster::Booster;
use crate::energy::capacitor::Capacitor;
use crate::energy::harvester::Harvester;
use crate::energy::mcu::{McuModel, OpCost};
use crate::energy::traces::Piecewise;
use crate::exec::faultplan::{FaultInjector, FaultPlan};
use crate::exec::tracked::{Event, Probe};
use std::sync::{Arc, OnceLock};

/// Which ledger an energy expense belongs to (Fig. 1's split between
/// "useful computations" and "managing persistent state").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ledger {
    /// Useful application processing: sensing, feature/loop steps, emission.
    App,
    /// Persistent-state management: checkpoints, restores, WAR versioning.
    State,
}

/// Result of attempting an operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpOutcome {
    /// Completed; device still alive.
    Done,
    /// The buffer crossed brown-out during the operation: the operation
    /// did NOT take effect and all volatile state is lost.
    BrownOut,
}

/// Which integrator drives the energy state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// Piecewise-analytic event stepping (the default).
    #[default]
    Analytic,
    /// The original fixed-`charge_dt` integrator, kept as the golden
    /// reference and as an escape hatch (`AIC_ENGINE=step`).
    FixedStep,
}

impl EngineKind {
    /// Parse an integrator spelling: `step`/`fixed`/`reference` select
    /// the fixed-step reference engine, `analytic` the event-driven one.
    /// Single source of truth for the CLI flag, the `AIC_ENGINE`
    /// environment variable, and the bench artifact label.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "step" | "fixed" | "reference" => Some(EngineKind::FixedStep),
            "analytic" => Some(EngineKind::Analytic),
            _ => None,
        }
    }

    /// Canonical spelling ([`EngineKind::parse`] round-trips it).
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Analytic => "analytic",
            EngineKind::FixedStep => "step",
        }
    }

    /// The process-wide default: `AIC_ENGINE=step|fixed|reference`
    /// selects the fixed-step reference engine; anything else (or unset)
    /// selects the analytic engine. This is how the CLI's `--engine`
    /// flag and bench baselines reach every campaign without threading a
    /// parameter through the coordinator.
    pub fn from_env() -> EngineKind {
        match std::env::var("AIC_ENGINE") {
            Err(_) => EngineKind::Analytic,
            Ok(s) => EngineKind::parse(&s).unwrap_or_else(|| {
                // No silent fallback on an explicit-but-broken request
                // (same contract as the CLI's --policy): warn once.
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "warning: unrecognized AIC_ENGINE='{s}' \
                         (expected analytic|step); using the analytic engine"
                    );
                });
                EngineKind::Analytic
            }),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub capacitor: Capacitor,
    pub booster: Booster,
    pub mcu: McuModel,
    /// Integration step for charging/sleeping, seconds (fixed-step
    /// reference engine only; the analytic engine steps event-to-event).
    pub charge_dt: f64,
    /// Campaign horizon: absolute time at which the simulation stops.
    pub max_time: f64,
    /// Initial capacitor voltage (e.g. `v_on` to boot immediately).
    pub initial_voltage: f64,
    /// Which integrator to use.
    pub kind: EngineKind,
}

impl EngineConfig {
    /// Paper-default device on the given horizon. The integrator kind
    /// honours the `AIC_ENGINE` environment variable (see
    /// [`EngineKind::from_env`]).
    pub fn paper_default(max_time: f64) -> EngineConfig {
        let capacitor = Capacitor::paper_default();
        let initial_voltage = capacitor.v_on;
        EngineConfig {
            capacitor,
            booster: Booster::paper_default(),
            mcu: McuModel::paper_default(),
            charge_dt: 0.02,
            max_time,
            initial_voltage,
            kind: EngineKind::from_env(),
        }
    }

    /// The fixed-step **reference engine** configuration: identical
    /// device, original integrator. Golden-trajectory tests compare the
    /// analytic engine against engines built from this.
    pub fn reference(max_time: f64) -> EngineConfig {
        EngineConfig { kind: EngineKind::FixedStep, ..EngineConfig::paper_default(max_time) }
    }
}

/// Segments per skip block: one block is skipped in O(1) when the
/// energy trajectory provably stays inside (brown-out, rail) bounds.
const SEGS_PER_BLOCK: usize = 256;

/// Tolerance for "pegged at the rail" detection (joules). Covers the
/// one-ulp loss of a voltage↔energy round trip; energy errors it can
/// introduce are orders of magnitude below any threshold gap.
const PEG_EPS: f64 = 1e-12;

/// The analytic engine's stepping table: the harvester's run-length
/// piecewise view with the booster transform and prefix energies baked
/// in. The table is **immutable** once built — each engine walks it
/// through its own private [`Cursor`] — so one table can be shared
/// `Arc`-style by every cell of a sweep that resolves to the same
/// supply (same harvester, seed and booster config; see
/// [`SupplyCache`](crate::coordinator::experiment::SupplyCache)).
#[derive(Clone, Debug)]
pub struct SupplyTable {
    /// The harvester's run-length piecewise view (segment end times, raw
    /// powers, repetition period — ∞ for a constant source).
    pw: Piecewise,
    /// Warm booster output power of segment `i`, watts.
    p_out: Vec<f64>,
    /// Raw power below the booster's cold-start threshold (gated to zero
    /// while the buffer sits at ~0 V).
    cold: Vec<bool>,
    /// Warm output energy from period start through segment `i`, joules.
    cum: Vec<f64>,
    /// Per-block minimum of `p_out` (blocks of [`SEGS_PER_BLOCK`]).
    blk_min: Vec<f64>,
    /// Per-block "contains a cold-gated segment".
    blk_cold: Vec<bool>,
}

/// A per-engine position within a [`SupplyTable`]: current segment,
/// elapsed whole periods, and the absolute time that state corresponds
/// to. Keeping the cursor out of the shared table is what makes sharing
/// sound: concurrent engines never write to the table itself.
#[derive(Clone, Copy, Debug, Default)]
struct Cursor {
    /// Current segment within the period.
    idx: usize,
    /// How many whole periods have elapsed before it.
    epoch: u64,
    /// Absolute time the cursor state corresponds to.
    time: f64,
}

impl SupplyTable {
    fn new(harvester: &Harvester, booster: &Booster) -> SupplyTable {
        let pw = harvester.piecewise();
        let n = pw.len();
        let p_out: Vec<f64> =
            pw.powers.iter().map(|&p| booster.warm_output_power(p)).collect();
        let cold: Vec<bool> =
            pw.powers.iter().map(|&p| p < booster.cold_start_power).collect();
        let mut cum = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            let len = pw.ends[i] - pw.start(i);
            if p_out[i] > 0.0 && len.is_finite() {
                acc += p_out[i] * len;
            }
            cum.push(acc);
        }
        let blocks = n / SEGS_PER_BLOCK + usize::from(n % SEGS_PER_BLOCK != 0);
        let mut blk_min = vec![f64::INFINITY; blocks];
        let mut blk_cold = vec![false; blocks];
        for i in 0..n {
            let b = i / SEGS_PER_BLOCK;
            blk_min[b] = blk_min[b].min(p_out[i]);
            blk_cold[b] = blk_cold[b] || cold[i];
        }
        SupplyTable { pw, p_out, cold, cum, blk_min, blk_cold }
    }

    #[inline]
    fn epoch_start(&self, cur: &Cursor) -> f64 {
        if cur.epoch == 0 {
            0.0
        } else {
            cur.epoch as f64 * self.pw.period
        }
    }

    /// Absolute end time of the current segment. The last segment of a
    /// period ends exactly at `(epoch+1)·period` so consecutive periods
    /// tile with no float seam.
    #[inline]
    fn seg_end_abs(&self, cur: &Cursor) -> f64 {
        if self.pw.period.is_finite() && cur.idx + 1 == self.pw.len() {
            (cur.epoch + 1) as f64 * self.pw.period
        } else {
            self.epoch_start(cur) + self.pw.ends[cur.idx]
        }
    }

    /// Advance to the next segment (wrapping a finite period; a constant
    /// source stays on its single infinite segment).
    #[inline]
    fn advance(&self, cur: &mut Cursor) {
        if cur.idx + 1 < self.pw.len() {
            cur.idx += 1;
        } else if self.pw.period.is_finite() {
            cur.idx = 0;
            cur.epoch += 1;
        }
    }

    /// Re-derive the cursor from an absolute time (O(log n), via
    /// [`Piecewise::locate`]); a no-op when the engine left it exactly
    /// here, which is the steady state.
    fn seek(&self, cur: &mut Cursor, t: f64) {
        if t == cur.time {
            return;
        }
        let (epoch, idx) = self.pw.locate(t);
        cur.epoch = epoch;
        cur.idx = idx;
        cur.time = t;
    }

    /// Warm energy, absolute end time, minimum output power, and
    /// cold-gate presence for the remainder of the block containing the
    /// current segment, measured from `now` (inside the current segment).
    #[inline]
    fn rest_of_block(&self, cur: &Cursor, now: f64) -> (f64, f64, f64, bool) {
        let b = cur.idx / SEGS_PER_BLOCK;
        let last = ((b + 1) * SEGS_PER_BLOCK).min(self.pw.len()) - 1;
        let p = self.p_out[cur.idx];
        let rem = if p > 0.0 { p * (self.seg_end_abs(cur) - now).max(0.0) } else { 0.0 };
        let energy = rem + self.cum[last] - self.cum[cur.idx];
        let end_abs = if self.pw.period.is_finite() && last + 1 == self.pw.len() {
            (cur.epoch + 1) as f64 * self.pw.period
        } else {
            self.epoch_start(cur) + self.pw.ends[last]
        };
        (energy, end_abs, self.blk_min[b], self.blk_cold[b])
    }

    /// Move the cursor to the first segment after the current block.
    #[inline]
    fn jump_to_block_end(&self, cur: &mut Cursor) {
        let b = cur.idx / SEGS_PER_BLOCK;
        cur.idx = ((b + 1) * SEGS_PER_BLOCK).min(self.pw.len()) - 1;
        self.advance(cur);
    }
}

/// A materialised harvester plus its lazily-built analytic stepping
/// table, shared across engines. One `SharedSupply` feeds every cell of
/// a sweep grid that resolves to the same supply: the harvester is
/// materialised once, and the [`SupplyTable`] is built at most once (on
/// first use by an analytic engine — fixed-step engines never build
/// one), whatever the number of cells or fleet workers.
#[derive(Debug)]
pub struct SharedSupply {
    harvester: Arc<Harvester>,
    table: OnceLock<Arc<SupplyTable>>,
}

impl SharedSupply {
    pub fn new(harvester: Harvester) -> SharedSupply {
        SharedSupply { harvester: Arc::new(harvester), table: OnceLock::new() }
    }

    /// The shared harvester.
    pub fn harvester(&self) -> &Arc<Harvester> {
        &self.harvester
    }

    /// The stepping table under `booster`, built on the first call and
    /// shared thereafter. Everyone sharing one `SharedSupply` must use
    /// one booster config — the supply cache keys on it.
    pub fn table(&self, booster: &Booster) -> Arc<SupplyTable> {
        Arc::clone(
            self.table
                .get_or_init(|| Arc::new(SupplyTable::new(&self.harvester, booster))),
        )
    }

    /// Whether the stepping table has been built yet (it never is for a
    /// supply only fixed-step engines have used).
    pub fn table_built(&self) -> bool {
        self.table.get().is_some()
    }
}

/// The simulated device.
pub struct Engine {
    pub cap: Capacitor,
    pub booster: Booster,
    pub mcu: McuModel,
    pub harvester: Arc<Harvester>,
    /// Absolute simulation time, seconds.
    pub now: f64,
    /// Power cycles so far (boot events; the first boot is cycle 1).
    pub cycles: u64,
    /// Power failures (brown-outs) so far.
    pub failures: u64,
    /// Joules billed to useful application processing.
    pub app_energy: f64,
    /// Joules billed to persistent-state management.
    pub state_energy: f64,
    /// Battery mode: the buffer is bottomless, operations advance time
    /// and bill the ledgers but never discharge the capacitor, and the
    /// device cannot brown out. The continuous baseline runs on this.
    powered: bool,
    charge_dt: f64,
    max_time: f64,
    kind: EngineKind,
    /// Analytic stepping table (possibly shared with other engines);
    /// `None` on the fixed-step reference path and in battery mode.
    supply: Option<Arc<SupplyTable>>,
    /// This engine's private position within the shared table.
    cursor: Cursor,
    /// Operations attempted so far — the fault-point ordinal space the
    /// correctness harness enumerates. Counted unconditionally (one
    /// u64 increment on the hot path).
    op_count: u64,
    /// Deterministic power-failure injection; `None` = physics only.
    fault: Option<FaultInjector>,
    /// Execution-trace probe (correctness harness); `None` in
    /// production runs.
    probe: Option<Probe>,
}

impl Engine {
    /// Build an engine owning its supply. For sweep grids where many
    /// cells share one supply, prefer [`Engine::from_shared`] so the
    /// harvester and stepping table are materialised once.
    pub fn new(cfg: EngineConfig, harvester: Harvester) -> Engine {
        Engine::from_shared(cfg, &SharedSupply::new(harvester))
    }

    /// Build an engine on a shared supply: the harvester `Arc` is cloned
    /// and the analytic stepping table is built once per
    /// [`SharedSupply`], however many engines it feeds.
    pub fn from_shared(cfg: EngineConfig, shared: &SharedSupply) -> Engine {
        let mut cap = cfg.capacitor;
        cap.set_voltage(cfg.initial_voltage);
        let supply = match cfg.kind {
            EngineKind::Analytic => Some(shared.table(&cfg.booster)),
            EngineKind::FixedStep => None,
        };
        Engine {
            cap,
            booster: cfg.booster,
            mcu: cfg.mcu,
            harvester: Arc::clone(shared.harvester()),
            now: 0.0,
            cycles: if cfg.initial_voltage > 0.0 { 1 } else { 0 },
            failures: 0,
            app_energy: 0.0,
            state_energy: 0.0,
            powered: false,
            charge_dt: cfg.charge_dt,
            max_time: cfg.max_time,
            kind: cfg.kind,
            supply,
            cursor: Cursor::default(),
            op_count: 0,
            fault: None,
            probe: None,
        }
    }

    /// A battery-powered device on the given horizon: time and energy
    /// are accounted through the same MCU model as the intermittent
    /// runtimes, but the device never browns out. `power_cycles` stays 0
    /// — there are no boot events on a battery.
    pub fn powered(mcu: McuModel, max_time: f64) -> Engine {
        // Same paper-default device as the harvesting engines — one
        // source of truth for the hardware constants. A battery never
        // reaches the harvesting branches, so no stepping table is built
        // (and none is counted against a sweep's supply builds).
        let mut cfg = EngineConfig::paper_default(max_time);
        cfg.mcu = mcu;
        cfg.initial_voltage = cfg.capacitor.v_max;
        let mut cap = cfg.capacitor;
        cap.set_voltage(cfg.initial_voltage);
        Engine {
            cap,
            booster: cfg.booster,
            mcu: cfg.mcu,
            harvester: Arc::new(Harvester::Constant(0.0)),
            now: 0.0,
            cycles: 0, // a battery counts no boot events
            failures: 0,
            app_energy: 0.0,
            state_energy: 0.0,
            powered: true,
            charge_dt: cfg.charge_dt,
            max_time: cfg.max_time,
            kind: cfg.kind,
            supply: None,
            cursor: Cursor::default(),
            op_count: 0,
            fault: None,
            probe: None,
        }
    }

    /// Which integrator this engine runs.
    #[inline]
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// True once the campaign horizon is reached.
    #[inline]
    pub fn out_of_time(&self) -> bool {
        self.now >= self.max_time
    }

    /// The campaign horizon, seconds.
    #[inline]
    pub fn horizon(&self) -> f64 {
        self.max_time
    }

    /// The campaign duration to report. A battery-powered device stops
    /// observing at the horizon even if its last operations ran a little
    /// past it (matching the continuous baseline's historical
    /// accounting); a harvesting device reports the real elapsed time,
    /// which may overrun the horizon by the tail of the last round.
    #[inline]
    pub fn campaign_duration(&self) -> f64 {
        if self.powered {
            self.now.min(self.max_time)
        } else {
            self.now
        }
    }

    // ------------------------------------------------------------------
    // Fixed-step reference integrator (the original engine, preserved).
    // ------------------------------------------------------------------

    /// Integrate harvesting over `[t, t+dt)` without advancing time.
    #[inline]
    fn harvest_into_buffer(&mut self, t: f64, dt: f64) {
        let p_raw = self.harvester.power_at(t);
        let p_out = self.booster.output_power(p_raw, self.cap.voltage());
        if p_out > 0.0 {
            self.cap.charge(p_out * dt);
        }
    }

    /// Advance `secs` of pure charging (device off — no load at all).
    fn advance_charging(&mut self, secs: f64) {
        let mut remaining = secs;
        while remaining > 0.0 {
            let dt = remaining.min(self.charge_dt);
            self.harvest_into_buffer(self.now, dt);
            self.now += dt;
            remaining -= dt;
        }
    }

    /// Reference charge-to-boot wait: fixed `charge_dt` strides.
    fn step_charge_wait(&mut self) -> bool {
        while !self.cap.can_boot() {
            if self.out_of_time() {
                return false;
            }
            self.advance_charging(self.charge_dt);
        }
        true
    }

    /// Reference in-operation harvest: chunked over the op duration.
    fn step_harvest_op(&mut self, duration: f64) {
        let mut remaining = duration;
        while remaining > 0.0 {
            let dt = remaining.min(self.charge_dt);
            self.harvest_into_buffer(self.now, dt);
            self.now += dt;
            remaining -= dt;
        }
    }

    /// Reference sleep: chunked integration with the adaptive stride
    /// (5× wider while comfortably above brown-out — sleep draw is
    /// µW-scale, so no threshold can be crossed within one wide step).
    fn step_sleep(&mut self, secs: f64) -> bool {
        let mut remaining = secs;
        let wide = self.charge_dt * 5.0;
        let safe_v = self.cap.v_off + 0.05;
        while remaining > 0.0 {
            if self.out_of_time() {
                return true; // horizon reached while alive
            }
            let dt = if self.cap.voltage() > safe_v {
                remaining.min(wide)
            } else {
                remaining.min(self.charge_dt)
            };
            self.harvest_into_buffer(self.now, dt);
            let ok = self.cap.discharge(self.mcu.sleep_energy(dt));
            self.now += dt;
            remaining -= dt;
            if !ok || !self.cap.alive() {
                self.brown_out();
                return false;
            }
        }
        true
    }

    // ------------------------------------------------------------------
    // Analytic event-stepping integrator.
    // ------------------------------------------------------------------

    /// Charge (no load) until the buffer can boot or the horizon
    /// expires; O(events), with O(1) block skips over segment runs that
    /// provably cannot reach V_on.
    fn an_charge_wait(&mut self) -> bool {
        let e_on = self.cap.boot_energy_level();
        let cold_e = self.cap.energy_at(Booster::COLD_GATE_V);
        let mut e = self.cap.energy();
        let mut now = self.now;
        let tab = Arc::clone(self.supply.as_ref().expect("analytic engine without supply"));
        let mut cur = self.cursor;
        tab.seek(&mut cur, now);
        let booted = loop {
            if e >= e_on {
                break true;
            }
            if now >= self.max_time {
                break false;
            }
            // O(1) block skip: the rest of this block cannot reach V_on
            // (charging is monotone — no load, rail above V_on).
            let (be, bend, _min, bcold) = tab.rest_of_block(&cur, now);
            if bend <= self.max_time && (e > cold_e || !bcold) && e + be < e_on {
                e += be;
                now = bend;
                tab.jump_to_block_end(&mut cur);
                continue;
            }
            let seg_end = tab.seg_end_abs(&cur);
            let limit = if seg_end < self.max_time { seg_end } else { self.max_time };
            let gated = e <= cold_e && tab.cold[cur.idx];
            let p = if gated { 0.0 } else { tab.p_out[cur.idx] };
            if p > 0.0 && e + p * (limit - now) >= e_on {
                // Closed-form V_on crossing inside this segment.
                now += (e_on - e) / p;
                e = e_on;
                break true;
            }
            e += p * (limit - now);
            now = limit;
            if limit == seg_end {
                tab.advance(&mut cur);
            }
        };
        cur.time = now;
        self.cursor = cur;
        self.now = now;
        self.cap.set_energy(e);
        booted
    }

    /// Exact harvest integral over `[now, until)` (rail-clamped), used
    /// while an operation runs. The device is alive here, so the
    /// cold-start gate cannot engage.
    fn an_harvest_span(&mut self, until: f64) {
        let e_max = self.cap.max_energy();
        let mut e = self.cap.energy();
        let mut now = self.now;
        let tab = Arc::clone(self.supply.as_ref().expect("analytic engine without supply"));
        let mut cur = self.cursor;
        tab.seek(&mut cur, now);
        while now < until {
            let (be, bend, _min, _cold) = tab.rest_of_block(&cur, now);
            if bend <= until && e + be <= e_max {
                e += be;
                now = bend;
                tab.jump_to_block_end(&mut cur);
                continue;
            }
            let seg_end = tab.seg_end_abs(&cur);
            let limit = if seg_end < until { seg_end } else { until };
            e = (e + tab.p_out[cur.idx] * (limit - now)).min(e_max);
            now = limit;
            if limit == seg_end {
                tab.advance(&mut cur);
            }
        }
        cur.time = now;
        self.cursor = cur;
        self.now = now;
        self.cap.set_energy(e);
    }

    /// Event-stepped LPM3 sleep: per segment the net rate
    /// `p_out − sleep_power` is constant, so the V_off crossing is in
    /// closed form; whole blocks are skipped in O(1) when the trajectory
    /// provably stays inside (V_off, rail) — including the common
    /// "pegged at the rail under ample harvest" regime.
    fn an_sleep(&mut self, secs: f64) -> bool {
        let stop = (self.now + secs).min(self.max_time);
        let e_max = self.cap.max_energy();
        let e_off = self.cap.brownout_energy_level();
        let p_load = self.mcu.sleep_power;
        let mut e = self.cap.energy();
        let mut now = self.now;
        let tab = Arc::clone(self.supply.as_ref().expect("analytic engine without supply"));
        let mut cur = self.cursor;
        tab.seek(&mut cur, now);
        if e < e_off && now < stop {
            // Dead on entry (e.g. sleeping off a failed emission). The
            // reference integrator takes one stride before noticing —
            // on a strong supply that stride's harvest can lift the
            // buffer back over V_off and the sleep continues; otherwise
            // it is an immediate brown-out. Mirror both outcomes.
            let dt = self.charge_dt.min(stop - now);
            e = (e + tab.p_out[cur.idx] * dt).min(e_max) - p_load * dt;
            now += dt;
            if e < e_off {
                cur.time = now;
                self.cursor = cur;
                self.now = now;
                self.brown_out();
                return false;
            }
            tab.seek(&mut cur, now);
        }
        while now < stop {
            let (be, bend, bmin, _cold) = tab.rest_of_block(&cur, now);
            if bend <= stop {
                if e + PEG_EPS >= e_max && bmin >= p_load {
                    // Pegged at the rail, never outdrawn: stays pegged.
                    e = e_max;
                    now = bend;
                    tab.jump_to_block_end(&mut cur);
                    continue;
                }
                let dur = bend - now;
                if e + be <= e_max && e - p_load * dur > e_off {
                    // No clamp, no brown-out possible: exact linear jump.
                    e += be - p_load * dur;
                    now = bend;
                    tab.jump_to_block_end(&mut cur);
                    continue;
                }
            }
            let seg_end = tab.seg_end_abs(&cur);
            let limit = if seg_end < stop { seg_end } else { stop };
            let dt = limit - now;
            let net = tab.p_out[cur.idx] - p_load;
            if net >= 0.0 {
                e = (e + net * dt).min(e_max);
            } else if e + net * dt >= e_off {
                e += net * dt;
            } else {
                // Closed-form V_off crossing: the device dies here.
                now += ((e - e_off) / -net).max(0.0);
                cur.time = now;
                self.cursor = cur;
                self.now = now;
                self.brown_out();
                return false;
            }
            now = limit;
            if limit == seg_end {
                tab.advance(&mut cur);
            }
        }
        cur.time = now;
        self.cursor = cur;
        self.now = now;
        self.cap.set_energy(e);
        true
    }

    // ------------------------------------------------------------------
    // Public device operations (dispatch over the integrator kind).
    // ------------------------------------------------------------------

    /// Device is dead: charge until boot is possible, then boot (counting
    /// a power cycle and paying the boot cost). Returns `false` if the
    /// campaign horizon expires first.
    pub fn charge_until_boot(&mut self) -> bool {
        if self.powered {
            // A battery never dies; there is nothing to recharge.
            return !self.out_of_time();
        }
        let charged = match self.kind {
            EngineKind::Analytic => self.an_charge_wait(),
            EngineKind::FixedStep => self.step_charge_wait(),
        };
        if !charged {
            return false;
        }
        self.cycles += 1;
        if let Some(p) = &self.probe {
            p.set_cycle(self.cycles);
            p.record(Event::Boot { cycle: self.cycles, now: self.now });
        }
        // Boot/runtime-init cost; billed to App (every runtime pays it).
        let boot = self.mcu.boot_energy;
        self.app_energy += boot;
        let _ = self.cap.discharge(boot);
        true
    }

    /// Execute one atomic operation: harvest over its duration, then
    /// withdraw its energy. On brown-out the operation is void and the
    /// buffer is left just below the brown-out threshold (the device
    /// consumed down to V_off and died).
    ///
    /// When a [`FaultPlan`] is armed (see [`Engine::arm_faults`]), the
    /// injector is consulted once per operation; a hit behaves exactly
    /// like a physical failure at the end of the op's window — time and
    /// harvesting advance, nothing is billed, the op is void. The
    /// powered (battery) engine never injects: a battery cannot fail.
    pub fn run_op(&mut self, cost: &OpCost, ledger: Ledger) -> OpOutcome {
        let ordinal = self.op_count;
        self.op_count += 1;
        let duration = self.mcu.duration(cost);
        let energy = self.mcu.energy(cost);
        if self.powered {
            self.now += duration;
            match ledger {
                Ledger::App => self.app_energy += energy,
                Ledger::State => self.state_energy += energy,
            }
            self.record_op(cost, ledger, OpOutcome::Done, false, ordinal);
            return OpOutcome::Done;
        }
        if !self.cap.alive() {
            let out = self.brown_out();
            self.record_op(cost, ledger, out, false, ordinal);
            return out;
        }
        let injected = match self.fault.as_mut() {
            Some(f) => f.strike(ordinal),
            None => false,
        };
        // Harvest while the op runs.
        match self.kind {
            EngineKind::Analytic => self.an_harvest_span(self.now + duration),
            EngineKind::FixedStep => self.step_harvest_op(duration),
        }
        if injected {
            let out = self.brown_out();
            self.record_op(cost, ledger, out, true, ordinal);
            return out;
        }
        let ok = self.cap.discharge(energy);
        if !ok || !self.cap.alive() {
            let out = self.brown_out();
            self.record_op(cost, ledger, out, false, ordinal);
            return out;
        }
        match ledger {
            Ledger::App => self.app_energy += energy,
            Ledger::State => self.state_energy += energy,
        }
        self.record_op(cost, ledger, OpOutcome::Done, false, ordinal);
        OpOutcome::Done
    }

    fn brown_out(&mut self) -> OpOutcome {
        self.failures += 1;
        // Physically the device dies crossing V_off; the residual charge
        // sits just below the threshold.
        self.cap.set_voltage(self.cap.v_off * 0.995);
        if let Some(p) = &self.probe {
            p.record(Event::Fail { failures: self.failures, now: self.now });
        }
        OpOutcome::BrownOut
    }

    /// Arm deterministic power-failure injection for the rest of the
    /// campaign (correctness harness; see
    /// [`faultplan`](crate::exec::faultplan)). Sleep and recharge are
    /// not fault points — a failure there is indistinguishable from a
    /// longer recharge — so injection targets `run_op` ordinals only.
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.fault = Some(FaultInjector::new(plan));
    }

    /// Attach an execution-trace probe (correctness harness). The probe
    /// is also handed to a [`crate::exec::tracked::TrackedProgram`] so
    /// program events interleave with op events in one totally ordered
    /// log.
    pub fn attach_probe(&mut self, probe: Probe) {
        probe.set_cycle(self.cycles);
        self.probe = Some(probe);
    }

    /// Operations attempted so far: each `run_op` call is one fault
    /// point, whatever its outcome.
    pub fn ops_attempted(&self) -> u64 {
        self.op_count
    }

    /// Failures forced by the armed fault plan (a subset of
    /// `self.failures`).
    pub fn injected_faults(&self) -> u64 {
        self.fault.as_ref().map_or(0, |f| f.injected())
    }

    fn record_op(
        &self,
        cost: &OpCost,
        ledger: Ledger,
        outcome: OpOutcome,
        injected: bool,
        ordinal: u64,
    ) {
        if let Some(p) = &self.probe {
            p.record(Event::Op {
                ordinal,
                ledger,
                cycles: cost.cycles,
                fram_reads: cost.fram_reads,
                fram_writes: cost.fram_writes,
                ble_bytes: cost.ble_bytes,
                adc_reads: cost.adc_reads,
                sensor: cost.sensor_secs > 0.0,
                outcome,
                injected,
                cycle: self.cycles,
            });
        }
    }

    /// Sleep in LPM3 for `secs` (harvesting continues, sleep current is
    /// drawn). Returns `false` if the device browned out while sleeping.
    pub fn sleep(&mut self, secs: f64) -> bool {
        if self.powered {
            // Never sleep past the campaign horizon: the reported
            // duration must stop at `max_time`, exactly like the
            // harvesting branches below.
            if !self.out_of_time() {
                self.now = (self.now + secs).min(self.max_time);
            }
            return true;
        }
        match self.kind {
            EngineKind::Analytic => self.an_sleep(secs),
            EngineKind::FixedStep => self.step_sleep(secs),
        }
    }

    /// Sleep until the next multiple of `period` strictly after `now`.
    ///
    /// Slot indices are computed in integer arithmetic: the naive
    /// `(now/period).floor() + 1.0` drifts for large `now` — when the
    /// division rounds up across an integer boundary it silently skips a
    /// whole slot.
    pub fn sleep_until_next_slot(&mut self, period: f64) -> bool {
        debug_assert!(period > 0.0);
        let mut idx = (self.now / period) as u64 + 1;
        if idx >= 2 && (idx - 1) as f64 * period > self.now {
            idx -= 1; // division rounded up across a boundary
        }
        let mut next = idx as f64 * period;
        if next <= self.now {
            idx += 1; // division rounded down across a boundary
            next = idx as f64 * period;
        }
        self.sleep(next - self.now)
    }

    /// The SMART policy's energy introspection: one ADC conversion, then
    /// read the usable budget. Returns `None` on brown-out during the read.
    pub fn read_budget(&mut self) -> Option<f64> {
        let cost = OpCost { adc_reads: 1, ..Default::default() };
        match self.run_op(&cost, Ledger::App) {
            OpOutcome::Done => Some(self.cap.usable_energy()),
            OpOutcome::BrownOut => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine_with(power: f64, max_time: f64) -> Engine {
        let mut cfg = EngineConfig::paper_default(max_time);
        cfg.kind = EngineKind::Analytic;
        Engine::new(cfg, Harvester::Constant(power))
    }

    fn reference_with(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::reference(max_time), Harvester::Constant(power))
    }

    #[test]
    fn boots_when_charged() {
        let mut cfg = EngineConfig::paper_default(3600.0);
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Constant(2e-3));
        assert_eq!(e.cycles, 0);
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 1);
        assert!(e.cap.alive());
        assert!(e.now > 0.0);
    }

    #[test]
    fn never_boots_without_power() {
        let mut cfg = EngineConfig::paper_default(10.0);
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Constant(0.0));
        assert!(!e.charge_until_boot());
        assert!(e.out_of_time());
    }

    #[test]
    fn op_charges_energy_and_time() {
        let mut e = engine_with(0.0, 3600.0);
        let v0 = e.cap.voltage();
        let t0 = e.now;
        let out = e.run_op(&OpCost::cycles(8_000), Ledger::App);
        assert_eq!(out, OpOutcome::Done);
        assert!(e.cap.voltage() < v0);
        assert!((e.now - t0 - 1e-3).abs() < 1e-9); // 8k cycles @ 8 MHz = 1 ms
        assert!(e.app_energy > 0.0);
        assert_eq!(e.state_energy, 0.0);
    }

    #[test]
    fn big_op_browns_out_and_is_void() {
        let mut e = engine_with(0.0, 3600.0);
        // An op far beyond the buffer: ~1 J.
        let out = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App);
        assert_eq!(out, OpOutcome::BrownOut);
        assert_eq!(e.failures, 1);
        assert!(!e.cap.alive());
        assert!(!e.cap.can_boot());
        // Void: nothing billed.
        assert_eq!(e.app_energy, 0.0);
    }

    #[test]
    fn state_ledger_separated() {
        let mut e = engine_with(0.0, 3600.0);
        let cost = OpCost { fram_writes: 100, cycles: 200, ..Default::default() };
        assert_eq!(e.run_op(&cost, Ledger::State), OpOutcome::Done);
        assert!(e.state_energy > 0.0);
        assert_eq!(e.app_energy, 0.0);
    }

    #[test]
    fn sleep_discharges_slowly_but_can_kill() {
        let mut e = engine_with(0.0, 1e7);
        assert!(e.sleep(60.0)); // 84 µJ of sleep: fine
        // Hours of sleep with zero harvest eventually browns out.
        let alive = e.sleep(4.0 * 3600.0);
        assert!(!alive);
        assert_eq!(e.failures, 1);
    }

    #[test]
    fn harvesting_during_sleep_sustains() {
        let mut e = engine_with(1e-3, 1e5);
        assert!(e.sleep(3600.0));
        assert!(e.cap.alive());
    }

    #[test]
    fn slot_alignment() {
        let mut e = engine_with(2e-3, 1e5);
        e.now = 61.0;
        assert!(e.sleep_until_next_slot(60.0));
        assert!((e.now - 120.0).abs() < 0.05, "now={}", e.now);
    }

    #[test]
    fn slot_arithmetic_is_stable_for_large_now() {
        // Powered engine: sleep advances time exactly, isolating the
        // slot arithmetic from energy effects.
        let period = 60.0;
        for &t in &[0.0, 59.9999, 60.0, 61.0, 3599.98, 1e7 + 12.3, 7.2e8 + 59.999_999] {
            let mut e = Engine::powered(McuModel::paper_default(), 1e12);
            e.now = t;
            assert!(e.sleep_until_next_slot(period));
            let k = (e.now / period).round();
            assert!(
                (e.now - k * period).abs() < 1e-6 * period.max(e.now.abs() * 1e-9),
                "t={t}: landed off-slot at {}",
                e.now
            );
            assert!(e.now > t, "t={t}: did not advance");
            assert!(e.now - t <= period + 1e-6, "t={t}: skipped a slot to {}", e.now);
        }
    }

    #[test]
    fn budget_read_costs_one_adc() {
        let mut e = engine_with(0.0, 3600.0);
        let before = e.cap.usable_energy();
        let b = e.read_budget().unwrap();
        assert!(b < before);
        assert!(b > 0.0);
    }

    #[test]
    fn powered_engine_never_browns_out() {
        let mut e = Engine::powered(McuModel::paper_default(), 1e9);
        // An op that would kill any capacitor-backed device (~1 J).
        assert_eq!(e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App), OpOutcome::Done);
        assert_eq!(e.failures, 0);
        assert_eq!(e.cycles, 0);
        assert!(e.app_energy > 0.9);
        assert!(e.cap.alive());
        // Sleeping for hours is free of brown-out risk too.
        assert!(e.sleep(8.0 * 3600.0));
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 0, "a battery counts no boot events");
    }

    #[test]
    fn recovery_cycle_after_brownout() {
        let mut e = engine_with(2e-3, 1e6);
        let _ = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App);
        assert!(!e.cap.alive());
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 2);
        assert!(e.cap.alive());
    }

    #[test]
    fn reference_engine_is_selectable_and_equivalent_on_constants() {
        // The preserved fixed-step integrator boots within one stride of
        // the analytic engine's exact crossing.
        for power in [0.3e-3, 1e-3, 2.5e-3] {
            let mut a = engine_with(power, 1e6);
            let mut r = reference_with(power, 1e6);
            assert_eq!(r.kind(), EngineKind::FixedStep);
            a.cap.set_voltage(2.0);
            r.cap.set_voltage(2.0);
            assert!(a.charge_until_boot());
            assert!(r.charge_until_boot());
            assert!(
                (a.now - r.now).abs() <= r.charge_dt + 1e-9,
                "power={power}: analytic {} vs reference {}",
                a.now,
                r.now
            );
            assert_eq!(a.cycles, r.cycles);
        }
    }

    #[test]
    fn analytic_sleep_matches_reference_brownout_time() {
        // Zero harvest: the V_off crossing has an exact closed form; the
        // reference lands within one (wide) stride of it.
        let mut a = engine_with(0.0, 1e7);
        let mut r = reference_with(0.0, 1e7);
        assert!(!a.sleep(1e6));
        assert!(!r.sleep(1e6));
        assert!(
            (a.now - r.now).abs() <= r.charge_dt * 5.0 + 1e-6,
            "analytic died at {}, reference at {}",
            a.now,
            r.now
        );
        assert_eq!(a.failures, r.failures);
    }

    #[test]
    fn analytic_engine_reseeks_after_external_time_reset() {
        // Benches rewind `now` between iterations; the segment cursor
        // must follow.
        let trace = crate::energy::traces::generate(
            crate::energy::traces::TraceKind::Sim,
            60.0,
            0.01,
            3,
        );
        let mut cfg = EngineConfig::paper_default(1e9);
        cfg.kind = EngineKind::Analytic;
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Replay(trace));
        assert!(e.charge_until_boot());
        let first_boot = e.now;
        e.cap.set_voltage(0.0);
        e.now = 0.0;
        assert!(e.charge_until_boot());
        assert!(
            (e.now - first_boot).abs() < 1e-9,
            "replayed boot at {} vs {}",
            e.now,
            first_boot
        );
    }

    #[test]
    fn shared_supply_builds_its_table_exactly_once() {
        let shared = SharedSupply::new(Harvester::Constant(1e-3));
        assert!(!shared.table_built(), "table must be lazy");
        let booster = Booster::paper_default();
        let t1 = shared.table(&booster);
        let t2 = shared.table(&booster);
        assert!(Arc::ptr_eq(&t1, &t2), "second call must reuse the table");
        let cfg = EngineConfig::paper_default(3600.0);
        let a = Engine::from_shared(cfg.clone(), &shared);
        let b = Engine::from_shared(cfg, &shared);
        if a.kind() == EngineKind::Analytic {
            assert!(Arc::ptr_eq(
                a.supply.as_ref().unwrap(),
                b.supply.as_ref().unwrap()
            ));
        }
        assert!(Arc::ptr_eq(&a.harvester, &b.harvester));
    }

    #[test]
    fn fixed_step_engines_never_build_a_table() {
        let shared = SharedSupply::new(Harvester::Constant(1e-3));
        let _e = Engine::from_shared(EngineConfig::reference(3600.0), &shared);
        assert!(!shared.table_built());
    }

    #[test]
    fn powered_engine_builds_no_supply() {
        let e = Engine::powered(McuModel::paper_default(), 3600.0);
        assert!(e.supply.is_none());
    }

    #[test]
    fn shared_engines_match_owning_engines_bitwise() {
        // Two engines on one shared supply must each reproduce exactly
        // what an owning engine does: the cursor is private, so sharing
        // introduces no cross-engine state bleed.
        let trace = crate::energy::traces::generate(
            crate::energy::traces::TraceKind::Sim,
            120.0,
            0.01,
            7,
        );
        let shared = SharedSupply::new(Harvester::Replay(trace.clone()));
        let mut cfg = EngineConfig::paper_default(1e6);
        cfg.kind = EngineKind::Analytic;
        let script = |e: &mut Engine| {
            let mut log = Vec::new();
            for _ in 0..12 {
                if !e.cap.alive() && !e.charge_until_boot() {
                    break;
                }
                let _ = e.run_op(&OpCost::cycles(600_000), Ledger::App);
                let _ = e.sleep(45.0);
                log.push((e.now, e.cap.energy(), e.cycles, e.failures));
            }
            log
        };
        let mut s1 = Engine::from_shared(cfg.clone(), &shared);
        let mut s2 = Engine::from_shared(cfg.clone(), &shared);
        let mut own = Engine::new(cfg, Harvester::Replay(trace));
        let want = script(&mut own);
        assert_eq!(script(&mut s1), want);
        assert_eq!(script(&mut s2), want);
    }
}
