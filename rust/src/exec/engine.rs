//! The intermittent device engine.
//!
//! A discrete-event simulation of one energy-harvesting device: the
//! harvester output flows through the booster into the capacitor; every
//! operation the runtime performs is charged atomically against the
//! buffer; crossing the brown-out threshold kills the device; the engine
//! then replays the recharge ramp until the turn-on threshold and counts a
//! new power cycle. This is the role MSPSim + the Ekho-style replay supply
//! play in the paper (§5, §6.3).

use crate::energy::booster::Booster;
use crate::energy::capacitor::Capacitor;
use crate::energy::harvester::Harvester;
use crate::energy::mcu::{McuModel, OpCost};

/// Which ledger an energy expense belongs to (Fig. 1's split between
/// "useful computations" and "managing persistent state").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ledger {
    /// Useful application processing: sensing, feature/loop steps, emission.
    App,
    /// Persistent-state management: checkpoints, restores, WAR versioning.
    State,
}

/// Result of attempting an operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpOutcome {
    /// Completed; device still alive.
    Done,
    /// The buffer crossed brown-out during the operation: the operation
    /// did NOT take effect and all volatile state is lost.
    BrownOut,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub capacitor: Capacitor,
    pub booster: Booster,
    pub mcu: McuModel,
    /// Integration step for charging/sleeping, seconds.
    pub charge_dt: f64,
    /// Campaign horizon: absolute time at which the simulation stops.
    pub max_time: f64,
    /// Initial capacitor voltage (e.g. `v_on` to boot immediately).
    pub initial_voltage: f64,
}

impl EngineConfig {
    /// Paper-default device on the given horizon.
    pub fn paper_default(max_time: f64) -> EngineConfig {
        let capacitor = Capacitor::paper_default();
        let initial_voltage = capacitor.v_on;
        EngineConfig {
            capacitor,
            booster: Booster::paper_default(),
            mcu: McuModel::paper_default(),
            charge_dt: 0.02,
            max_time,
            initial_voltage,
        }
    }
}

/// The simulated device.
pub struct Engine {
    pub cap: Capacitor,
    pub booster: Booster,
    pub mcu: McuModel,
    pub harvester: Harvester,
    /// Absolute simulation time, seconds.
    pub now: f64,
    /// Power cycles so far (boot events; the first boot is cycle 1).
    pub cycles: u64,
    /// Power failures (brown-outs) so far.
    pub failures: u64,
    /// Joules billed to useful application processing.
    pub app_energy: f64,
    /// Joules billed to persistent-state management.
    pub state_energy: f64,
    /// Battery mode: the buffer is bottomless, operations advance time
    /// and bill the ledgers but never discharge the capacitor, and the
    /// device cannot brown out. The continuous baseline runs on this.
    powered: bool,
    charge_dt: f64,
    max_time: f64,
}

impl Engine {
    pub fn new(cfg: EngineConfig, harvester: Harvester) -> Engine {
        let mut cap = cfg.capacitor;
        cap.set_voltage(cfg.initial_voltage);
        Engine {
            cap,
            booster: cfg.booster,
            mcu: cfg.mcu,
            harvester,
            now: 0.0,
            cycles: if cfg.initial_voltage > 0.0 { 1 } else { 0 },
            failures: 0,
            app_energy: 0.0,
            state_energy: 0.0,
            powered: false,
            charge_dt: cfg.charge_dt,
            max_time: cfg.max_time,
        }
    }

    /// A battery-powered device on the given horizon: time and energy
    /// are accounted through the same MCU model as the intermittent
    /// runtimes, but the device never browns out. `power_cycles` stays 0
    /// — there are no boot events on a battery.
    pub fn powered(mcu: McuModel, max_time: f64) -> Engine {
        // Same paper-default device as the harvesting engines — one
        // source of truth for the hardware constants.
        let mut cfg = EngineConfig::paper_default(max_time);
        cfg.mcu = mcu;
        cfg.initial_voltage = cfg.capacitor.v_max;
        let mut engine = Engine::new(cfg, Harvester::Constant(0.0));
        engine.powered = true;
        engine.cycles = 0; // a battery counts no boot events
        engine
    }

    /// True once the campaign horizon is reached.
    #[inline]
    pub fn out_of_time(&self) -> bool {
        self.now >= self.max_time
    }

    /// The campaign horizon, seconds.
    #[inline]
    pub fn horizon(&self) -> f64 {
        self.max_time
    }

    /// The campaign duration to report. A battery-powered device stops
    /// observing at the horizon even if its last operations ran a little
    /// past it (matching the continuous baseline's historical
    /// accounting); a harvesting device reports the real elapsed time,
    /// which may overrun the horizon by the tail of the last round.
    #[inline]
    pub fn campaign_duration(&self) -> f64 {
        if self.powered {
            self.now.min(self.max_time)
        } else {
            self.now
        }
    }

    /// Integrate harvesting over `[now, now+dt)` without advancing time.
    #[inline]
    fn harvest_into_buffer(&mut self, t: f64, dt: f64) {
        let p_raw = self.harvester.power_at(t);
        let p_out = self.booster.output_power(p_raw, self.cap.voltage());
        if p_out > 0.0 {
            self.cap.charge(p_out * dt);
        }
    }

    /// Advance `secs` of pure charging (device off — no load at all).
    fn advance_charging(&mut self, secs: f64) {
        let mut remaining = secs;
        while remaining > 0.0 {
            let dt = remaining.min(self.charge_dt);
            self.harvest_into_buffer(self.now, dt);
            self.now += dt;
            remaining -= dt;
        }
    }

    /// Device is dead: charge until boot is possible, then boot (counting
    /// a power cycle and paying the boot cost). Returns `false` if the
    /// campaign horizon expires first.
    pub fn charge_until_boot(&mut self) -> bool {
        if self.powered {
            // A battery never dies; there is nothing to recharge.
            return !self.out_of_time();
        }
        while !self.cap.can_boot() {
            if self.out_of_time() {
                return false;
            }
            self.advance_charging(self.charge_dt);
        }
        self.cycles += 1;
        // Boot/runtime-init cost; billed to App (every runtime pays it).
        let boot = self.mcu.boot_energy;
        self.app_energy += boot;
        let _ = self.cap.discharge(boot);
        true
    }

    /// Execute one atomic operation: harvest over its duration, then
    /// withdraw its energy. On brown-out the operation is void and the
    /// buffer is left just below the brown-out threshold (the device
    /// consumed down to V_off and died).
    pub fn run_op(&mut self, cost: &OpCost, ledger: Ledger) -> OpOutcome {
        let duration = self.mcu.duration(cost);
        let energy = self.mcu.energy(cost);
        if self.powered {
            self.now += duration;
            match ledger {
                Ledger::App => self.app_energy += energy,
                Ledger::State => self.state_energy += energy,
            }
            return OpOutcome::Done;
        }
        if !self.cap.alive() {
            return self.brown_out();
        }
        // Harvest while the op runs (ops are ms-scale; chunk long ones).
        let mut remaining = duration;
        while remaining > 0.0 {
            let dt = remaining.min(self.charge_dt);
            self.harvest_into_buffer(self.now, dt);
            self.now += dt;
            remaining -= dt;
        }
        let ok = self.cap.discharge(energy);
        if !ok || !self.cap.alive() {
            return self.brown_out();
        }
        match ledger {
            Ledger::App => self.app_energy += energy,
            Ledger::State => self.state_energy += energy,
        }
        OpOutcome::Done
    }

    fn brown_out(&mut self) -> OpOutcome {
        self.failures += 1;
        // Physically the device dies crossing V_off; the residual charge
        // sits just below the threshold.
        self.cap.set_voltage(self.cap.v_off * 0.995);
        OpOutcome::BrownOut
    }

    /// Sleep in LPM3 for `secs` (harvesting continues, sleep current is
    /// drawn). Returns `false` if the device browned out while sleeping.
    ///
    /// Adaptive stride: when the buffer is comfortably above brown-out
    /// the integration step widens 5x — sleep draw is ~µW-scale, so the
    /// voltage cannot cross a threshold within one wide step, and the
    /// harvest integral only smooths over sub-step burst boundaries
    /// (see EXPERIMENTS.md §Perf).
    pub fn sleep(&mut self, secs: f64) -> bool {
        if self.powered {
            // Never sleep past the campaign horizon: the reported
            // duration must stop at `max_time`, exactly like the
            // harvesting branch below (which re-checks per chunk).
            self.now = (self.now + secs).min(self.max_time.max(self.now));
            return true;
        }
        let mut remaining = secs;
        let wide = self.charge_dt * 5.0;
        let safe_v = self.cap.v_off + 0.05;
        while remaining > 0.0 {
            if self.out_of_time() {
                return true; // horizon reached while alive
            }
            let dt = if self.cap.voltage() > safe_v {
                remaining.min(wide)
            } else {
                remaining.min(self.charge_dt)
            };
            self.harvest_into_buffer(self.now, dt);
            let ok = self.cap.discharge(self.mcu.sleep_energy(dt));
            self.now += dt;
            remaining -= dt;
            if !ok || !self.cap.alive() {
                self.brown_out();
                return false;
            }
        }
        true
    }

    /// Sleep until the next multiple of `period` strictly after `now`.
    pub fn sleep_until_next_slot(&mut self, period: f64) -> bool {
        let next = ((self.now / period).floor() + 1.0) * period;
        self.sleep(next - self.now)
    }

    /// The SMART policy's energy introspection: one ADC conversion, then
    /// read the usable budget. Returns `None` on brown-out during the read.
    pub fn read_budget(&mut self) -> Option<f64> {
        let cost = OpCost { adc_reads: 1, ..Default::default() };
        match self.run_op(&cost, Ledger::App) {
            OpOutcome::Done => Some(self.cap.usable_energy()),
            OpOutcome::BrownOut => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine_with(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    #[test]
    fn boots_when_charged() {
        let mut cfg = EngineConfig::paper_default(3600.0);
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Constant(2e-3));
        assert_eq!(e.cycles, 0);
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 1);
        assert!(e.cap.alive());
        assert!(e.now > 0.0);
    }

    #[test]
    fn never_boots_without_power() {
        let mut cfg = EngineConfig::paper_default(10.0);
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Constant(0.0));
        assert!(!e.charge_until_boot());
        assert!(e.out_of_time());
    }

    #[test]
    fn op_charges_energy_and_time() {
        let mut e = engine_with(0.0, 3600.0);
        let v0 = e.cap.voltage();
        let t0 = e.now;
        let out = e.run_op(&OpCost::cycles(8_000), Ledger::App);
        assert_eq!(out, OpOutcome::Done);
        assert!(e.cap.voltage() < v0);
        assert!((e.now - t0 - 1e-3).abs() < 1e-9); // 8k cycles @ 8 MHz = 1 ms
        assert!(e.app_energy > 0.0);
        assert_eq!(e.state_energy, 0.0);
    }

    #[test]
    fn big_op_browns_out_and_is_void() {
        let mut e = engine_with(0.0, 3600.0);
        // An op far beyond the buffer: ~1 J.
        let out = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App);
        assert_eq!(out, OpOutcome::BrownOut);
        assert_eq!(e.failures, 1);
        assert!(!e.cap.alive());
        assert!(!e.cap.can_boot());
        // Void: nothing billed.
        assert_eq!(e.app_energy, 0.0);
    }

    #[test]
    fn state_ledger_separated() {
        let mut e = engine_with(0.0, 3600.0);
        let cost = OpCost { fram_writes: 100, cycles: 200, ..Default::default() };
        assert_eq!(e.run_op(&cost, Ledger::State), OpOutcome::Done);
        assert!(e.state_energy > 0.0);
        assert_eq!(e.app_energy, 0.0);
    }

    #[test]
    fn sleep_discharges_slowly_but_can_kill() {
        let mut e = engine_with(0.0, 1e7);
        assert!(e.sleep(60.0)); // 84 µJ of sleep: fine
        // Hours of sleep with zero harvest eventually browns out.
        let alive = e.sleep(4.0 * 3600.0);
        assert!(!alive);
        assert_eq!(e.failures, 1);
    }

    #[test]
    fn harvesting_during_sleep_sustains() {
        let mut e = engine_with(1e-3, 1e5);
        assert!(e.sleep(3600.0));
        assert!(e.cap.alive());
    }

    #[test]
    fn slot_alignment() {
        let mut e = engine_with(2e-3, 1e5);
        e.now = 61.0;
        assert!(e.sleep_until_next_slot(60.0));
        assert!((e.now - 120.0).abs() < 0.05, "now={}", e.now);
    }

    #[test]
    fn budget_read_costs_one_adc() {
        let mut e = engine_with(0.0, 3600.0);
        let before = e.cap.usable_energy();
        let b = e.read_budget().unwrap();
        assert!(b < before);
        assert!(b > 0.0);
    }

    #[test]
    fn powered_engine_never_browns_out() {
        let mut e = Engine::powered(McuModel::paper_default(), 1e9);
        // An op that would kill any capacitor-backed device (~1 J).
        assert_eq!(e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App), OpOutcome::Done);
        assert_eq!(e.failures, 0);
        assert_eq!(e.cycles, 0);
        assert!(e.app_energy > 0.9);
        assert!(e.cap.alive());
        // Sleeping for hours is free of brown-out risk too.
        assert!(e.sleep(8.0 * 3600.0));
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 0, "a battery counts no boot events");
    }

    #[test]
    fn recovery_cycle_after_brownout() {
        let mut e = engine_with(2e-3, 1e6);
        let _ = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App);
        assert!(!e.cap.alive());
        assert!(e.charge_until_boot());
        assert_eq!(e.cycles, 2);
        assert!(e.cap.alive());
    }
}
