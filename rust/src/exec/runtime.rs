//! The runtime abstraction: one trait all four policies implement, and
//! the shared round driver that owns the campaign boilerplate.
//!
//! Before this module existed, the continuous / Chinchilla / approximate
//! executors were free functions with divergent signatures, and every
//! coordinator path re-dispatched over [`Policy`](crate::exec::Policy)
//! by hand. Now:
//!
//! * [`Runtime`] — `run(&self, &mut P, &mut Engine) -> Campaign` is the
//!   single entry point the coordinator calls, whatever the policy.
//! * [`RoundDriver`] — owns the per-campaign loop every policy shares:
//!   recharge-to-boot, input acquisition slots, round bookkeeping
//!   (sample ids, latency in power cycles, the sleep to the next slot)
//!   and the final [`Campaign`] assembly. Policies implement only
//!   [`RoundStrategy::round`], their per-sample strategy.
//! * [`RuntimeSpec`] — the workload-provided knobs
//!   ([`Policy::runtime`](crate::exec::Policy::runtime) turns a policy
//!   plus a spec into a boxed runtime).
//!
//! The continuous baseline participates through the engine's *powered*
//! mode (see [`Engine::powered`]): a battery is an energy-harvesting
//! device whose buffer never browns out, so the same driver and the same
//! ledgers apply and every figure keeps comparing like with like.

use crate::energy::estimator::SmartTable;
use crate::exec::engine::Engine;
use crate::exec::{Campaign, RoundResult, StepProgram};

/// A policy's executable form: drives `program` on `engine` until the
/// campaign horizon or the end of the input stream.
pub trait Runtime<P: StepProgram> {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output>;
}

/// What one acquired sample came to.
pub enum RoundOutcome<O> {
    /// The result reached the user.
    Emitted {
        /// Absolute time of the emission.
        emitted_at: f64,
        /// Steps actually executed for this sample.
        steps: usize,
        /// The application output.
        output: O,
    },
    /// The sample is recorded without an emission — lost to a brown-out
    /// or deliberately skipped. `steps` records the work executed before
    /// the drop (0 for a skip); `sleep` says whether the runtime waits
    /// for the next sampling slot (a deliberate skip) or goes straight
    /// back to recharging (a mid-round power failure).
    Dropped { steps: usize, sleep: bool },
    /// The campaign horizon expired mid-round: the partial round is not
    /// recorded and the campaign ends.
    Expired,
}

/// A malformed [`RoundOutcome`] the driver refused to account. Rather
/// than corrupting the ledgers (negative latencies, phantom steps), the
/// driver quarantines the round as unemitted and records the breach in
/// [`Campaign::violations`] — a structured error the correctness
/// harness and CI can assert on.
#[derive(Clone, Debug, PartialEq)]
pub enum DriverViolation {
    /// `Emitted { emitted_at }` earlier than the round's acquisition —
    /// a result cannot reach the user before its input exists.
    EmitBeforeAcquire { sample_id: u64, acquired_at: f64, emitted_at: f64 },
    /// The strategy claimed more executed steps than the program's
    /// accepted plan allows.
    StepsBeyondPlan { sample_id: u64, steps: usize, planned: usize },
}

/// The per-sample strategy a policy contributes to the shared driver.
pub trait RoundStrategy<P: StepProgram> {
    /// Drive one sample to an outcome. Called with the input already
    /// loaded ([`StepProgram::load_next`] succeeded) and the device
    /// alive; everything else — including surviving brown-outs — is the
    /// strategy's business.
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output>;
}

/// The campaign loop shared by every runtime.
pub struct RoundDriver {
    /// Seconds between sampling slots.
    pub sample_period: f64,
}

impl RoundDriver {
    pub fn new(sample_period: f64) -> RoundDriver {
        RoundDriver { sample_period }
    }

    /// Run the campaign: boot/recharge, acquire each slot's sample, hand
    /// it to the strategy, account the outcome, sleep to the next slot.
    pub fn drive<P, S>(
        &self,
        program: &mut P,
        engine: &mut Engine,
        strategy: &S,
    ) -> Campaign<P::Output>
    where
        P: StepProgram,
        S: RoundStrategy<P> + ?Sized,
    {
        // One slot per sample period bounds the round count; reserving
        // up front keeps the steady-state loop free of reallocation.
        // Capped so degenerate horizon/period ratios (perf benches use
        // 1e12-second horizons) cannot demand absurd reservations.
        let mut rounds: Vec<RoundResult<P::Output>> = Vec::new();
        if self.sample_period > 0.0 {
            let est = (engine.horizon() / self.sample_period).ceil() as usize + 2;
            rounds.reserve(est.min(1 << 16));
        }
        let mut violations: Vec<DriverViolation> = Vec::new();
        let mut sample_id = 0u64;
        while !engine.out_of_time() {
            if !engine.cap.alive() && !engine.charge_until_boot() {
                break;
            }
            if !program.load_next(engine.now) {
                break;
            }
            let acquired_at = engine.now;
            let acquired_cycle = engine.cycles;
            match strategy.round(program, engine) {
                RoundOutcome::Emitted { emitted_at, steps, output } => {
                    // Validate before accounting: a strategy bug must
                    // not corrupt the ledgers downstream metrics trust.
                    let planned = program.planned_steps();
                    let mut valid = true;
                    if emitted_at < acquired_at {
                        violations.push(DriverViolation::EmitBeforeAcquire {
                            sample_id,
                            acquired_at,
                            emitted_at,
                        });
                        valid = false;
                    }
                    if steps > planned {
                        violations.push(DriverViolation::StepsBeyondPlan {
                            sample_id,
                            steps,
                            planned,
                        });
                        valid = false;
                    }
                    rounds.push(RoundResult {
                        sample_id,
                        acquired_at,
                        emitted_at: valid.then_some(emitted_at),
                        latency_cycles: if valid { engine.cycles - acquired_cycle } else { 0 },
                        steps_executed: steps.min(planned),
                        output: valid.then_some(output),
                    });
                    sample_id += 1;
                    let _ = engine.sleep_until_next_slot(self.sample_period);
                }
                RoundOutcome::Dropped { steps, sleep } => {
                    let planned = program.planned_steps();
                    if steps > planned {
                        violations.push(DriverViolation::StepsBeyondPlan {
                            sample_id,
                            steps,
                            planned,
                        });
                    }
                    rounds.push(RoundResult {
                        sample_id,
                        acquired_at,
                        emitted_at: None,
                        latency_cycles: 0,
                        steps_executed: steps.min(planned),
                        output: None,
                    });
                    sample_id += 1;
                    if sleep {
                        let _ = engine.sleep_until_next_slot(self.sample_period);
                    }
                }
                RoundOutcome::Expired => break,
            }
        }
        Campaign {
            rounds,
            duration: engine.campaign_duration(),
            power_failures: engine.failures,
            power_cycles: engine.cycles,
            app_energy: engine.app_energy,
            state_energy: engine.state_energy,
            violations,
        }
    }
}

/// The workload-provided knobs a [`Policy`](crate::exec::Policy) needs to
/// instantiate its runtime.
#[derive(Clone, Debug, Default)]
pub struct RuntimeSpec {
    /// Seconds between sampling slots.
    pub sample_period: f64,
    /// SMART's offline lookup table; required only for `Policy::Smart`.
    pub smart_table: Option<SmartTable>,
}

impl RuntimeSpec {
    pub fn new(sample_period: f64) -> RuntimeSpec {
        RuntimeSpec { sample_period, smart_table: None }
    }

    pub fn with_smart_table(mut self, table: SmartTable) -> RuntimeSpec {
        self.smart_table = Some(table);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::Harvester;
    use crate::energy::mcu::McuModel;
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;
    use crate::exec::Policy;

    fn engine(power: f64, horizon: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(horizon), Harvester::Constant(power))
    }

    #[test]
    fn every_policy_constructs_and_runs_through_the_trait() {
        for policy in [
            Policy::Continuous,
            Policy::Chinchilla,
            Policy::Alpaca,
            Policy::Greedy,
        ] {
            let mut p = SyntheticProgram::new(5, 10, 10_000);
            let mut e = match policy {
                Policy::Continuous => Engine::powered(McuModel::paper_default(), 1200.0),
                _ => engine(2e-3, 1200.0),
            };
            let rt = policy.runtime::<SyntheticProgram>(&RuntimeSpec::new(60.0));
            let c = rt.run(&mut p, &mut e);
            assert!(
                c.emitted().count() > 0,
                "{} emitted nothing under abundant energy",
                policy.name()
            );
            assert!(c.rounds.len() as u64 <= 5, "{}", policy.name());
        }
    }

    #[test]
    fn driver_assigns_contiguous_sample_ids() {
        let mut p = SyntheticProgram::new(8, 5, 5_000);
        let mut e = engine(2e-3, 3600.0);
        let rt = Policy::Greedy.runtime::<SyntheticProgram>(&RuntimeSpec::new(60.0));
        let c = rt.run(&mut p, &mut e);
        for (i, r) in c.rounds.iter().enumerate() {
            assert_eq!(r.sample_id, i as u64);
        }
    }

    #[test]
    #[should_panic(expected = "smart_table")]
    fn smart_without_table_is_a_loud_error() {
        let _ = Policy::Smart { bound: 0.8 }.runtime::<SyntheticProgram>(&RuntimeSpec::new(60.0));
    }

    /// A strategy that lies to the driver: emissions dated before the
    /// acquisition and step counts beyond the plan.
    struct RogueStrategy;

    impl RoundStrategy<SyntheticProgram> for RogueStrategy {
        fn round(
            &self,
            program: &mut SyntheticProgram,
            engine: &mut Engine,
        ) -> RoundOutcome<usize> {
            use crate::exec::engine::Ledger;
            let _ = engine.run_op(&program.acquire_cost(), Ledger::App);
            RoundOutcome::Emitted {
                emitted_at: engine.now - 1e3,
                steps: program.planned_steps() + 5,
                output: 0,
            }
        }
    }

    #[test]
    fn driver_quarantines_malformed_outcomes() {
        let mut p = SyntheticProgram::new(3, 10, 1_000);
        let mut e = engine(2e-3, 600.0);
        let c = RoundDriver::new(60.0).drive(&mut p, &mut e, &RogueStrategy);
        assert_eq!(c.rounds.len(), 3);
        // No corrupt round reaches the ledgers: quarantined as unemitted,
        // steps clamped to the plan, zero latency.
        for r in &c.rounds {
            assert!(r.emitted_at.is_none());
            assert!(r.output.is_none());
            assert_eq!(r.latency_cycles, 0);
            assert!(r.steps_executed <= 10);
        }
        // Both breach kinds are surfaced, once per round.
        let before = c
            .violations
            .iter()
            .filter(|v| matches!(v, DriverViolation::EmitBeforeAcquire { .. }))
            .count();
        let beyond = c
            .violations
            .iter()
            .filter(|v| matches!(v, DriverViolation::StepsBeyondPlan { .. }))
            .count();
        assert_eq!((before, beyond), (3, 3), "{:?}", c.violations);
    }

    #[test]
    fn well_behaved_strategies_record_no_violations() {
        for policy in [Policy::Chinchilla, Policy::Alpaca, Policy::Greedy] {
            let mut p = SyntheticProgram::new(4, 10, 10_000);
            let mut e = engine(2e-3, 1200.0);
            let rt = policy.runtime::<SyntheticProgram>(&RuntimeSpec::new(60.0));
            let c = rt.run(&mut p, &mut e);
            assert!(c.violations.is_empty(), "{}: {:?}", policy.name(), c.violations);
        }
    }
}
