//! Intermittent execution: the step-program model, the discrete-event
//! device engine, the runtime abstraction, and the policies the paper
//! compares (plus the Alpaca task-based baseline).
//!
//! * [`program`] — [`program::StepProgram`]: a stateful computation as a
//!   sequence of atomic, energy-accounted steps with an approximation
//!   *plan* knob (anytime feature prefix for HAR, loop perforation for
//!   imaging).
//! * [`engine`] — the device simulator: capacitor + booster + harvester
//!   integration, brown-out, reboot, power-cycle accounting.
//! * [`runtime`] — the [`runtime::Runtime`] trait every policy
//!   implements, plus the shared [`runtime::RoundDriver`] that owns the
//!   boot/recharge/acquire/emit/bookkeeping loop; policies contribute
//!   only their per-round strategy.
//! * [`continuous`] — battery-powered baseline (the accuracy/throughput
//!   ceiling every figure normalises against).
//! * [`chinchilla`] — the regular-intermittent-computing baseline
//!   (checkpoints on FRAM with dynamic disabling, per Maeng & Lucia).
//! * [`alpaca`] — the second regular-intermittent baseline: task-based
//!   execution with privatization buffers instead of checkpoints, per
//!   Maeng, Colin & Lucia.
//! * [`approx`] — the paper's contribution: the GREEDY and SMART
//!   approximate-intermittent runtimes that finish (and emit) within the
//!   current power cycle, needing no persistent state at all.
//! * [`adaptive`] — the environment-learning extension: an EWMA energy
//!   predictor plus a UCB bandit over refinement depth that tunes the
//!   anytime knob online, persisting only a few words of learned state
//!   per power cycle (billed through the state ledger).
//! * [`faultplan`] / [`tracked`] — the correctness layer: deterministic
//!   power-failure injection over the engine's op ordinals, shadow
//!   access tracking, and the invariant checker (WAR freedom, replay
//!   idempotence, monotone commit, volatility discipline) every runtime
//!   is gated on. [`mutants`] holds the deliberately broken runtime
//!   variants the checker must flag (the mutation gate proving the
//!   harness has teeth).

pub mod adaptive;
pub mod alpaca;
pub mod approx;
pub mod chinchilla;
pub mod continuous;
pub mod engine;
pub mod faultplan;
pub mod mutants;
pub mod program;
pub mod runtime;
pub mod tracked;

pub use faultplan::FaultPlan;
pub use program::StepProgram;
pub use runtime::{
    DriverViolation, RoundDriver, RoundOutcome, RoundStrategy, Runtime, RuntimeSpec,
};
pub use tracked::{
    check_trace, run_checked, CheckedRun, Probe, RuntimeProfile, Trace, TrackedProgram, Violation,
};

/// Which runtime drives the device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// Battery-powered, never browns out; the normalisation ceiling.
    Continuous,
    /// Regular intermittent computing: checkpoints on FRAM (Chinchilla).
    Chinchilla,
    /// Regular intermittent computing, task-based: privatization buffers
    /// and task-granularity redo instead of checkpoints (Alpaca).
    Alpaca,
    /// Approximate intermittent computing, greedy: spend every joule on
    /// the current sample, always emit before dying.
    Greedy,
    /// Approximate intermittent computing with an accuracy lower bound:
    /// skip samples the current budget cannot classify at `bound`.
    Smart { bound: f64 },
    /// Environment-learning approximate intermittent computing: an EWMA
    /// harvest predictor (smoothing factor `alpha`) plus a UCB bandit
    /// (exploration weight `explore`) choose the refinement depth online.
    Adaptive { alpha: f64, explore: f64 },
}

impl Policy {
    /// Canonical policy name. The store's grid hash and every sink table
    /// key on this string, so `name()` ↔ [`FromStr`] must round-trip
    /// **losslessly**: `parse(name(p)) == p` for every representable
    /// parameter. Whole-percent SMART bounds keep the legacy `smartNN`
    /// spelling (so existing goldens, grid hashes and stored campaigns
    /// stay byte-identical); any other bound falls back to Rust's
    /// shortest-round-trip float formatting (`smart:0.8300000000000001`),
    /// which `FromStr` parses back to the identical bits.
    pub fn name(&self) -> String {
        match self {
            Policy::Continuous => "continuous".into(),
            Policy::Chinchilla => "chinchilla".into(),
            Policy::Alpaca => "alpaca".into(),
            Policy::Greedy => "greedy".into(),
            Policy::Smart { bound } => {
                let pct = (bound * 100.0).round();
                // The legacy spelling is exact only when the percent grid
                // reproduces the bound bit-for-bit (the parser computes
                // `pct / 100.0`, so compare against that same expression).
                if (0.0..=100.0).contains(&pct) && pct / 100.0 == *bound {
                    format!("smart{:02}", pct as u32)
                } else {
                    format!("smart:{bound}")
                }
            }
            Policy::Adaptive { alpha, explore } => {
                if *alpha == adaptive::DEFAULT_ALPHA && *explore == adaptive::DEFAULT_EXPLORE {
                    "adaptive".into()
                } else {
                    format!("adaptive:{alpha}:{explore}")
                }
            }
        }
    }

    /// Instantiate the runtime that executes this policy.
    ///
    /// The [`RuntimeSpec`] carries the workload-provided knobs: the
    /// sampling period for every policy, and the offline lookup table
    /// SMART and ADAPTIVE consult (panics if a `Smart` or `Adaptive`
    /// policy is constructed without one — that is a wiring bug, not a
    /// runtime condition).
    pub fn runtime<P: StepProgram>(&self, spec: &RuntimeSpec) -> Box<dyn Runtime<P>> {
        match *self {
            Policy::Continuous => {
                Box::new(continuous::ContinuousRuntime::new(spec.sample_period))
            }
            Policy::Chinchilla => Box::new(chinchilla::ChinchillaRuntime::new(
                chinchilla::ChinchillaConfig {
                    sample_period: spec.sample_period,
                    ..Default::default()
                },
            )),
            Policy::Alpaca => Box::new(alpaca::AlpacaRuntime::new(alpaca::AlpacaConfig {
                sample_period: spec.sample_period,
                ..Default::default()
            })),
            Policy::Greedy => Box::new(approx::ApproxRuntime::new(ApproxConfig::greedy(
                spec.sample_period,
            ))),
            Policy::Smart { bound } => {
                let table = spec
                    .smart_table
                    .clone()
                    .expect("Policy::Smart needs RuntimeSpec::smart_table");
                Box::new(approx::ApproxRuntime::new(ApproxConfig::smart(
                    spec.sample_period,
                    bound,
                    table,
                )))
            }
            Policy::Adaptive { alpha, explore } => {
                let table = spec
                    .smart_table
                    .clone()
                    .expect("Policy::Adaptive needs RuntimeSpec::smart_table");
                Box::new(adaptive::AdaptiveRuntime::new(adaptive::AdaptiveConfig::new(
                    spec.sample_period,
                    alpha,
                    explore,
                    table,
                )))
            }
        }
    }

    /// The invariant profile the correctness harness checks this
    /// policy's runtime against (see [`tracked::check_trace`]).
    pub fn profile(&self) -> RuntimeProfile {
        match self {
            Policy::Continuous => continuous::profile(),
            Policy::Chinchilla => chinchilla::profile(),
            Policy::Alpaca => alpaca::profile(),
            Policy::Greedy | Policy::Smart { .. } => approx::profile(),
            Policy::Adaptive { .. } => adaptive::profile(),
        }
    }
}

use approx::ApproxConfig;

impl std::str::FromStr for Policy {
    type Err = String;

    /// Parse a CLI policy name: `continuous`, `chinchilla`, `alpaca`,
    /// `greedy`, `smartNN` (`NN` = accuracy bound in percent, e.g.
    /// `smart60`, `smart80`), `smart:BOUND` (exact fractional bound,
    /// shortest-round-trip float), `adaptive` (default learning knobs),
    /// or `adaptive:ALPHA:EXPLORE`. Unknown names are an error — no
    /// silent fallback.
    fn from_str(s: &str) -> Result<Policy, String> {
        let err = || {
            format!(
                "unknown policy '{s}' (expected greedy|smartNN|smart:BOUND|\
                 adaptive[:ALPHA:EXPLORE]|chinchilla|alpaca|continuous)"
            )
        };
        match s {
            "continuous" => return Ok(Policy::Continuous),
            "chinchilla" => return Ok(Policy::Chinchilla),
            "alpaca" => return Ok(Policy::Alpaca),
            "greedy" => return Ok(Policy::Greedy),
            "adaptive" => {
                return Ok(Policy::Adaptive {
                    alpha: adaptive::DEFAULT_ALPHA,
                    explore: adaptive::DEFAULT_EXPLORE,
                })
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("adaptive:") {
            let (a, e) = rest.split_once(':').ok_or_else(err)?;
            let alpha: f64 = a.parse().map_err(|_| err())?;
            let explore: f64 = e.parse().map_err(|_| err())?;
            if alpha.is_finite()
                && alpha > 0.0
                && alpha <= 1.0
                && explore.is_finite()
                && explore >= 0.0
            {
                return Ok(Policy::Adaptive { alpha, explore });
            }
            return Err(err());
        }
        if let Some(rest) = s.strip_prefix("smart:") {
            let bound: f64 = rest.parse().map_err(|_| err())?;
            if bound.is_finite() && (0.0..=1.0).contains(&bound) {
                return Ok(Policy::Smart { bound });
            }
            return Err(err());
        }
        s.strip_prefix("smart")
            .and_then(|pct| pct.parse::<u32>().ok())
            .filter(|&pct| pct <= 100)
            .map(|pct| Policy::Smart { bound: pct as f64 / 100.0 })
            .ok_or_else(err)
    }
}

/// One emitted (or skipped/lost) application round.
#[derive(Clone, Debug)]
pub struct RoundResult<O> {
    /// Input (sample) ordinal within the campaign.
    pub sample_id: u64,
    /// Absolute time the sensor window was acquired.
    pub acquired_at: f64,
    /// Absolute time the result reached the user (BLE), if it did.
    pub emitted_at: Option<f64>,
    /// Power cycles between acquisition and emission (0 = same cycle).
    pub latency_cycles: u64,
    /// Steps actually executed for this sample (features / iterations).
    pub steps_executed: usize,
    /// The application output, if emitted.
    pub output: Option<O>,
}

/// Outcome of a whole campaign on one device.
#[derive(Clone, Debug)]
pub struct Campaign<O> {
    /// Emitted results (and, for SMART, skipped samples with `output: None`).
    pub rounds: Vec<RoundResult<O>>,
    /// Total simulated wall-clock time, seconds.
    pub duration: f64,
    /// Power failures experienced.
    pub power_failures: u64,
    /// Reboots (power cycles) experienced.
    pub power_cycles: u64,
    /// Joules spent on application processing (steps + emit + sensing).
    pub app_energy: f64,
    /// Joules spent on state management (checkpoint/restore/WAR on NVM).
    pub state_energy: f64,
    /// Malformed strategy outcomes the driver refused to account
    /// (empty for every well-behaved runtime; see [`DriverViolation`]).
    pub violations: Vec<DriverViolation>,
}

impl<O> Campaign<O> {
    /// Results actually delivered to the user.
    pub fn emitted(&self) -> impl Iterator<Item = &RoundResult<O>> {
        self.rounds.iter().filter(|r| r.emitted_at.is_some())
    }

    /// Throughput: results delivered per second of campaign time.
    pub fn throughput(&self) -> f64 {
        if self.duration == 0.0 {
            return 0.0;
        }
        self.emitted().count() as f64 / self.duration
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_round_trip_through_from_str() {
        for policy in [
            Policy::Continuous,
            Policy::Chinchilla,
            Policy::Alpaca,
            Policy::Greedy,
            Policy::Smart { bound: 0.60 },
            Policy::Smart { bound: 0.80 },
            Policy::Adaptive {
                alpha: adaptive::DEFAULT_ALPHA,
                explore: adaptive::DEFAULT_EXPLORE,
            },
            Policy::Adaptive { alpha: 0.25, explore: 1.5 },
        ] {
            let parsed: Policy = policy.name().parse().expect("round trip");
            assert_eq!(parsed, policy, "{}", policy.name());
        }
    }

    #[test]
    fn smart_bounds_round_trip_losslessly() {
        // The store's grid hash and the sink tables key on name(), so a
        // lossy round-trip silently forks resumed campaigns. Exercise the
        // full legacy percent grid plus bounds the grid cannot represent
        // (the issue's 0.8300000000000001 is the double right above 0.83).
        let mut bounds: Vec<f64> = (0..=100).map(|i| i as f64 / 100.0).collect();
        bounds.extend([
            0.8300000000000001,
            0.835,
            1.0 / 3.0,
            0.605,
            f64::EPSILON,
            1.0 - f64::EPSILON,
        ]);
        for bound in bounds {
            let p = Policy::Smart { bound };
            let name = p.name();
            let parsed: Policy = name.parse().unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(parsed, p, "bound {bound:?} via '{name}'");
        }
        // Whole percents keep the legacy spelling: goldens and stored
        // grid hashes must not change under the lossless fallback.
        assert_eq!(Policy::Smart { bound: 0.60 }.name(), "smart60");
        assert_eq!(Policy::Smart { bound: 0.80 }.name(), "smart80");
        assert_eq!(Policy::Smart { bound: 0.05 }.name(), "smart05");
        assert_eq!(
            Policy::Smart { bound: 0.8300000000000001 }.name(),
            "smart:0.8300000000000001"
        );
        // Adaptive knobs ride the same shortest-round-trip formatting.
        for (alpha, explore) in [(0.3, 0.7), (0.1 + 0.2, 1.0 / 7.0), (1.0, 0.0)] {
            let p = Policy::Adaptive { alpha, explore };
            let parsed: Policy = p.name().parse().unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(parsed, p, "{}", p.name());
        }
    }

    #[test]
    fn unknown_policy_is_an_error_not_a_fallback() {
        assert!("gredy".parse::<Policy>().is_err());
        assert!("".parse::<Policy>().is_err());
        assert!("smartly".parse::<Policy>().is_err());
        assert!("smart999".parse::<Policy>().is_err());
        // Malformed parametrised spellings are hard errors too.
        assert!("smart:".parse::<Policy>().is_err());
        assert!("smart:1.5".parse::<Policy>().is_err());
        assert!("smart:-0.1".parse::<Policy>().is_err());
        assert!("smart:nan".parse::<Policy>().is_err());
        assert!("adaptive:".parse::<Policy>().is_err());
        assert!("adaptive:0.5".parse::<Policy>().is_err());
        assert!("adaptive:0:1".parse::<Policy>().is_err());
        assert!("adaptive:0.5:-1".parse::<Policy>().is_err());
        assert!("adaptive:inf:1".parse::<Policy>().is_err());
    }
}
