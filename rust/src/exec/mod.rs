//! Intermittent execution: the step-program model, the discrete-event
//! device engine, the runtime abstraction, and the policies the paper
//! compares (plus the Alpaca task-based baseline).
//!
//! * [`program`] — [`program::StepProgram`]: a stateful computation as a
//!   sequence of atomic, energy-accounted steps with an approximation
//!   *plan* knob (anytime feature prefix for HAR, loop perforation for
//!   imaging).
//! * [`engine`] — the device simulator: capacitor + booster + harvester
//!   integration, brown-out, reboot, power-cycle accounting.
//! * [`runtime`] — the [`runtime::Runtime`] trait every policy
//!   implements, plus the shared [`runtime::RoundDriver`] that owns the
//!   boot/recharge/acquire/emit/bookkeeping loop; policies contribute
//!   only their per-round strategy.
//! * [`continuous`] — battery-powered baseline (the accuracy/throughput
//!   ceiling every figure normalises against).
//! * [`chinchilla`] — the regular-intermittent-computing baseline
//!   (checkpoints on FRAM with dynamic disabling, per Maeng & Lucia).
//! * [`alpaca`] — the second regular-intermittent baseline: task-based
//!   execution with privatization buffers instead of checkpoints, per
//!   Maeng, Colin & Lucia.
//! * [`approx`] — the paper's contribution: the GREEDY and SMART
//!   approximate-intermittent runtimes that finish (and emit) within the
//!   current power cycle, needing no persistent state at all.
//! * [`faultplan`] / [`tracked`] — the correctness layer: deterministic
//!   power-failure injection over the engine's op ordinals, shadow
//!   access tracking, and the invariant checker (WAR freedom, replay
//!   idempotence, monotone commit, volatility discipline) every runtime
//!   is gated on. [`mutants`] holds the deliberately broken runtime
//!   variants the checker must flag (the mutation gate proving the
//!   harness has teeth).

pub mod alpaca;
pub mod approx;
pub mod chinchilla;
pub mod continuous;
pub mod engine;
pub mod faultplan;
pub mod mutants;
pub mod program;
pub mod runtime;
pub mod tracked;

pub use faultplan::FaultPlan;
pub use program::StepProgram;
pub use runtime::{
    DriverViolation, RoundDriver, RoundOutcome, RoundStrategy, Runtime, RuntimeSpec,
};
pub use tracked::{
    check_trace, run_checked, CheckedRun, Probe, RuntimeProfile, Trace, TrackedProgram, Violation,
};

/// Which runtime drives the device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// Battery-powered, never browns out; the normalisation ceiling.
    Continuous,
    /// Regular intermittent computing: checkpoints on FRAM (Chinchilla).
    Chinchilla,
    /// Regular intermittent computing, task-based: privatization buffers
    /// and task-granularity redo instead of checkpoints (Alpaca).
    Alpaca,
    /// Approximate intermittent computing, greedy: spend every joule on
    /// the current sample, always emit before dying.
    Greedy,
    /// Approximate intermittent computing with an accuracy lower bound:
    /// skip samples the current budget cannot classify at `bound`.
    Smart { bound: f64 },
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Continuous => "continuous".into(),
            Policy::Chinchilla => "chinchilla".into(),
            Policy::Alpaca => "alpaca".into(),
            Policy::Greedy => "greedy".into(),
            Policy::Smart { bound } => format!("smart{:02}", (bound * 100.0).round() as u32),
        }
    }

    /// Instantiate the runtime that executes this policy.
    ///
    /// The [`RuntimeSpec`] carries the workload-provided knobs: the
    /// sampling period for every policy, and the offline lookup table
    /// SMART consults (panics if a `Smart` policy is constructed without
    /// one — that is a wiring bug, not a runtime condition).
    pub fn runtime<P: StepProgram>(&self, spec: &RuntimeSpec) -> Box<dyn Runtime<P>> {
        match *self {
            Policy::Continuous => {
                Box::new(continuous::ContinuousRuntime::new(spec.sample_period))
            }
            Policy::Chinchilla => Box::new(chinchilla::ChinchillaRuntime::new(
                chinchilla::ChinchillaConfig {
                    sample_period: spec.sample_period,
                    ..Default::default()
                },
            )),
            Policy::Alpaca => Box::new(alpaca::AlpacaRuntime::new(alpaca::AlpacaConfig {
                sample_period: spec.sample_period,
                ..Default::default()
            })),
            Policy::Greedy => Box::new(approx::ApproxRuntime::new(ApproxConfig::greedy(
                spec.sample_period,
            ))),
            Policy::Smart { bound } => {
                let table = spec
                    .smart_table
                    .clone()
                    .expect("Policy::Smart needs RuntimeSpec::smart_table");
                Box::new(approx::ApproxRuntime::new(ApproxConfig::smart(
                    spec.sample_period,
                    bound,
                    table,
                )))
            }
        }
    }

    /// The invariant profile the correctness harness checks this
    /// policy's runtime against (see [`tracked::check_trace`]).
    pub fn profile(&self) -> RuntimeProfile {
        match self {
            Policy::Continuous => continuous::profile(),
            Policy::Chinchilla => chinchilla::profile(),
            Policy::Alpaca => alpaca::profile(),
            Policy::Greedy | Policy::Smart { .. } => approx::profile(),
        }
    }
}

use approx::ApproxConfig;

impl std::str::FromStr for Policy {
    type Err = String;

    /// Parse a CLI policy name: `continuous`, `chinchilla`, `alpaca`,
    /// `greedy`, or `smartNN` (`NN` = accuracy bound in percent, e.g.
    /// `smart60`, `smart80`). Unknown names are an error — no silent
    /// fallback.
    fn from_str(s: &str) -> Result<Policy, String> {
        match s {
            "continuous" => Ok(Policy::Continuous),
            "chinchilla" => Ok(Policy::Chinchilla),
            "alpaca" => Ok(Policy::Alpaca),
            "greedy" => Ok(Policy::Greedy),
            _ => s
                .strip_prefix("smart")
                .and_then(|pct| pct.parse::<u32>().ok())
                .filter(|&pct| pct <= 100)
                .map(|pct| Policy::Smart { bound: pct as f64 / 100.0 })
                .ok_or_else(|| {
                    format!(
                        "unknown policy '{s}' \
                         (expected greedy|smartNN|chinchilla|alpaca|continuous)"
                    )
                }),
        }
    }
}

/// One emitted (or skipped/lost) application round.
#[derive(Clone, Debug)]
pub struct RoundResult<O> {
    /// Input (sample) ordinal within the campaign.
    pub sample_id: u64,
    /// Absolute time the sensor window was acquired.
    pub acquired_at: f64,
    /// Absolute time the result reached the user (BLE), if it did.
    pub emitted_at: Option<f64>,
    /// Power cycles between acquisition and emission (0 = same cycle).
    pub latency_cycles: u64,
    /// Steps actually executed for this sample (features / iterations).
    pub steps_executed: usize,
    /// The application output, if emitted.
    pub output: Option<O>,
}

/// Outcome of a whole campaign on one device.
#[derive(Clone, Debug)]
pub struct Campaign<O> {
    /// Emitted results (and, for SMART, skipped samples with `output: None`).
    pub rounds: Vec<RoundResult<O>>,
    /// Total simulated wall-clock time, seconds.
    pub duration: f64,
    /// Power failures experienced.
    pub power_failures: u64,
    /// Reboots (power cycles) experienced.
    pub power_cycles: u64,
    /// Joules spent on application processing (steps + emit + sensing).
    pub app_energy: f64,
    /// Joules spent on state management (checkpoint/restore/WAR on NVM).
    pub state_energy: f64,
    /// Malformed strategy outcomes the driver refused to account
    /// (empty for every well-behaved runtime; see [`DriverViolation`]).
    pub violations: Vec<DriverViolation>,
}

impl<O> Campaign<O> {
    /// Results actually delivered to the user.
    pub fn emitted(&self) -> impl Iterator<Item = &RoundResult<O>> {
        self.rounds.iter().filter(|r| r.emitted_at.is_some())
    }

    /// Throughput: results delivered per second of campaign time.
    pub fn throughput(&self) -> f64 {
        if self.duration == 0.0 {
            return 0.0;
        }
        self.emitted().count() as f64 / self.duration
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_round_trip_through_from_str() {
        for policy in [
            Policy::Continuous,
            Policy::Chinchilla,
            Policy::Alpaca,
            Policy::Greedy,
            Policy::Smart { bound: 0.60 },
            Policy::Smart { bound: 0.80 },
        ] {
            let parsed: Policy = policy.name().parse().expect("round trip");
            assert_eq!(parsed, policy, "{}", policy.name());
        }
    }

    #[test]
    fn unknown_policy_is_an_error_not_a_fallback() {
        assert!("gredy".parse::<Policy>().is_err());
        assert!("".parse::<Policy>().is_err());
        assert!("smartly".parse::<Policy>().is_err());
        assert!("smart999".parse::<Policy>().is_err());
    }
}
