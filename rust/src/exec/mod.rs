//! Intermittent execution: the step-program model, the discrete-event
//! device engine, and the four runtimes the paper compares.
//!
//! * [`program`] — [`program::StepProgram`]: a stateful computation as a
//!   sequence of atomic, energy-accounted steps with an approximation
//!   *plan* knob (anytime feature prefix for HAR, loop perforation for
//!   imaging).
//! * [`engine`] — the device simulator: capacitor + booster + harvester
//!   integration, brown-out, reboot, power-cycle accounting.
//! * [`continuous`] — battery-powered baseline (the accuracy/throughput
//!   ceiling every figure normalises against).
//! * [`chinchilla`] — the regular-intermittent-computing baseline
//!   (checkpoints on FRAM with dynamic disabling, per Maeng & Lucia).
//! * [`approx`] — the paper's contribution: the GREEDY and SMART
//!   approximate-intermittent runtimes that finish (and emit) within the
//!   current power cycle, needing no persistent state at all.

pub mod approx;
pub mod chinchilla;
pub mod continuous;
pub mod engine;
pub mod program;

pub use program::StepProgram;

/// Which runtime drives the device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// Battery-powered, never browns out; the normalisation ceiling.
    Continuous,
    /// Regular intermittent computing: checkpoints on FRAM (Chinchilla).
    Chinchilla,
    /// Approximate intermittent computing, greedy: spend every joule on
    /// the current sample, always emit before dying.
    Greedy,
    /// Approximate intermittent computing with an accuracy lower bound:
    /// skip samples the current budget cannot classify at `bound`.
    Smart { bound: f64 },
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Continuous => "continuous".into(),
            Policy::Chinchilla => "chinchilla".into(),
            Policy::Greedy => "greedy".into(),
            Policy::Smart { bound } => format!("smart{:02}", (bound * 100.0).round() as u32),
        }
    }
}

/// One emitted (or skipped/lost) application round.
#[derive(Clone, Debug)]
pub struct RoundResult<O> {
    /// Input (sample) ordinal within the campaign.
    pub sample_id: u64,
    /// Absolute time the sensor window was acquired.
    pub acquired_at: f64,
    /// Absolute time the result reached the user (BLE), if it did.
    pub emitted_at: Option<f64>,
    /// Power cycles between acquisition and emission (0 = same cycle).
    pub latency_cycles: u64,
    /// Steps actually executed for this sample (features / iterations).
    pub steps_executed: usize,
    /// The application output, if emitted.
    pub output: Option<O>,
}

/// Outcome of a whole campaign on one device.
#[derive(Clone, Debug)]
pub struct Campaign<O> {
    /// Emitted results (and, for SMART, skipped samples with `output: None`).
    pub rounds: Vec<RoundResult<O>>,
    /// Total simulated wall-clock time, seconds.
    pub duration: f64,
    /// Power failures experienced.
    pub power_failures: u64,
    /// Reboots (power cycles) experienced.
    pub power_cycles: u64,
    /// Joules spent on application processing (steps + emit + sensing).
    pub app_energy: f64,
    /// Joules spent on state management (checkpoint/restore/WAR on NVM).
    pub state_energy: f64,
}

impl<O> Campaign<O> {
    /// Results actually delivered to the user.
    pub fn emitted(&self) -> impl Iterator<Item = &RoundResult<O>> {
        self.rounds.iter().filter(|r| r.emitted_at.is_some())
    }

    /// Throughput: results delivered per second of campaign time.
    pub fn throughput(&self) -> f64 {
        if self.duration == 0.0 {
            return 0.0;
        }
        self.emitted().count() as f64 / self.duration
    }
}
