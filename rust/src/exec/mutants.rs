//! Deliberately broken runtime variants — the mutation gate.
//!
//! A correctness harness that never fires is indistinguishable from one
//! that cannot fire. Each runtime here reproduces a real intermittent-
//! computing bug class from the literature (WAR hazards, premature
//! commit, non-idempotent output) by taking a shipping runtime's
//! structure and removing exactly one protection. The fault-injection
//! suite (`tests/fault_injection.rs`) gates every change on the checker
//! flagging each mutant with its expected [`Violation`] kind while the
//! shipping runtimes stay clean under the very same fault schedules.
//!
//! | mutant | removed protection | expected violation |
//! |---|---|---|
//! | [`NoWarChinchillaRuntime`] | WAR versioning write before each step | `unversioned-war-write` |
//! | [`EarlyCommitAlpacaRuntime`] | commit *after* the task's write-back | `replay-beyond-commit` |
//! | [`EmitBeforeCommitRuntime`] | commit *before* the emission | `double-emit` |
//! | [`PersistentGreedyRuntime`] | "no persistent state" discipline | `stateful-volatile-runtime` |
//!
//! The first and last misbehave on every round — no fault needed; the
//! middle two are only wrong *under power failure*, which is exactly
//! what makes them good mutants: they prove the harness catches bugs
//! that are invisible in fault-free runs.

use crate::energy::mcu::OpCost;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::{Campaign, StepProgram};

/// Reboot recovery shared by the persistent mutants: pay the restore
/// cost, then rebuild program state by replaying the prefix the runtime
/// *believes* is committed (for the broken variants that belief is the
/// bug — the checker compares it against billed progress).
fn reenter<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    restore_cycles: u64,
    committed: usize,
) {
    let cost = OpCost {
        cycles: restore_cycles,
        fram_reads: program.state_words(committed),
        ..Default::default()
    };
    let _ = engine.run_op(&cost, Ledger::State);
    program.reset_round();
    for j in 0..committed {
        program.execute_step(j);
    }
}

/// Acquire the sensor window and persist it to FRAM, retrying across
/// power failures (the shared prologue of the persistent mutants).
/// Returns `false` when the campaign horizon expires first.
fn acquire_and_persist<P: StepProgram>(program: &mut P, engine: &mut Engine) -> bool {
    loop {
        if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::Done {
            let persist =
                OpCost { fram_writes: program.state_words(0), ..Default::default() };
            if engine.run_op(&persist, Ledger::State) == OpOutcome::Done {
                return true;
            }
        }
        program.reset_round();
        if !engine.charge_until_boot() {
            return false;
        }
    }
}

/// Chinchilla with the WAR versioning write removed: checkpoints are
/// taken, but non-idempotent steps run without persisting the words they
/// overwrite — after a reboot, replay re-reads already-overwritten
/// state (the classic intermittence anomaly). Expected violation:
/// `unversioned-war-write`, on every billed step with `war_words > 0`,
/// faults or no faults.
pub struct NoWarChinchillaRuntime {
    pub sample_period: f64,
}

impl<P: StepProgram> RoundStrategy<P> for NoWarChinchillaRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        program.plan(program.num_steps());
        if !acquire_and_persist(program, engine) {
            return RoundOutcome::Expired;
        }
        let total = program.planned_steps();
        let mut k = 0usize;
        let mut last_ckpt = 0usize;
        'process: loop {
            if k >= total {
                match engine.run_op(&program.emit_cost(), Ledger::App) {
                    OpOutcome::Done => {
                        return RoundOutcome::Emitted {
                            emitted_at: engine.now,
                            steps: total,
                            output: program.output(),
                        };
                    }
                    OpOutcome::BrownOut => {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        reenter(program, engine, 300, last_ckpt);
                        k = last_ckpt;
                        continue 'process;
                    }
                }
            }
            // Step k: application burst, then execution — with NO WAR
            // versioning write in between (the removed protection).
            match engine.run_op(&program.step_cost(k), Ledger::App) {
                OpOutcome::Done => {
                    program.execute_step(k);
                    k += 1;
                    // Checkpoint after every step (maximally conservative
                    // — the bug is isolated to the missing WAR write).
                    let ckpt = OpCost {
                        cycles: 400,
                        fram_writes: program.state_words(k),
                        ..Default::default()
                    };
                    if engine.run_op(&ckpt, Ledger::State) == OpOutcome::Done {
                        last_ckpt = k;
                    } else {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        reenter(program, engine, 300, last_ckpt);
                        k = last_ckpt;
                    }
                }
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 300, last_ckpt);
                    k = last_ckpt;
                }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for NoWarChinchillaRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.sample_period).drive(program, engine, self)
    }
}

/// Alpaca with the two-phase commit moved *before* the task body: the
/// runtime marks the task committed, then executes it. Fault-free runs
/// are indistinguishable from the real thing; a power failure inside a
/// task makes the reboot path "restore" work that was never done —
/// replaying a prefix longer than anything ever billed. Expected
/// violation: `replay-beyond-commit` (under fault injection).
pub struct EarlyCommitAlpacaRuntime {
    pub steps_per_task: usize,
    pub sample_period: f64,
}

impl<P: StepProgram> RoundStrategy<P> for EarlyCommitAlpacaRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        program.plan(program.num_steps());
        if !acquire_and_persist(program, engine) {
            return RoundOutcome::Expired;
        }
        let total = program.planned_steps();
        let mut committed = 0usize;
        let mut k = 0usize;
        'tasks: while committed < total {
            let task_end = (committed + self.steps_per_task.max(1)).min(total);
            // BUG: commit the task boundary before running its steps.
            let delta = program
                .state_words(task_end)
                .saturating_sub(program.state_words(committed))
                .max(1);
            let commit =
                OpCost { cycles: 300, fram_writes: delta, ..Default::default() };
            match engine.run_op(&commit, Ledger::State) {
                OpOutcome::Done => committed = task_end,
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 250, committed);
                    k = committed;
                    continue 'tasks;
                }
            }
            while k < task_end {
                if engine.run_op(&program.step_cost(k), Ledger::App) == OpOutcome::BrownOut {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    // `committed` already covers this unfinished task:
                    // the reboot replays steps that never ran.
                    reenter(program, engine, 250, committed);
                    k = committed;
                    continue 'tasks;
                }
                let war = program.war_words(k);
                if war > 0 {
                    let privatize = OpCost { fram_writes: war, ..Default::default() };
                    if engine.run_op(&privatize, Ledger::State) == OpOutcome::BrownOut {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        reenter(program, engine, 250, committed);
                        k = committed;
                        continue 'tasks;
                    }
                }
                program.execute_step(k);
                k += 1;
            }
        }
        loop {
            match engine.run_op(&program.emit_cost(), Ledger::App) {
                OpOutcome::Done => {
                    return RoundOutcome::Emitted {
                        emitted_at: engine.now,
                        steps: total,
                        output: program.output(),
                    };
                }
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 250, total);
                }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for EarlyCommitAlpacaRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.sample_period).drive(program, engine, self)
    }
}

/// A task runtime that emits the result *before* committing it: a fault
/// between the emission and the commit reboots into a state that does
/// not know the result left the device, so the whole round redoes — and
/// emits again. Fault-free runs look correct. Expected violation:
/// `double-emit` (under fault injection).
pub struct EmitBeforeCommitRuntime {
    pub sample_period: f64,
}

impl<P: StepProgram> RoundStrategy<P> for EmitBeforeCommitRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        program.plan(program.num_steps());
        if !acquire_and_persist(program, engine) {
            return RoundOutcome::Expired;
        }
        let total = program.planned_steps();
        let mut k = 0usize;
        'round: loop {
            while k < total {
                if engine.run_op(&program.step_cost(k), Ledger::App) == OpOutcome::BrownOut {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 250, 0);
                    k = 0;
                    continue 'round;
                }
                let war = program.war_words(k);
                if war > 0 {
                    let privatize = OpCost { fram_writes: war, ..Default::default() };
                    if engine.run_op(&privatize, Ledger::State) == OpOutcome::BrownOut {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        reenter(program, engine, 250, 0);
                        k = 0;
                        continue 'round;
                    }
                }
                program.execute_step(k);
                k += 1;
            }
            // BUG: the result leaves the device before the commit that
            // would make the emission durable knowledge.
            let emitted_at = match engine.run_op(&program.emit_cost(), Ledger::App) {
                OpOutcome::Done => engine.now,
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 250, 0);
                    k = 0;
                    continue 'round;
                }
            };
            let commit = OpCost {
                cycles: 300,
                fram_writes: program.state_words(total),
                ..Default::default()
            };
            match engine.run_op(&commit, Ledger::State) {
                OpOutcome::Done => {
                    return RoundOutcome::Emitted {
                        emitted_at,
                        steps: total,
                        output: program.output(),
                    };
                }
                OpOutcome::BrownOut => {
                    // The reboot forgot the emission: redo everything.
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    reenter(program, engine, 250, 0);
                    k = 0;
                }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for EmitBeforeCommitRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.sample_period).drive(program, engine, self)
    }
}

/// GREEDY with a per-step FRAM checkpoint bolted on — breaking the
/// paper's headline "no persistent state at all" guarantee while still
/// completing every round within one power cycle. Expected violation:
/// `stateful-volatile-runtime` (under the approx profile), on every
/// round, faults or no faults.
pub struct PersistentGreedyRuntime {
    pub sample_period: f64,
}

impl<P: StepProgram> RoundStrategy<P> for PersistentGreedyRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::BrownOut {
            return RoundOutcome::Dropped { steps: 0, sleep: false };
        }
        program.plan(program.num_steps());
        for j in 0..program.planned_steps() {
            if engine.run_op(&program.step_cost(j), Ledger::App) == OpOutcome::BrownOut {
                return RoundOutcome::Dropped { steps: j, sleep: false };
            }
            // BUG: persistent-state management in a runtime whose whole
            // point is that none exists.
            let ckpt = OpCost {
                fram_writes: program.state_words(j + 1),
                ..Default::default()
            };
            if engine.run_op(&ckpt, Ledger::State) == OpOutcome::BrownOut {
                return RoundOutcome::Dropped { steps: j, sleep: false };
            }
            program.execute_step(j);
        }
        match engine.run_op(&program.emit_cost(), Ledger::App) {
            OpOutcome::Done => RoundOutcome::Emitted {
                emitted_at: engine.now,
                steps: program.planned_steps(),
                output: program.output(),
            },
            OpOutcome::BrownOut => {
                RoundOutcome::Dropped { steps: program.planned_steps(), sleep: true }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for PersistentGreedyRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.sample_period).drive(program, engine, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::Harvester;
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;
    use crate::exec::tracked::run_checked;
    use crate::exec::{alpaca, approx, chinchilla, FaultPlan};

    fn engine(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    #[test]
    fn no_war_mutant_is_flagged_without_any_fault() {
        let run = run_checked(
            SyntheticProgram::new(2, 6, 10_000),
            engine(2e-3, 600.0),
            &NoWarChinchillaRuntime { sample_period: 60.0 },
            FaultPlan::None,
            &chinchilla::profile(),
        );
        assert!(
            run.violations.iter().any(|v| v.kind() == "unversioned-war-write"),
            "{:?}",
            run.violations
        );
    }

    #[test]
    fn persistent_greedy_mutant_is_flagged_without_any_fault() {
        let run = run_checked(
            SyntheticProgram::new(2, 6, 10_000),
            engine(2e-3, 600.0),
            &PersistentGreedyRuntime { sample_period: 60.0 },
            FaultPlan::None,
            &approx::profile(),
        );
        assert!(
            run.violations.iter().any(|v| v.kind() == "stateful-volatile-runtime"),
            "{:?}",
            run.violations
        );
    }

    #[test]
    fn fault_hidden_mutants_are_clean_without_faults() {
        // The early-commit and emit-before-commit bugs only manifest
        // under power failure — exactly what makes them good mutants.
        let early = run_checked(
            SyntheticProgram::new(2, 8, 10_000),
            engine(2e-3, 600.0),
            &EarlyCommitAlpacaRuntime { steps_per_task: 4, sample_period: 60.0 },
            FaultPlan::None,
            &alpaca::profile(),
        );
        assert!(early.violations.is_empty(), "{:?}", early.violations);
        let emitter = run_checked(
            SyntheticProgram::new(2, 8, 10_000),
            engine(2e-3, 600.0),
            &EmitBeforeCommitRuntime { sample_period: 60.0 },
            FaultPlan::None,
            &alpaca::profile(),
        );
        assert!(emitter.violations.is_empty(), "{:?}", emitter.violations);
    }
}
