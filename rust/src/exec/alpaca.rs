//! The Alpaca baseline: task-based intermittent computing without
//! checkpoints (Maeng, Colin & Lucia, OOPSLA'17).
//!
//! Instead of snapshotting volatile state, the program is decomposed into
//! *tasks* of a few steps each. A task reads task-shared variables that
//! live in FRAM, keeps its work in *privatization buffers* (redo-log
//! copies of every task-shared word it will overwrite), and at the task
//! boundary atomically *commits* the buffers back to the task-shared
//! state (a two-phase swap). A power failure therefore never corrupts
//! state: on reboot the runtime re-reads the committed task-shared
//! variables and re-executes the interrupted task from its start —
//! redo-at-task-granularity rather than restore-from-checkpoint.
//!
//! Compared with Chinchilla, Alpaca pays no checkpoint-sized FRAM bursts
//! (commits write only the task's delta) but re-executes more work per
//! failure (a whole task) and pays privatization writes on every
//! WAR-prone step. Like Chinchilla — and unlike the approximate
//! runtimes — it is always precise: results are emitted at maximum
//! accuracy, stretched across as many power cycles as the energy trace
//! dictates.

use crate::energy::mcu::OpCost;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::tracked::RuntimeProfile;
use crate::exec::{Campaign, StepProgram};

/// The invariant profile the correctness harness holds Alpaca to: tasks
/// redo across power cycles (replays must stay within the committed
/// prefix, monotone, idempotent) and persistent task-shared state is
/// managed — so every WAR-prone step must privatize before executing.
pub fn profile() -> RuntimeProfile {
    RuntimeProfile { name: "alpaca", replays: true, persists: true }
}

/// Alpaca tuning knobs.
#[derive(Clone, Debug)]
pub struct AlpacaConfig {
    /// Steps per task (the task decomposition granularity). Small tasks
    /// waste energy on commits; large tasks waste energy on re-execution
    /// after every failure.
    pub steps_per_task: usize,
    /// Fixed cycles per task commit (the two-phase pointer swap and
    /// bookkeeping before the FRAM burst).
    pub commit_cycles: u64,
    /// Fixed cycles to re-enter the interrupted task after a reboot
    /// (task dispatcher + reading the task-shared variables).
    pub restore_cycles: u64,
    /// Seconds between sampling slots.
    pub sample_period: f64,
}

impl Default for AlpacaConfig {
    fn default() -> AlpacaConfig {
        AlpacaConfig {
            steps_per_task: 8,
            commit_cycles: 300,
            restore_cycles: 250,
            sample_period: 60.0,
        }
    }
}

/// The Alpaca executor in [`Runtime`] form.
pub struct AlpacaRuntime {
    pub cfg: AlpacaConfig,
}

impl AlpacaRuntime {
    pub fn new(cfg: AlpacaConfig) -> AlpacaRuntime {
        AlpacaRuntime { cfg }
    }

    /// Reboot recovery: pay the dispatcher + task-shared reads, then
    /// rebuild the program state the committed FRAM variables encode by
    /// replaying the committed prefix (replay is free — the energy was
    /// billed when the commits were written).
    fn reenter<P: StepProgram>(&self, program: &mut P, engine: &mut Engine, committed: usize) {
        let cost = OpCost {
            cycles: self.cfg.restore_cycles,
            fram_reads: program.state_words(committed),
            ..Default::default()
        };
        let _ = engine.run_op(&cost, Ledger::State);
        program.reset_round();
        for j in 0..committed {
            program.execute_step(j);
        }
    }
}

impl<P: StepProgram> RoundStrategy<P> for AlpacaRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        let cfg = &self.cfg;
        program.plan(program.num_steps()); // Alpaca is always precise.

        // Acquire the sensor window; commit the raw input into the
        // task-shared FRAM state so the sample survives power failures.
        loop {
            if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::Done {
                let persist = OpCost {
                    cycles: cfg.commit_cycles,
                    fram_writes: program.state_words(0),
                    ..Default::default()
                };
                if engine.run_op(&persist, Ledger::State) == OpOutcome::Done {
                    break;
                }
            }
            // Brown-out during acquisition: window lost; reboot, retry
            // with a fresh window (the same logical sample).
            program.reset_round();
            if !engine.charge_until_boot() {
                return RoundOutcome::Expired;
            }
        }

        let total = program.planned_steps();
        let mut committed = 0usize; // first step of the current task
        let mut k = 0usize; // next step to run

        'tasks: while committed < total {
            let task_end = (committed + cfg.steps_per_task.max(1)).min(total);

            // Execute the task's steps, privatizing WAR-prone words as
            // redo-log copies in FRAM.
            while k < task_end {
                let step_cost = program.step_cost(k);
                if engine.run_op(&step_cost, Ledger::App) == OpOutcome::BrownOut {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    self.reenter(program, engine, committed);
                    k = committed;
                    continue 'tasks;
                }
                let war = program.war_words(k);
                if war > 0 {
                    let privatize = OpCost { fram_writes: war, ..Default::default() };
                    if engine.run_op(&privatize, Ledger::State) == OpOutcome::BrownOut {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        self.reenter(program, engine, committed);
                        k = committed;
                        continue 'tasks;
                    }
                }
                program.execute_step(k);
                k += 1;
            }

            // Two-phase commit: swap the privatization buffers into the
            // task-shared state. Only the task's delta is written — this
            // is Alpaca's edge over checkpoint-sized FRAM bursts.
            let delta = program
                .state_words(task_end)
                .saturating_sub(program.state_words(committed))
                .max(1);
            let commit = OpCost {
                cycles: cfg.commit_cycles,
                fram_writes: delta,
                ..Default::default()
            };
            match engine.run_op(&commit, Ledger::State) {
                OpOutcome::Done => committed = task_end,
                OpOutcome::BrownOut => {
                    // The swap did not happen: the task redoes entirely.
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    self.reenter(program, engine, committed);
                    k = committed;
                }
            }
        }

        // Emit; the result lives in task-shared FRAM, so retries survive
        // power failures by re-entering the (fully committed) state.
        loop {
            match engine.run_op(&program.emit_cost(), Ledger::App) {
                OpOutcome::Done => {
                    return RoundOutcome::Emitted {
                        emitted_at: engine.now,
                        steps: total,
                        output: program.output(),
                    };
                }
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    self.reenter(program, engine, total);
                }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for AlpacaRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.cfg.sample_period).drive(program, engine, self)
    }
}

/// Run the Alpaca baseline on the given engine until the campaign horizon
/// or the input stream ends. Thin wrapper over [`AlpacaRuntime`].
pub fn run<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    cfg: &AlpacaConfig,
) -> Campaign<P::Output> {
    AlpacaRuntime::new(cfg.clone()).run(program, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::Harvester;
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;

    fn engine(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    #[test]
    fn always_full_precision() {
        // 140 steps x 400k cycles ≈ 17 mJ ≫ the ~7 mJ usable buffer:
        // every sample needs several power cycles, yet outputs stay
        // precise.
        let mut p = SyntheticProgram::new(4, 140, 400_000);
        let mut e = engine(0.5e-3, 4.0 * 3600.0);
        let c = run(&mut p, &mut e, &AlpacaConfig::default());
        assert_eq!(c.rounds.len(), 4);
        assert!(c.rounds.iter().all(|r| r.emitted_at.is_some()));
        assert!(c.rounds.iter().all(|r| r.output == Some(140)));
        assert!(c.power_failures > 0, "should have browned out");
        // Commits + privatization cost real energy.
        assert!(c.state_energy > 0.0);
    }

    #[test]
    fn latency_spans_cycles_under_scarcity() {
        let mut p = SyntheticProgram::new(3, 140, 400_000);
        let mut e = engine(1.0e-3, 6.0 * 3600.0);
        let c = run(&mut p, &mut e, &AlpacaConfig::default());
        let max_latency = c.rounds.iter().map(|r| r.latency_cycles).max().unwrap_or(0);
        assert!(max_latency >= 1, "expected multi-cycle latency");
    }

    #[test]
    fn single_cycle_when_program_is_tiny() {
        let mut p = SyntheticProgram::new(3, 4, 1_000);
        let mut e = engine(2e-3, 3600.0);
        let c = run(&mut p, &mut e, &AlpacaConfig::default());
        assert_eq!(c.rounds.len(), 3);
        assert!(c.rounds.iter().all(|r| r.latency_cycles == 0));
    }

    #[test]
    fn commits_are_cheaper_than_chinchilla_checkpoints() {
        // Same program, same energy: Alpaca's delta-commits should bill
        // less to the state ledger than Chinchilla's cumulative-state
        // checkpoints on a program whose live state grows with progress.
        let horizon = 4.0 * 3600.0;
        let mut pa = SyntheticProgram::new(3, 140, 400_000);
        let mut ea = engine(0.5e-3, horizon);
        let alpaca = run(&mut pa, &mut ea, &AlpacaConfig::default());

        let mut pc = SyntheticProgram::new(3, 140, 400_000);
        let mut ec = engine(0.5e-3, horizon);
        let chin = crate::exec::chinchilla::run(
            &mut pc,
            &mut ec,
            &crate::exec::chinchilla::ChinchillaConfig::default(),
        );
        assert!(
            alpaca.state_energy < chin.state_energy,
            "alpaca {} >= chinchilla {}",
            alpaca.state_energy,
            chin.state_energy
        );
    }

    #[test]
    fn task_granularity_trades_commits_for_redo() {
        // One huge task commits once but redoes everything on failure;
        // with abundant energy (no failures) it must be the cheaper
        // state-ledger option.
        let mut p1 = SyntheticProgram::new(2, 40, 10_000);
        let mut e1 = engine(3e-3, 3600.0);
        let coarse = AlpacaConfig { steps_per_task: 40, ..Default::default() };
        let c1 = run(&mut p1, &mut e1, &coarse);

        let mut p2 = SyntheticProgram::new(2, 40, 10_000);
        let mut e2 = engine(3e-3, 3600.0);
        let fine = AlpacaConfig { steps_per_task: 1, ..Default::default() };
        let c2 = run(&mut p2, &mut e2, &fine);

        assert!(c1.power_failures == 0 && c2.power_failures == 0);
        assert!(
            c1.state_energy < c2.state_energy,
            "coarse {} >= fine {}",
            c1.state_energy,
            c2.state_energy
        );
    }
}
