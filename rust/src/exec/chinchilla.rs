//! The Chinchilla baseline: regular intermittent computing.
//!
//! Re-implementation of the adaptive-checkpointing runtime the paper uses
//! as its state-of-the-art baseline (Maeng & Lucia, OSDI'18): code is
//! overprovisioned with checkpoints (here: a potential checkpoint before
//! every step), and the runtime *dynamically disables* them — after every
//! interval that completes without a power failure the checkpoint spacing
//! doubles (up to a cap); a failure resets the spacing to one. Checkpoints
//! write the live state to FRAM; on reboot the state is restored and
//! execution resumes from the last checkpoint, re-executing the steps that
//! followed it. Non-idempotent steps additionally pay WAR versioning
//! writes (intermittence-anomaly protection).
//!
//! Exactly as in the paper, the result of a sample is emitted only when
//! *all* steps have run — maximum accuracy, at the cost of stretching one
//! sample across many power cycles (Figs. 6, 9, 15).

use crate::energy::mcu::OpCost;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::tracked::RuntimeProfile;
use crate::exec::{Campaign, StepProgram};

/// The invariant profile the correctness harness holds Chinchilla to: it
/// stretches rounds across power cycles by replaying from checkpoints
/// (replays must stay within billed progress, monotone, idempotent) and
/// manages persistent state — so every non-idempotent step must carry
/// its WAR versioning write before executing.
pub fn profile() -> RuntimeProfile {
    RuntimeProfile { name: "chinchilla", replays: true, persists: true }
}

/// Chinchilla tuning knobs.
#[derive(Clone, Debug)]
pub struct ChinchillaConfig {
    /// Fixed cycles per checkpoint (bookkeeping before the FRAM burst).
    pub checkpoint_cycles: u64,
    /// Fixed cycles per restore.
    pub restore_cycles: u64,
    /// Checkpoint spacing doubles up to `2^max_skip_exp` steps.
    pub max_skip_exp: u32,
    /// Seconds between sampling slots.
    pub sample_period: f64,
}

impl Default for ChinchillaConfig {
    fn default() -> ChinchillaConfig {
        ChinchillaConfig {
            checkpoint_cycles: 400,
            restore_cycles: 300,
            max_skip_exp: 5,
            sample_period: 60.0,
        }
    }
}

/// The Chinchilla baseline in [`Runtime`] form.
pub struct ChinchillaRuntime {
    pub cfg: ChinchillaConfig,
}

impl ChinchillaRuntime {
    pub fn new(cfg: ChinchillaConfig) -> ChinchillaRuntime {
        ChinchillaRuntime { cfg }
    }
}

impl<P: StepProgram> RoundStrategy<P> for ChinchillaRuntime {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        let cfg = &self.cfg;
        program.plan(program.num_steps()); // Chinchilla is always precise.

        // Acquire the sensor window; persist the raw input to FRAM so the
        // sample can survive power failures (state ledger).
        loop {
            if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::Done {
                let persist = OpCost {
                    fram_writes: program.state_words(0),
                    ..Default::default()
                };
                if engine.run_op(&persist, Ledger::State) == OpOutcome::Done {
                    break;
                }
            }
            // Brown-out during acquisition: window lost; reboot, retry
            // with a fresh window (counts as the same logical sample).
            program.reset_round();
            if !engine.charge_until_boot() {
                return RoundOutcome::Expired;
            }
        }

        // Process all steps with adaptive checkpointing.
        let total = program.planned_steps();
        let mut k = 0usize; // next step to run
        let mut last_ckpt = 0usize; // step index the FRAM state reflects
        let mut interval = 1u64; // steps between checkpoints
        let mut survived_in_interval = 0u64;

        'process: loop {
            if k >= total {
                // Emit; retries across failures (output state is coverable
                // by the last checkpoint, which for k == total we force).
                match engine.run_op(&program.emit_cost(), Ledger::App) {
                    OpOutcome::Done => {
                        return RoundOutcome::Emitted {
                            emitted_at: engine.now,
                            steps: total,
                            output: program.output(),
                        };
                    }
                    OpOutcome::BrownOut => {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        restore(program, engine, cfg, last_ckpt);
                        k = last_ckpt;
                        interval = 1;
                        survived_in_interval = 0;
                        continue 'process;
                    }
                }
            }

            // Checkpoint decision (overprovisioned before every step,
            // dynamically disabled by the adaptive interval).
            let due = (k - last_ckpt) as u64 >= interval || k == total - 1;
            if due && k > last_ckpt {
                let cost = OpCost {
                    cycles: cfg.checkpoint_cycles,
                    fram_writes: program.state_words(k),
                    ..Default::default()
                };
                match engine.run_op(&cost, Ledger::State) {
                    OpOutcome::Done => {
                        last_ckpt = k;
                        survived_in_interval += 1;
                        // Interval completed without failure: double it.
                        if survived_in_interval >= 2 {
                            interval = (interval * 2).min(1 << cfg.max_skip_exp);
                            survived_in_interval = 0;
                        }
                    }
                    OpOutcome::BrownOut => {
                        if !engine.charge_until_boot() {
                            return RoundOutcome::Expired;
                        }
                        restore(program, engine, cfg, last_ckpt);
                        k = last_ckpt;
                        interval = 1;
                        survived_in_interval = 0;
                        continue 'process;
                    }
                }
            }

            // Execute step k: application cost, plus WAR versioning on
            // FRAM for non-idempotent steps (anomaly protection).
            let step_cost = program.step_cost(k);
            match engine.run_op(&step_cost, Ledger::App) {
                OpOutcome::Done => {
                    let war = program.war_words(k);
                    if war > 0 {
                        let cost = OpCost { fram_writes: war, ..Default::default() };
                        if engine.run_op(&cost, Ledger::State) == OpOutcome::BrownOut {
                            if !engine.charge_until_boot() {
                                return RoundOutcome::Expired;
                            }
                            restore(program, engine, cfg, last_ckpt);
                            k = last_ckpt;
                            interval = 1;
                            survived_in_interval = 0;
                            continue 'process;
                        }
                    }
                    program.execute_step(k);
                    k += 1;
                }
                OpOutcome::BrownOut => {
                    if !engine.charge_until_boot() {
                        return RoundOutcome::Expired;
                    }
                    restore(program, engine, cfg, last_ckpt);
                    k = last_ckpt;
                    interval = 1;
                    survived_in_interval = 0;
                }
            }
        }
    }
}

impl<P: StepProgram> Runtime<P> for ChinchillaRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        RoundDriver::new(self.cfg.sample_period).drive(program, engine, self)
    }
}

/// Run the Chinchilla baseline on the given engine until the campaign
/// horizon or the input stream ends. Thin wrapper over
/// [`ChinchillaRuntime`].
pub fn run<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    cfg: &ChinchillaConfig,
) -> Campaign<P::Output> {
    ChinchillaRuntime::new(cfg.clone()).run(program, engine)
}

/// Pay the restore cost and rebuild program state to `last_ckpt` by
/// replaying steps (replay is free: it reconstructs the deterministic
/// state the FRAM image holds — the energy was billed when the
/// checkpoint was written).
fn restore<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    cfg: &ChinchillaConfig,
    last_ckpt: usize,
) {
    let cost = OpCost {
        cycles: cfg.restore_cycles,
        fram_reads: program.state_words(last_ckpt),
        ..Default::default()
    };
    // A brown-out during restore leads to another recharge + retry at the
    // caller; the restore cost is billed on success only.
    let _ = engine.run_op(&cost, Ledger::State);
    program.reset_round();
    for j in 0..last_ckpt {
        program.execute_step(j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::harvester::Harvester;
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;

    fn small_engine(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    #[test]
    fn completes_everything_with_plenty_of_power() {
        // 140 steps x 400k cycles ≈ 17 mJ ≫ buffer (7 mJ usable): at
        // 0.4 mW each sample needs several power cycles.
        let mut p = SyntheticProgram::new(5, 140, 400_000);
        let mut e = small_engine(0.4e-3, 3600.0 * 4.0);
        let c = run(&mut p, &mut e, &ChinchillaConfig::default());
        assert_eq!(c.rounds.len(), 5);
        assert!(c.rounds.iter().all(|r| r.emitted_at.is_some()));
        // Full precision always.
        assert!(c.rounds.iter().all(|r| r.output == Some(140)));
        // It must have browned out at least once per sample.
        assert!(c.power_failures >= 5, "failures={}", c.power_failures);
        // State management costs real energy.
        assert!(c.state_energy > 0.0);
    }

    #[test]
    fn latency_spans_multiple_cycles() {
        let mut p = SyntheticProgram::new(3, 140, 400_000);
        let mut e = small_engine(1.5e-3, 3600.0 * 6.0);
        let c = run(&mut p, &mut e, &ChinchillaConfig::default());
        let max_latency =
            c.rounds.iter().map(|r| r.latency_cycles).max().unwrap_or(0);
        assert!(max_latency >= 1, "expected multi-cycle latency");
    }

    #[test]
    fn single_cycle_when_program_is_tiny() {
        let mut p = SyntheticProgram::new(3, 4, 1_000);
        let mut e = small_engine(2e-3, 3600.0);
        let c = run(&mut p, &mut e, &ChinchillaConfig::default());
        assert_eq!(c.rounds.len(), 3);
        assert!(c.rounds.iter().all(|r| r.latency_cycles == 0));
    }

    #[test]
    fn forward_progress_under_harsh_energy() {
        // Weak, bursty power: still must eventually finish one sample.
        let mut p = SyntheticProgram::new(1, 60, 400_000);
        let trace = crate::energy::traces::generate(
            crate::energy::traces::TraceKind::Rf,
            3600.0 * 8.0,
            0.01,
            7,
        );
        let mut e = Engine::new(
            EngineConfig::paper_default(3600.0 * 8.0),
            Harvester::Replay(trace),
        );
        let c = run(&mut p, &mut e, &ChinchillaConfig::default());
        assert_eq!(c.rounds.len(), 1);
        assert!(c.rounds[0].emitted_at.is_some(), "no forward progress");
    }
}
