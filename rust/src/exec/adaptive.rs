//! ADAPTIVE — environment-learning approximate intermittent computing.
//!
//! GREEDY and SMART (see [`approx`](crate::exec::approx)) hand-set the
//! anytime knob: GREEDY spends whatever is in the capacitor, SMART holds
//! a fixed user accuracy bound. *Approxify* (PAPERS.md) argues the
//! energy-accuracy trade-off should instead be auto-tuned to the
//! deployment's actual energy envelope, and *Intermittent Learning*
//! shows constant-space online learning survives intermittent power when
//! its state is persisted as carefully as application state. ADAPTIVE is
//! that combination inside the paper's single-power-cycle discipline:
//!
//! * an [`EwmaPredictor`] learns the realised per-cycle budget and
//!   inter-boot gap, updated **once per power cycle** from the same ADC
//!   read SMART performs anyway;
//! * a deterministic UCB bandit chooses among a fixed menu of refinement
//!   depths ([`ARM_FRACTIONS`] of the pipeline: feature count for HAR,
//!   perforation level for imaging, probe tier for audio), rewarded by
//!   the emitted accuracy proxy discounted by the energy it burned
//!   (accuracy per joule, not accuracy at any price);
//! * the whole learned state is a **bounded, tiny record**
//!   ([`STATE_WORDS`] FRAM words — two packed EWMA estimates, four
//!   `(count, mean)` arm cells, a pending-arm marker) persisted through
//!   the energy ledger like any other state write, and restored (and
//!   billed) at the first round of every power cycle.
//!
//! The crash discipline is write-ahead: the chosen arm is persisted as
//! *pending* before any step runs. If the cycle dies mid-round, the next
//! boot finds the pending marker and charges the arm a zero reward — a
//! death certificate for the depth that overreached — so the bandit
//! learns survivable depths without ever replaying application work.
//! Application rounds remain strictly single-cycle (the PR 7 checker
//! profile is `replays: false, persists: true`).
//!
//! Everything here is allocation-free and RNG-free per round: arm
//! selection is argmax with deterministic tie-breaking, so adaptive
//! sweeps stay bitwise deterministic for any worker count.

use std::cell::RefCell;

use crate::energy::estimator::SmartTable;
use crate::energy::mcu::OpCost;
use crate::energy::predictor::EwmaPredictor;
use crate::exec::engine::{Engine, Ledger, OpOutcome};
use crate::exec::runtime::{RoundDriver, RoundOutcome, RoundStrategy, Runtime};
use crate::exec::tracked::RuntimeProfile;
use crate::exec::{Campaign, StepProgram};

/// Default EWMA smoothing factor (≈ the last five cycles dominate).
pub const DEFAULT_ALPHA: f64 = 0.2;
/// Default UCB exploration weight.
pub const DEFAULT_EXPLORE: f64 = 0.5;
/// The bandit's depth menu, as fractions of the full pipeline.
pub const ARM_FRACTIONS: [f64; 4] = [0.25, 0.5, 0.75, 1.0];
/// Energy discount in the reward: accuracy minus `λ ·
/// spent/full-pipeline-cost`. Small, so accuracy dominates and the
/// discount only breaks ties toward cheaper depths.
pub const REWARD_ENERGY_WEIGHT: f64 = 0.05;
/// 16-bit FRAM words of persisted learned state: the two EWMA estimates
/// packed as f32 (4 words), four arm cells as fixed-point mean + count
/// (8 words), pending-arm marker + play counter + stamps (4 words).
/// Constant and tiny by construction — the checker-visible bound on the
/// paper's "a few words of state" discipline.
pub const STATE_WORDS: u64 = 16;

/// The invariant profile the correctness harness holds ADAPTIVE to:
/// rounds never replay and never stretch across power cycles (the
/// paper's guarantee, same as GREEDY/SMART), but unlike them the runtime
/// *does* manage persistent state — the bounded learned record above —
/// so State-ledger operations are expected rather than violations.
pub fn profile() -> RuntimeProfile {
    RuntimeProfile { name: "adaptive", replays: false, persists: true }
}

/// Adaptive runtime configuration.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Seconds between sampling slots.
    pub sample_period: f64,
    /// Safety margin multiplier on planned (steps + emit + persist)
    /// energy, as in the approximate runtimes.
    pub margin: f64,
    /// EWMA smoothing factor for the environment predictor, `(0, 1]`.
    pub alpha: f64,
    /// UCB exploration weight, `>= 0` (0 = pure exploitation).
    pub explore: f64,
    /// The offline depth-cost/accuracy table (same artifact SMART uses).
    pub table: SmartTable,
}

impl AdaptiveConfig {
    pub fn new(sample_period: f64, alpha: f64, explore: f64, table: SmartTable) -> AdaptiveConfig {
        assert!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "adaptive alpha must be in (0, 1], got {alpha}"
        );
        assert!(
            explore.is_finite() && explore >= 0.0,
            "adaptive explore must be finite and >= 0, got {explore}"
        );
        AdaptiveConfig { sample_period, margin: 1.05, alpha, explore, table }
    }
}

/// One bandit arm's sufficient statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ArmStat {
    /// Times this arm completed (emitted or was charged a death).
    pub count: u64,
    /// Running mean reward.
    pub mean: f64,
}

/// The complete learned state — everything ADAPTIVE persists. `Copy`,
/// fixed-size, no heap: the in-memory image of the [`STATE_WORDS`] FRAM
/// record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LearnedState {
    /// Environment model (per-cycle energy + inter-boot gap EWMAs).
    pub predictor: EwmaPredictor,
    /// Bandit arms over [`ARM_FRACTIONS`].
    pub arms: [ArmStat; ARM_FRACTIONS.len()],
    /// Total completed plays across arms (UCB's `t`).
    pub plays: u64,
    /// Arm chosen by a round that has not yet completed. Persisted
    /// *before* the round's first step: if the cycle dies, the next boot
    /// finds it and charges the arm a zero reward.
    pub pending: Option<usize>,
    /// Engine power-cycle stamp of the last restore (volatile guard; a
    /// mismatch with `engine.cycles` means we rebooted since last round).
    pub seen_cycle: u64,
}

impl LearnedState {
    pub fn new(alpha: f64) -> LearnedState {
        LearnedState {
            predictor: EwmaPredictor::new(alpha),
            arms: [ArmStat::default(); ARM_FRACTIONS.len()],
            plays: 0,
            pending: None,
            seen_cycle: u64::MAX,
        }
    }

    /// Deterministic UCB1 arm selection: unplayed arms first in index
    /// order, then argmax of `mean + explore * sqrt(ln t / n_i)` with
    /// ties resolved to the lowest index. No RNG — bitwise reproducible.
    pub fn select_arm(&self, explore: f64) -> usize {
        if let Some(i) = self.arms.iter().position(|a| a.count == 0) {
            return i;
        }
        let ln_t = (self.plays.max(1) as f64).ln();
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (i, a) in self.arms.iter().enumerate() {
            let score = a.mean + explore * (ln_t / a.count as f64).sqrt();
            if score > best_score {
                best = i;
                best_score = score;
            }
        }
        best
    }

    /// Fold a completed play's reward into `arm`.
    pub fn reward(&mut self, arm: usize, r: f64) {
        let a = &mut self.arms[arm];
        a.count += 1;
        a.mean += (r - a.mean) / a.count as f64;
        self.plays += 1;
    }

    /// The depth (step count) `arm` asks for on an `total`-step pipeline.
    pub fn depth_of(arm: usize, total: usize) -> usize {
        ((ARM_FRACTIONS[arm] * total as f64).ceil() as usize).clamp(1, total.max(1))
    }
}

/// The ADAPTIVE executor in [`Runtime`] form.
pub struct AdaptiveRuntime {
    pub cfg: AdaptiveConfig,
}

impl AdaptiveRuntime {
    pub fn new(cfg: AdaptiveConfig) -> AdaptiveRuntime {
        AdaptiveRuntime { cfg }
    }
}

impl<P: StepProgram> Runtime<P> for AdaptiveRuntime {
    fn run(&self, program: &mut P, engine: &mut Engine) -> Campaign<P::Output> {
        // Fresh learned state per campaign: the runtime object stays
        // reusable and runs stay independent (and deterministic).
        let session = AdaptiveSession {
            cfg: &self.cfg,
            live: RefCell::new(LearnedState::new(self.cfg.alpha)),
            committed: RefCell::new(LearnedState::new(self.cfg.alpha)),
        };
        RoundDriver::new(self.cfg.sample_period).drive(program, engine, &session)
    }
}

/// Per-campaign strategy state. `live` is the volatile SRAM image;
/// `committed` mirrors what is on FRAM and is only updated by a
/// successful persist, so a brown-out anywhere leaves exactly the
/// last-persisted record to restore from.
struct AdaptiveSession<'a> {
    cfg: &'a AdaptiveConfig,
    live: RefCell<LearnedState>,
    committed: RefCell<LearnedState>,
}

impl AdaptiveSession<'_> {
    /// Write the learned record to FRAM (state ledger). On success the
    /// committed mirror catches up; on brown-out it stays behind and the
    /// next boot restores the older record — write-ahead semantics.
    fn persist(&self, engine: &mut Engine, live: &LearnedState) -> bool {
        let cost = OpCost { fram_writes: STATE_WORDS, ..Default::default() };
        match engine.run_op(&cost, Ledger::State) {
            OpOutcome::Done => {
                *self.committed.borrow_mut() = *live;
                true
            }
            OpOutcome::BrownOut => false,
        }
    }
}

impl<P: StepProgram> RoundStrategy<P> for AdaptiveSession<'_> {
    fn round(&self, program: &mut P, engine: &mut Engine) -> RoundOutcome<P::Output> {
        let cfg = self.cfg;
        let mut st = self.live.borrow_mut();

        // ------ Restore: first round of every power cycle -------------
        let fresh_cycle = st.seen_cycle != engine.cycles;
        if fresh_cycle {
            let restore = OpCost { fram_reads: STATE_WORDS, ..Default::default() };
            if engine.run_op(&restore, Ledger::State) == OpOutcome::BrownOut {
                return RoundOutcome::Dropped { steps: 0, sleep: false };
            }
            *st = *self.committed.borrow();
            if let Some(arm) = st.pending.take() {
                // A previous cycle chose this depth and died before
                // completing: charge the death. Persist immediately so a
                // crash loop cannot double-charge (restore + zero-reward
                // + persist is idempotent until the persist lands).
                st.reward(arm, 0.0);
                if !self.persist(engine, &st) {
                    return RoundOutcome::Dropped { steps: 0, sleep: false };
                }
            }
            st.seen_cycle = engine.cycles;
        }

        // ------ Acquire the sensor window -----------------------------
        if engine.run_op(&program.acquire_cost(), Ledger::App) == OpOutcome::BrownOut {
            return RoundOutcome::Dropped { steps: 0, sleep: false };
        }

        // ------ Introspect the budget (ADC), feed the predictor -------
        let budget = match engine.read_budget() {
            Some(b) => b,
            None => return RoundOutcome::Dropped { steps: 0, sleep: false },
        };
        if fresh_cycle {
            // Exactly one observation per power cycle: the realised
            // budget at this cycle's first sampling opportunity.
            st.predictor.observe(budget, engine.now);
        }

        // ------ Plan: clamp the bandit's ask to what is affordable ----
        let table = &cfg.table;
        let total = program.num_steps().min(table.cumulative_energy.len().saturating_sub(1));
        let emit_energy = engine.mcu.energy(&program.emit_cost());
        let persist_energy =
            engine.mcu.energy(&OpCost { fram_writes: STATE_WORDS, ..Default::default() });
        // Plan against the *pessimistic* of the live reading and the
        // learned envelope: a transiently full capacitor in a lean
        // environment should not bait a depth the next cycles cannot
        // sustain.
        let planning_budget = budget.min(st.predictor.energy_or(budget));
        // Largest depth whose steps + emission + the round's two persists
        // fit the planning budget with margin. `cumulative_energy` is
        // non-decreasing, so partition_point finds the frontier (and ties
        // resolve to the deepest index, per the estimator's contract).
        let reserve = (emit_energy + 2.0 * persist_energy) * cfg.margin;
        let affordable = if planning_budget.is_finite() && planning_budget > reserve {
            table.cumulative_energy[..=total]
                .partition_point(|&e| e * cfg.margin + reserve <= planning_budget)
                .saturating_sub(1)
        } else {
            0
        };
        if affordable == 0 {
            // Not even the shallowest depth survives: skip deliberately
            // and wait for the next slot. No arm is charged — skipping
            // is the planner's decision, not a depth's failure.
            return RoundOutcome::Dropped { steps: 0, sleep: true };
        }
        let arm = st.select_arm(cfg.explore);
        let target = LearnedState::depth_of(arm, total).min(affordable);

        // ------ Write-ahead: persist the pending arm ------------------
        st.pending = Some(arm);
        if !self.persist(engine, &st) {
            st.pending = None;
            return RoundOutcome::Dropped { steps: 0, sleep: false };
        }

        // ------ Execute the chosen depth ------------------------------
        program.plan(target);
        let mut k = 0usize;
        while k < program.planned_steps() {
            let cost = program.step_cost(k);
            if engine.run_op(&cost, Ledger::App) == OpOutcome::BrownOut {
                // The pending marker on FRAM settles the score next boot.
                return RoundOutcome::Dropped { steps: k, sleep: false };
            }
            program.execute_step(k);
            k += 1;
        }

        // ------ Emit within the same power cycle ----------------------
        if engine.run_op(&program.emit_cost(), Ledger::App) == OpOutcome::BrownOut {
            return RoundOutcome::Dropped { steps: k, sleep: true };
        }
        let emitted_at = engine.now;
        let output = program.output();

        // ------ Reward: accuracy per joule, then commit ---------------
        let acc = table.expected_accuracy[k.min(table.expected_accuracy.len() - 1)];
        let full_cost = table.cumulative_energy[total] + emit_energy;
        let spent = table.cumulative_energy[k] + emit_energy;
        let discount = if full_cost > 0.0 { spent / full_cost } else { 0.0 };
        let r = (acc - REWARD_ENERGY_WEIGHT * discount).max(0.0);
        st.reward(arm, r);
        st.pending = None;
        // If this persist browns out the emission still happened; the
        // committed record keeps the pending marker and the arm is
        // (conservatively) charged a death next boot instead of the
        // earned reward. Safe, merely pessimistic.
        let _ = self.persist(engine, &st);
        RoundOutcome::Emitted { emitted_at, steps: k, output }
    }
}

/// Run the adaptive runtime. Thin wrapper over [`AdaptiveRuntime`].
pub fn run<P: StepProgram>(
    program: &mut P,
    engine: &mut Engine,
    cfg: &AdaptiveConfig,
) -> Campaign<P::Output> {
    AdaptiveRuntime::new(cfg.clone()).run(program, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::estimator::{EnergyProfile, SmartTable};
    use crate::energy::harvester::Harvester;
    use crate::energy::mcu::{McuModel, OpCost};
    use crate::exec::engine::EngineConfig;
    use crate::exec::program::SyntheticProgram;

    fn engine(power: f64, max_time: f64) -> Engine {
        Engine::new(EngineConfig::paper_default(max_time), Harvester::Constant(power))
    }

    fn table(steps: usize, cycles: u64, acc_at_full: f64) -> SmartTable {
        let mcu = McuModel::paper_default();
        let costs: Vec<OpCost> = (0..steps).map(|_| OpCost::cycles(cycles)).collect();
        let profile = EnergyProfile::from_costs(&mcu, &costs);
        let acc: Vec<f64> = (0..=steps)
            .map(|p| 1.0 / 6.0 + (acc_at_full - 1.0 / 6.0) * p as f64 / steps as f64)
            .collect();
        let emit = mcu.energy(&OpCost { cycles: 500, ble_bytes: 1, ..Default::default() });
        SmartTable::new(acc, &profile, emit)
    }

    fn cfg(steps: usize, cycles: u64) -> AdaptiveConfig {
        AdaptiveConfig::new(60.0, DEFAULT_ALPHA, DEFAULT_EXPLORE, table(steps, cycles, 0.88))
    }

    #[test]
    fn ucb_plays_every_arm_once_then_exploits() {
        let mut st = LearnedState::new(0.2);
        // Unplayed arms drain in index order.
        for want in 0..ARM_FRACTIONS.len() {
            let arm = st.select_arm(0.5);
            assert_eq!(arm, want);
            st.reward(arm, if want == 2 { 0.9 } else { 0.1 });
        }
        // With exploration off, the best mean wins deterministically.
        assert_eq!(st.select_arm(0.0), 2);
        // With exploration on, repeated best-arm plays still converge to
        // the best arm (its bonus shrinks slower than the others' only
        // logarithmically).
        for _ in 0..200 {
            let arm = st.select_arm(0.5);
            st.reward(arm, if arm == 2 { 0.9 } else { 0.1 });
        }
        assert_eq!(st.select_arm(0.5), 2);
        assert!(st.arms[2].count > 150, "exploitation dominates: {:?}", st.arms);
    }

    #[test]
    fn depth_menu_spans_the_pipeline() {
        assert_eq!(LearnedState::depth_of(0, 140), 35);
        assert_eq!(LearnedState::depth_of(3, 140), 140);
        // Tiny pipelines still get a valid, distinct-ish menu.
        assert_eq!(LearnedState::depth_of(0, 1), 1);
        assert_eq!(LearnedState::depth_of(3, 1), 1);
    }

    #[test]
    fn adaptive_emits_single_cycle_with_bounded_state() {
        let mut p = SyntheticProgram::new(30, 140, 400_000);
        let mut e = engine(1.5e-3, 3600.0 * 2.0);
        let c = run(&mut p, &mut e, &cfg(140, 400_000));
        let emitted: Vec<_> = c.rounds.iter().filter(|r| r.emitted_at.is_some()).collect();
        assert!(!emitted.is_empty(), "adaptive must emit under a paper-scale harvest");
        // The paper's guarantee carries over: zero-cycle latency.
        assert!(emitted.iter().all(|r| r.latency_cycles == 0));
        // Unlike GREEDY/SMART the runtime does persist — but only the
        // bounded learned record: at most restore + three persists per
        // round (pending, death settlement, commit).
        assert!(c.state_energy > 0.0, "learned state must be billed");
        let mcu = McuModel::paper_default();
        let per_round = mcu.energy(&OpCost { fram_writes: STATE_WORDS, ..Default::default() })
            * 3.0
            + mcu.energy(&OpCost { fram_reads: STATE_WORDS, ..Default::default() });
        assert!(
            c.state_energy <= per_round * c.rounds.len() as f64 + 1e-12,
            "state energy {} exceeds the bounded-record ceiling {}",
            c.state_energy,
            per_round * c.rounds.len() as f64
        );
        assert!(c.violations.is_empty(), "{:?}", c.violations);
    }

    #[test]
    fn adaptive_converges_on_a_stationary_environment() {
        // Constant harvest: the affordable depth is stable, so the
        // bandit must settle. Assert the tail of the campaign stops
        // wobbling between depths (the convergence property the issue
        // asks for; N = one UCB sweep + slack).
        let mut p = SyntheticProgram::new(100_000, 140, 400_000);
        let mut e = engine(1.0e-3, 3600.0 * 4.0);
        let c = run(&mut p, &mut e, &cfg(140, 400_000));
        let depths: Vec<usize> = c
            .rounds
            .iter()
            .filter(|r| r.emitted_at.is_some())
            .map(|r| r.steps_executed)
            .collect();
        assert!(depths.len() >= 20, "need a campaign to converge over, got {}", depths.len());
        // UCB keeps a logarithmic trickle of exploration forever, so the
        // settled regime is modal dominance, not strict constancy: in the
        // tail one depth must account for at least 70% of emissions.
        let tail = &depths[depths.len() / 2..];
        let mode = *tail
            .iter()
            .max_by_key(|&&d| tail.iter().filter(|&&x| x == d).count())
            .unwrap();
        let share = tail.iter().filter(|&&d| d == mode).count() as f64 / tail.len() as f64;
        assert!(share >= 0.7, "no dominant depth in the tail: {tail:?}");
    }

    #[test]
    fn adaptive_skips_when_nothing_is_affordable() {
        // Starvation-level harvest: planning must skip, not die mid-round.
        let mut p = SyntheticProgram::new(10, 140, 400_000);
        let mut e = engine(5e-6, 3600.0);
        let c = run(&mut p, &mut e, &cfg(140, 400_000));
        let skipped = c.rounds.iter().filter(|r| r.emitted_at.is_none()).count();
        assert!(skipped > 0, "adaptive should skip under starvation");
        assert!(c.violations.is_empty(), "{:?}", c.violations);
    }

    #[test]
    fn two_identical_runs_are_bitwise_identical() {
        let run_once = || {
            let mut p = SyntheticProgram::new(50, 140, 400_000);
            let mut e = engine(0.8e-3, 3600.0);
            run(&mut p, &mut e, &cfg(140, 400_000))
        };
        let (a, b) = (run_once(), run_once());
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.emitted_at, y.emitted_at);
            assert_eq!(x.steps_executed, y.steps_executed);
        }
        assert_eq!(a.app_energy, b.app_energy);
        assert_eq!(a.state_energy, b.state_energy);
    }
}
