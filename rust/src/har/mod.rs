//! Human activity recognition (the paper's first application, §3-§5).
//!
//! * [`dataset`] — seeded synthetic corpus standing in for the UCI-HAR
//!   recordings (6 activities, 3-axis accelerometer + gyroscope at
//!   50 Hz), including long activity *scripts* whose acceleration also
//!   drives the kinetic harvester — the same wrist motion that powers the
//!   device produces the data it classifies, as in the paper's trials.
//! * [`features`] — the 140-feature catalog (time-domain statistics,
//!   DFT-based spectral features, correlations, jerk, gravity posture)
//!   with per-feature MCU cost vectors for the energy estimator.
//! * [`app`] — the HAR pipeline as a [`crate::exec::StepProgram`]:
//!   acquire window → anytime-SVM feature steps → BLE emission.

pub mod app;
pub mod dataset;
pub mod features;

/// The six activities of Anguita et al. [4].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Activity {
    Walking = 0,
    WalkingUpstairs = 1,
    WalkingDownstairs = 2,
    Sitting = 3,
    Standing = 4,
    Laying = 5,
}

impl Activity {
    pub const ALL: [Activity; 6] = [
        Activity::Walking,
        Activity::WalkingUpstairs,
        Activity::WalkingDownstairs,
        Activity::Sitting,
        Activity::Standing,
        Activity::Laying,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Activity::Walking => "walking",
            Activity::WalkingUpstairs => "walking_upstairs",
            Activity::WalkingDownstairs => "walking_downstairs",
            Activity::Sitting => "sitting",
            Activity::Standing => "standing",
            Activity::Laying => "laying",
        }
    }

    pub fn from_index(i: usize) -> Activity {
        Activity::ALL[i]
    }
}

/// Sampling rate of the paper's sensors.
pub const SAMPLE_RATE_HZ: f64 = 50.0;
/// Window length in samples (2.56 s at 50 Hz, the Anguita windows the
/// paper's 140-feature set implies; see DESIGN.md §5 on the ".2 sec" typo).
pub const WINDOW_LEN: usize = 128;
/// Number of classification features (the linearly separable subset,
/// §4.2).
pub const NUM_FEATURES: usize = 140;
