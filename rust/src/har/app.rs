//! The HAR classification pipeline as a step program (§4.3).
//!
//! Acquire a 2.56 s sensor window → process features one at a time in
//! anytime order (each step = extract one feature and fold it into the
//! cached per-class scores) → emit the 1-byte classification over BLE.
//! The per-step costs come from the feature catalog; the simulation
//! computes the actual feature values eagerly at acquisition (the math is
//! identical either way — the *energy* is charged per executed step).

use crate::energy::estimator::{EnergyProfile, SmartTable};
use crate::energy::mcu::{McuModel, OpCost};
use crate::exec::program::StepProgram;
use crate::har::dataset::{ActivityScript, LabelledWindow};
use crate::har::features::{extract_all, feature_cost};
use crate::har::{Activity, NUM_FEATURES};
use crate::svm::analysis::{coherence_curve_model, expected_accuracy, ClassFeatureModel};
use crate::svm::anytime::{AnytimeSvm, ScoreState};

/// Where the program's sensor windows come from.
pub enum WindowSource {
    /// A fixed list (emulation replay, §5.1-5.2); ends when exhausted.
    List(Vec<LabelledWindow>),
    /// A volunteer's activity script sampled at acquisition time
    /// (real-world campaigns, §5.3-5.4); never ends.
    Script(ActivityScript),
}

/// Classification output delivered over BLE (plus ground truth carried
/// along for the metrics layer; it does not influence execution).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HarOutput {
    pub predicted: usize,
    pub truth: Activity,
    pub features_used: usize,
}

/// The HAR pipeline program.
pub struct HarProgram {
    pub asvm: AnytimeSvm,
    source: WindowSource,
    cursor: usize,
    /// Cached full feature vector for the current window.
    features: Vec<f64>,
    truth: Activity,
    state: ScoreState,
    planned: usize,
    /// Per-step costs in anytime order (step j = feature order[j]).
    step_costs: Vec<OpCost>,
}

impl HarProgram {
    pub fn new(asvm: AnytimeSvm, source: WindowSource) -> HarProgram {
        let step_costs =
            asvm.order.iter().map(|&j| feature_cost(j)).collect::<Vec<_>>();
        let state = asvm.begin();
        HarProgram {
            asvm,
            source,
            cursor: 0,
            features: Vec::new(),
            truth: Activity::Walking,
            state,
            planned: 0,
            step_costs,
        }
    }

    /// Energy profile of the full anytime pipeline (for SMART tables and
    /// the figure benches).
    pub fn energy_profile(&self, mcu: &McuModel) -> EnergyProfile {
        EnergyProfile::from_costs(mcu, &self.step_costs)
    }
}

/// Build SMART's offline lookup table: Eq. 7 expected-accuracy curve (via
/// the fitted class model) + the estimator's cumulative energy.
pub fn smart_table(
    asvm: &AnytimeSvm,
    model: &ClassFeatureModel,
    full_accuracy: f64,
    mcu: &McuModel,
) -> SmartTable {
    let ps: Vec<usize> = (0..=NUM_FEATURES).collect();
    let coherence = coherence_curve_model(asvm, model, &ps, 3000, 0xE97);
    let acc = expected_accuracy(&coherence, full_accuracy, asvm.svm.classes);
    let costs: Vec<OpCost> = asvm.order.iter().map(|&j| feature_cost(j)).collect();
    let profile = EnergyProfile::from_costs(mcu, &costs);
    let emit = mcu.energy(&OpCost { cycles: 800, ble_bytes: 1, ..Default::default() });
    SmartTable::new(acc, &profile, emit)
}

impl StepProgram for HarProgram {
    type Output = HarOutput;

    fn load_next(&mut self, now: f64) -> bool {
        let lw = match &self.source {
            WindowSource::List(list) => {
                if self.cursor >= list.len() {
                    return false;
                }
                let lw = list[self.cursor].clone();
                self.cursor += 1;
                lw
            }
            WindowSource::Script(script) => script.window_at(now),
        };
        self.features = extract_all(&lw.window);
        self.truth = lw.label;
        self.state = self.asvm.begin();
        self.planned = NUM_FEATURES;
        true
    }

    fn acquire_cost(&self) -> OpCost {
        // 2.56 s of sensor duty plus windowing/filter bookkeeping.
        OpCost { cycles: 60_000, sensor_secs: 2.56, ..Default::default() }
    }

    fn num_steps(&self) -> usize {
        NUM_FEATURES
    }

    fn plan(&mut self, k: usize) {
        debug_assert!(k <= NUM_FEATURES);
        self.planned = k;
    }

    fn planned_steps(&self) -> usize {
        self.planned
    }

    fn step_cost(&self, j: usize) -> OpCost {
        self.step_costs[j]
    }

    fn execute_step(&mut self, j: usize) {
        debug_assert_eq!(j, self.state.used, "anytime steps run in order");
        self.asvm.add_feature(&mut self.state, &self.features);
    }

    fn state_words(&self, j: usize) -> u64 {
        // Raw window (128 × 6 16-bit words) + per-class Q30 scores +
        // cursor/bookkeeping + one word per already-extracted feature.
        768 + 2 * self.asvm.svm.classes as u64 + 8 + j as u64
    }

    fn war_words(&self, _j: usize) -> u64 {
        // Score accumulators are read-modify-write: 2 words per class.
        2 * self.asvm.svm.classes as u64
    }

    fn emit_cost(&self) -> OpCost {
        OpCost { cycles: 800, ble_bytes: 1, ..Default::default() }
    }

    fn output(&self) -> HarOutput {
        HarOutput {
            predicted: self.asvm.classify(&self.state),
            truth: self.truth,
            features_used: self.state.used,
        }
    }

    fn reset_round(&mut self) {
        self.state = self.asvm.begin();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::har::dataset::{Corpus, CorpusSpec};
    use crate::svm::train::{train_ovr, TrainConfig};

    fn trained_asvm() -> (AnytimeSvm, Corpus) {
        let spec = CorpusSpec {
            train_volunteers: 3,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 8,
        };
        let corpus = Corpus::generate(&spec, 42);
        let (rows, labels) = Corpus::features(&corpus.train);
        let svm = train_ovr(&rows, &labels, 6, &TrainConfig::default());
        (AnytimeSvm::by_coefficient_magnitude(svm), corpus)
    }

    #[test]
    fn program_runs_a_full_round() {
        let (asvm, corpus) = trained_asvm();
        let mut prog = HarProgram::new(asvm, WindowSource::List(corpus.test.clone()));
        assert!(prog.load_next(0.0));
        prog.plan(30);
        for j in 0..30 {
            prog.execute_step(j);
        }
        let out = prog.output();
        assert_eq!(out.features_used, 30);
        assert!(out.predicted < 6);
    }

    #[test]
    fn full_execution_matches_direct_svm() {
        let (asvm, corpus) = trained_asvm();
        let direct = asvm.clone();
        let mut prog = HarProgram::new(asvm, WindowSource::List(corpus.test.clone()));
        for lw in corpus.test.iter().take(10) {
            assert!(prog.load_next(0.0));
            for j in 0..prog.num_steps() {
                prog.execute_step(j);
            }
            let want = direct.svm.classify(&extract_all(&lw.window));
            assert_eq!(prog.output().predicted, want);
        }
    }

    #[test]
    fn trained_model_beats_chance_by_far_on_held_out_volunteers() {
        let (asvm, corpus) = trained_asvm();
        let (rows, labels) = Corpus::features(&corpus.test);
        let acc = asvm.svm.accuracy(&rows, &labels);
        assert!(acc > 0.7, "held-out accuracy {acc}");
    }

    #[test]
    fn smart_table_monotone_and_priced() {
        let (asvm, corpus) = trained_asvm();
        let (rows, labels) = Corpus::features(&corpus.train);
        let scaled: Vec<Vec<f64>> =
            rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
        let model = ClassFeatureModel::fit(&scaled, &labels, 6);
        let mcu = McuModel::paper_default();
        let table = smart_table(&asvm, &model, 0.88, &mcu);
        assert_eq!(table.expected_accuracy.len(), NUM_FEATURES + 1);
        // Accuracy must reach the ceiling at full prefix.
        assert!((table.expected_accuracy[NUM_FEATURES] - 0.88).abs() < 1e-9);
        // Energy strictly increasing.
        for p in 1..=NUM_FEATURES {
            assert!(table.cumulative_energy[p] > table.cumulative_energy[p - 1]);
        }
        // A 60 % bound needs strictly fewer features than an 85 % bound.
        let p60 = table.min_features_for(0.60);
        let p85 = table.min_features_for(0.85);
        if let (Some(a), Some(b)) = (p60, p85) {
            assert!(a < b, "p60={a} p85={b}");
        }
    }

    #[test]
    fn script_source_loads_time_dependent_windows() {
        let (asvm, _) = trained_asvm();
        let script = ActivityScript::generate(3600.0, 3);
        let truth_at_100 = script.activity_at(100.0);
        let mut prog = HarProgram::new(asvm, WindowSource::Script(script));
        assert!(prog.load_next(100.0));
        assert_eq!(prog.output().truth, truth_at_100);
        // Script sources never exhaust.
        assert!(prog.load_next(2e6));
    }
}
