//! Synthetic HAR corpus.
//!
//! Stands in for the (non-redistributable) recordings of Anguita et al.
//! and the paper's own 842 h of volunteer data. Each activity has a
//! structural signal model — gait oscillations with harmonics for the
//! walking classes, distinct gravity orientations with micro-motion for
//! the postures — plus per-volunteer variation (gait frequency, amplitude,
//! sensor mounting tilt) and sensor noise. What the anytime-SVM analysis
//! needs from the data is preserved by construction: a 6-class problem
//! that is largely linearly separable in the 140-feature space with a
//! long-tailed feature-importance spectrum and a realistic (~88 %)
//! accuracy ceiling.

use crate::har::{Activity, SAMPLE_RATE_HZ, WINDOW_LEN};
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Gravity, m/s².
pub const G: f64 = 9.81;

/// One sensor window: 3-axis accelerometer + 3-axis gyroscope.
#[derive(Clone, Debug)]
pub struct Window {
    /// `accel[axis][t]`, m/s², includes gravity.
    pub accel: [Vec<f64>; 3],
    /// `gyro[axis][t]`, rad/s.
    pub gyro: [Vec<f64>; 3],
}

/// A labelled window.
#[derive(Clone, Debug)]
pub struct LabelledWindow {
    pub window: Window,
    pub label: Activity,
}

/// Per-volunteer trait vector: makes volunteers distinguishable without
/// breaking class structure.
#[derive(Clone, Debug)]
pub struct Volunteer {
    /// Gait frequency, Hz (walking cadence varies per person).
    pub gait_hz: f64,
    /// Overall movement amplitude factor.
    pub vigor: f64,
    /// Device mounting tilt (radians) rotating gravity between axes.
    pub tilt: f64,
    /// Sensor noise level, m/s².
    pub noise: f64,
}

impl Volunteer {
    pub fn sample(rng: &mut Rng) -> Volunteer {
        Volunteer {
            gait_hz: rng.range(1.7, 2.2),
            vigor: rng.range(0.8, 1.25),
            tilt: rng.range(-0.18, 0.18),
            noise: rng.range(0.55, 0.95),
        }
    }
}

/// Activity signal parameters (class structure, shared by all people).
struct ActivityModel {
    /// Gait fundamental relative to the volunteer's cadence (0 = static).
    gait_rel: f64,
    /// Vertical oscillation amplitude, m/s².
    amp_v: f64,
    /// Harmonic content (2f, 3f) relative amplitudes.
    harmonics: (f64, f64),
    /// Forward-axis amplitude.
    amp_f: f64,
    /// Gyro oscillation amplitude, rad/s.
    gyro_amp: f64,
    /// Gravity direction: angle from the vertical axis, radians.
    grav_angle: f64,
    /// Low-frequency sway amplitude (postures), m/s².
    sway: f64,
}

fn model(a: Activity) -> ActivityModel {
    match a {
        Activity::Walking => ActivityModel {
            gait_rel: 1.0,
            amp_v: 3.2,
            harmonics: (0.45, 0.18),
            amp_f: 1.8,
            gyro_amp: 0.9,
            grav_angle: 0.0,
            sway: 0.0,
        },
        Activity::WalkingUpstairs => ActivityModel {
            gait_rel: 0.90,
            amp_v: 3.55,
            harmonics: (0.54, 0.20),
            amp_f: 1.5,
            gyro_amp: 1.10,
            grav_angle: 0.10,
            sway: 0.0,
        },
        Activity::WalkingDownstairs => ActivityModel {
            gait_rel: 1.08,
            amp_v: 4.1,
            harmonics: (0.66, 0.34),
            amp_f: 2.1,
            gyro_amp: 1.3,
            grav_angle: -0.08,
            sway: 0.0,
        },
        Activity::Sitting => ActivityModel {
            gait_rel: 0.0,
            amp_v: 0.0,
            harmonics: (0.0, 0.0),
            amp_f: 0.0,
            gyro_amp: 0.035,
            grav_angle: 0.35,
            sway: 0.10,
        },
        Activity::Standing => ActivityModel {
            gait_rel: 0.0,
            amp_v: 0.0,
            harmonics: (0.0, 0.0),
            amp_f: 0.0,
            gyro_amp: 0.02,
            grav_angle: 0.05,
            sway: 0.16,
        },
        Activity::Laying => ActivityModel {
            gait_rel: 0.0,
            amp_v: 0.0,
            harmonics: (0.0, 0.0),
            amp_f: 0.0,
            gyro_amp: 0.015,
            grav_angle: 1.45,
            sway: 0.05,
        },
    }
}

/// Generate one window of `activity` for `who`, with phase continuity
/// governed by `phase0` (radians at window start).
pub fn generate_window(
    activity: Activity,
    who: &Volunteer,
    rng: &mut Rng,
    phase0: f64,
) -> Window {
    let m = model(activity);
    let n = WINDOW_LEN;
    let fs = SAMPLE_RATE_HZ;
    let f = m.gait_rel * who.gait_hz;
    let tilt = who.tilt + m.grav_angle;
    // Gravity distributed between vertical (z) and horizontal (x) axes by
    // the posture angle; a second small rotation spills into y.
    let gz = G * tilt.cos();
    let gx = G * tilt.sin();
    let gy = G * (0.22 * tilt).sin();

    let mut accel = [vec![0.0; n], vec![0.0; n], vec![0.0; n]];
    let mut gyro = [vec![0.0; n], vec![0.0; n], vec![0.0; n]];
    // Slow sway phase for postures.
    let sway_f = rng.range(0.25, 0.6);
    let sway_phase = rng.range(0.0, 2.0 * PI);
    for t in 0..n {
        let time = t as f64 / fs;
        let ph = phase0 + 2.0 * PI * f * time;
        let vigor = who.vigor;
        let (h2, h3) = m.harmonics;
        // Vertical (z) impact pattern.
        let vertical = m.amp_v
            * vigor
            * (ph.sin() + h2 * (2.0 * ph).sin() + h3 * (3.0 * ph + 0.7).sin());
        // Forward (x) propulsion, phase-shifted.
        let forward = m.amp_f * vigor * ((ph + PI / 2.0).sin() + 0.3 * (2.0 * ph).cos());
        // Lateral (y) weight shift at half cadence.
        let lateral = 0.4 * m.amp_v * vigor * (0.5 * ph + 0.3).sin();
        let sway = m.sway * (2.0 * PI * sway_f * time + sway_phase).sin();

        accel[0][t] = gx + forward + sway + who.noise * rng.gaussian();
        accel[1][t] = gy + lateral + 0.6 * sway + who.noise * rng.gaussian();
        accel[2][t] = gz + vertical + who.noise * rng.gaussian();

        let gn = 0.18 * who.noise;
        gyro[0][t] = m.gyro_amp * vigor * (ph + 0.4).sin() + gn * rng.gaussian();
        gyro[1][t] = m.gyro_amp * vigor * 0.7 * (0.5 * ph).sin() + gn * rng.gaussian();
        gyro[2][t] =
            m.gyro_amp * vigor * 0.4 * (2.0 * ph + 1.1).sin() + gn * rng.gaussian();
    }
    Window { accel, gyro }
}

/// A labelled corpus with a train/test split.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub train: Vec<LabelledWindow>,
    pub test: Vec<LabelledWindow>,
}

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub train_volunteers: usize,
    pub test_volunteers: usize,
    pub windows_per_volunteer_per_class: usize,
}

impl Default for CorpusSpec {
    fn default() -> CorpusSpec {
        CorpusSpec {
            train_volunteers: 10,
            test_volunteers: 3,
            windows_per_volunteer_per_class: 20,
        }
    }
}

impl Corpus {
    /// Generate a corpus; test volunteers are disjoint from training ones
    /// (subject-independent evaluation, as Anguita et al. do).
    pub fn generate(spec: &CorpusSpec, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let mut make = |count: usize, tag: u64| -> Vec<LabelledWindow> {
            let mut out = Vec::new();
            for v in 0..count {
                let mut vrng = rng.fork(tag.wrapping_mul(1000) + v as u64);
                let who = Volunteer::sample(&mut vrng);
                for activity in Activity::ALL {
                    for _ in 0..spec.windows_per_volunteer_per_class {
                        let phase0 = vrng.range(0.0, 2.0 * PI);
                        let window = generate_window(activity, &who, &mut vrng, phase0);
                        out.push(LabelledWindow { window, label: activity });
                    }
                }
            }
            out
        };
        Corpus { train: make(spec.train_volunteers, 1), test: make(spec.test_volunteers, 2) }
    }

    /// Extract feature matrices (uses the full 140-feature catalog).
    pub fn features(set: &[LabelledWindow]) -> (Vec<Vec<f64>>, Vec<usize>) {
        let rows = set
            .iter()
            .map(|lw| crate::har::features::extract_all(&lw.window))
            .collect();
        let labels = set.iter().map(|lw| lw.label as usize).collect();
        (rows, labels)
    }
}

/// A long activity script: a volunteer's day as a sequence of activity
/// segments. Provides both the labelled windows the classifier sees and
/// the continuous acceleration-magnitude signal that drives the kinetic
/// harvester — the same motion powers and is classified by the device.
#[derive(Clone, Debug)]
pub struct ActivityScript {
    pub who: Volunteer,
    /// (activity, start_time_secs) segments, sorted.
    pub segments: Vec<(Activity, f64)>,
    pub duration: f64,
    seed: u64,
}

impl ActivityScript {
    /// Markov-style schedule: dwell times differ per activity (postures
    /// dwell long; stair segments are short).
    pub fn generate(duration: f64, seed: u64) -> ActivityScript {
        let mut rng = Rng::new(seed);
        let who = Volunteer::sample(&mut rng);
        let mut segments = Vec::new();
        let mut t = 0.0;
        let mut current = *rng.choose(&Activity::ALL);
        while t < duration {
            segments.push((current, t));
            let dwell = match current {
                Activity::Walking => rng.range(120.0, 600.0),
                Activity::WalkingUpstairs | Activity::WalkingDownstairs => {
                    rng.range(30.0, 90.0)
                }
                Activity::Sitting => rng.range(300.0, 1200.0),
                Activity::Standing => rng.range(120.0, 600.0),
                Activity::Laying => rng.range(600.0, 1800.0),
            };
            t += dwell;
            // Transition: prefer plausible successors.
            current = match current {
                Activity::Laying => *rng.choose(&[Activity::Sitting, Activity::Standing]),
                Activity::Sitting => {
                    *rng.choose(&[Activity::Standing, Activity::Walking, Activity::Laying])
                }
                _ => *rng.choose(&Activity::ALL),
            };
        }
        ActivityScript { who, segments, duration, seed }
    }

    /// Activity at absolute time `t`.
    pub fn activity_at(&self, t: f64) -> Activity {
        match self.segments.binary_search_by(|(_, s)| s.partial_cmp(&t).unwrap()) {
            Ok(i) => self.segments[i].0,
            Err(0) => self.segments[0].0,
            Err(i) => self.segments[i - 1].0,
        }
    }

    /// The labelled window acquired at time `t` (deterministic in `t`).
    pub fn window_at(&self, t: f64) -> LabelledWindow {
        let activity = self.activity_at(t);
        let mut rng = Rng::new(self.seed ^ (t * 1000.0) as u64);
        let phase0 = 2.0 * PI * self.who.gait_hz * t;
        LabelledWindow {
            window: generate_window(activity, &self.who, &mut rng, phase0),
            label: activity,
        }
    }

    /// Acceleration-magnitude stream (gravity removed) for the harvester,
    /// sampled at `fs`, covering the whole script duration.
    pub fn accel_magnitude(&self, fs: f64) -> Vec<f64> {
        let n = (self.duration * fs) as usize;
        let mut rng = Rng::new(self.seed ^ 0xACCE1);
        let mut out = Vec::with_capacity(n);
        // Generate per-segment windows' worth of signal cheaply: use the
        // same structural model directly.
        // Fidget bursts: short arm-movement episodes during otherwise
        // static activities (typing, gesturing, drinking) — the dominant
        // kinetic-energy source while not walking.
        let mut fidget_until = 0usize;
        let mut fidget_amp = 0.0;
        let mut fidget_hz = 1.5;
        for i in 0..n {
            let t = i as f64 / fs;
            let activity = self.activity_at(t);
            let m = model(activity);
            let f = m.gait_rel * self.who.gait_hz;
            let ph = 2.0 * PI * f * t;
            let (h2, h3) = m.harmonics;
            let v = m.amp_v
                * self.who.vigor
                * (ph.sin() + h2 * (2.0 * ph).sin() + h3 * (3.0 * ph + 0.7).sin());
            let fwd = m.amp_f * self.who.vigor * (ph + PI / 2.0).sin();
            let sway = m.sway;
            let mut mag =
                (v * v + fwd * fwd).sqrt() + sway + self.who.noise * rng.gaussian().abs();
            let is_static = matches!(
                activity,
                Activity::Sitting | Activity::Standing | Activity::Laying
            );
            if is_static {
                if i >= fidget_until && rng.chance(0.10 / fs) {
                    // ~one burst every 10 s of static time on average.
                    fidget_amp = rng.range(1.0, 3.5) * self.who.vigor;
                    fidget_hz = rng.range(1.2, 2.8);
                    fidget_until = i + (rng.range(1.5, 5.0) * fs) as usize;
                }
                if i < fidget_until {
                    mag += fidget_amp * (2.0 * PI * fidget_hz * t).sin().abs();
                }
            }
            out.push(mag);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_have_expected_shape() {
        let mut rng = Rng::new(1);
        let who = Volunteer::sample(&mut rng);
        let w = generate_window(Activity::Walking, &who, &mut rng, 0.0);
        for axis in 0..3 {
            assert_eq!(w.accel[axis].len(), WINDOW_LEN);
            assert_eq!(w.gyro[axis].len(), WINDOW_LEN);
        }
    }

    #[test]
    fn walking_is_dynamic_postures_are_static() {
        let mut rng = Rng::new(2);
        let who = Volunteer::sample(&mut rng);
        let walk = generate_window(Activity::Walking, &who, &mut rng, 0.0);
        let lay = generate_window(Activity::Laying, &who, &mut rng, 0.0);
        let std_of = |xs: &[f64]| crate::util::stats::std_dev(xs);
        assert!(std_of(&walk.accel[2]) > 4.0 * std_of(&lay.accel[2]));
    }

    #[test]
    fn gravity_orientation_distinguishes_postures() {
        let mut rng = Rng::new(3);
        let who = Volunteer { tilt: 0.0, ..Volunteer::sample(&mut rng) };
        let stand = generate_window(Activity::Standing, &who, &mut rng, 0.0);
        let lay = generate_window(Activity::Laying, &who, &mut rng, 0.0);
        let mean_of = |xs: &[f64]| crate::util::stats::mean(xs);
        // Standing: gravity mostly on z; laying: mostly on x.
        assert!(mean_of(&stand.accel[2]) > 8.0);
        assert!(mean_of(&lay.accel[2]) < 2.5);
        assert!(mean_of(&lay.accel[0]) > 8.0);
    }

    #[test]
    fn corpus_generation_is_deterministic_and_balanced() {
        let spec = CorpusSpec {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 3,
        };
        let a = Corpus::generate(&spec, 9);
        let b = Corpus::generate(&spec, 9);
        assert_eq!(a.train.len(), 2 * 6 * 3);
        assert_eq!(a.test.len(), 6 * 3);
        assert_eq!(a.train[0].window.accel[0], b.train[0].window.accel[0]);
        // Balanced classes.
        for activity in Activity::ALL {
            let count = a.train.iter().filter(|lw| lw.label == activity).count();
            assert_eq!(count, 6);
        }
    }

    #[test]
    fn script_covers_duration_with_consistent_lookups() {
        let s = ActivityScript::generate(4.0 * 3600.0, 17);
        assert!(!s.segments.is_empty());
        assert_eq!(s.activity_at(0.0), s.segments[0].0);
        let lw = s.window_at(1234.0);
        assert_eq!(lw.label, s.activity_at(1234.0));
        // Deterministic.
        let lw2 = s.window_at(1234.0);
        assert_eq!(lw.window.accel[0], lw2.window.accel[0]);
    }

    #[test]
    fn accel_magnitude_reflects_activity_intensity() {
        let s = ActivityScript::generate(2.0 * 3600.0, 23);
        let fs = 50.0;
        let mag = s.accel_magnitude(fs);
        assert_eq!(mag.len(), (s.duration * fs) as usize);
        // Mean magnitude during walking beats laying.
        let mut walk_sum = (0.0, 0usize);
        let mut lay_sum = (0.0, 0usize);
        for (i, &v) in mag.iter().enumerate() {
            match s.activity_at(i as f64 / fs) {
                Activity::Walking => {
                    walk_sum.0 += v;
                    walk_sum.1 += 1;
                }
                Activity::Laying => {
                    lay_sum.0 += v;
                    lay_sum.1 += 1;
                }
                _ => {}
            }
        }
        if walk_sum.1 > 0 && lay_sum.1 > 0 {
            assert!(walk_sum.0 / walk_sum.1 as f64 > 2.0 * lay_sum.0 / lay_sum.1 as f64);
        }
    }
}
