//! The 140-feature catalog (§4.2).
//!
//! Features are computed from Butterworth-filtered sensor windows: eight
//! channels (3-axis body acceleration after gravity separation, 3-axis
//! angular velocity, and the two magnitudes), time-domain statistics,
//! DFT-based spectral features, jerk statistics, inter-axis correlations,
//! gravity posture and aggregate activity measures — the linearly
//! separable subset the paper limits itself to. Every feature carries an
//! MCU cost vector (dominated by the extraction processing, which is why
//! per-feature energy varies, §4.2); the catalog order is the canonical
//! feature index used by the SVM and the AOT artifacts.

use crate::energy::mcu::OpCost;
use crate::har::dataset::Window;
use crate::har::{NUM_FEATURES, SAMPLE_RATE_HZ, WINDOW_LEN};
use crate::util::dsp::Cascade;
use crate::util::fft::power_spectrum;
use crate::util::stats;

/// Preprocessed channels ready for feature extraction.
#[derive(Clone, Debug)]
pub struct Preprocessed {
    /// body-ax, body-ay, body-az, gx, gy, gz, |body accel|, |gyro|.
    pub channels: [Vec<f64>; 8],
    /// Gravity components per accel axis (means of the 0.3 Hz low-pass).
    pub gravity: [f64; 3],
}

/// Preprocess a raw window: 3rd-order Butterworth low-pass at 20 Hz
/// (§4.2: 99 % of signal energy below 20 Hz), then gravity separation
/// with a 0.3 Hz low-pass.
pub fn preprocess(w: &Window) -> Preprocessed {
    let fs = SAMPLE_RATE_HZ;
    let n = WINDOW_LEN;
    let mut noise_filter = Cascade::butterworth_lowpass(3, 20.0, fs);
    let mut grav_filter = Cascade::butterworth_lowpass(3, 0.3, fs);

    let mut body = [vec![0.0; n], vec![0.0; n], vec![0.0; n]];
    let mut gravity = [0.0; 3];
    for axis in 0..3 {
        noise_filter.reset();
        let filtered = noise_filter.filter(&w.accel[axis]);
        grav_filter.reset();
        // Prime the slow gravity filter to the window mean to avoid the
        // long settle transient a streaming implementation would not see.
        let mean = stats::mean(&filtered);
        for _ in 0..256 {
            grav_filter.step(mean);
        }
        let grav = grav_filter.filter(&filtered);
        gravity[axis] = stats::mean(&grav);
        for t in 0..n {
            body[axis][t] = filtered[t] - grav[t];
        }
    }
    let mut gyro = [vec![0.0; n], vec![0.0; n], vec![0.0; n]];
    for axis in 0..3 {
        noise_filter.reset();
        gyro[axis] = noise_filter.filter(&w.gyro[axis]);
    }
    let amag: Vec<f64> = (0..n)
        .map(|t| (body[0][t].powi(2) + body[1][t].powi(2) + body[2][t].powi(2)).sqrt())
        .collect();
    let gmag: Vec<f64> = (0..n)
        .map(|t| (gyro[0][t].powi(2) + gyro[1][t].powi(2) + gyro[2][t].powi(2)).sqrt())
        .collect();
    let [bx, by, bz] = body;
    let [gx, gy, gz] = gyro;
    Preprocessed { channels: [bx, by, bz, gx, gy, gz, amag, gmag], gravity }
}

/// Time-domain statistic kinds (per channel).
const TIME_KINDS: usize = 7; // mean, std, mad, min, max, energy, iqr
/// Frequency-domain kinds (per channel).
const FREQ_KINDS: usize = 7; // 4 band energies, centroid, peak, entropy
const CHANNELS: usize = 8;

/// Human-readable feature name for index `idx`.
pub fn feature_name(idx: usize) -> String {
    let ch_names = ["bax", "bay", "baz", "gyx", "gyy", "gyz", "amag", "gmag"];
    if idx < 56 {
        let (ch, k) = (idx / TIME_KINDS, idx % TIME_KINDS);
        let kind = ["mean", "std", "mad", "min", "max", "energy", "iqr"][k];
        format!("{}_{}", ch_names[ch], kind)
    } else if idx < 112 {
        let r = idx - 56;
        let (ch, k) = (r / FREQ_KINDS, r % FREQ_KINDS);
        let kind =
            ["band0", "band1", "band2", "band3", "centroid", "peakbin", "sentropy"][k];
        format!("{}_{}", ch_names[ch], kind)
    } else if idx < 128 {
        let r = idx - 112;
        let (ch, k) = (r / 2, r % 2);
        format!("{}_jerk_{}", ch_names[ch], ["mean", "std"][k])
    } else if idx < 134 {
        let pairs = ["ax_ay", "ax_az", "ay_az", "gx_gy", "gx_gz", "gy_gz"];
        format!("corr_{}", pairs[idx - 128])
    } else if idx < 137 {
        format!("gravity_{}", ["x", "y", "z"][idx - 134])
    } else {
        ["sma_accel", "sma_gyro", "total_power"][idx - 137].to_string()
    }
}

/// MCU cost of extracting feature `idx` from the raw window (the paper
/// profiles this per feature with EPIC; costs vary because of the
/// processing needed to *compute* the feature, §4.2). Spectral features
/// carry an amortised share of the channel DFT.
pub fn feature_cost(idx: usize) -> OpCost {
    let cycles: u64 = if idx < 56 {
        match idx % TIME_KINDS {
            0 => 80_000,     // mean
            1 => 70_000,     // std
            2 => 220_000,     // mad (needs a sort)
            3 | 4 => 70_000, // min / max
            5 => 100_000,     // energy
            _ => 240_000,     // iqr (sort + interpolate)
        }
    } else if idx < 112 {
        match (idx - 56) % FREQ_KINDS {
            0..=3 => 280_000, // band energies (incl. amortised DFT share)
            4 => 310_000,     // spectral centroid
            5 => 180_000,     // peak bin
            _ => 340_000,     // spectral entropy
        }
    } else if idx < 128 {
        if (idx - 112) % 2 == 0 {
            140_000 // jerk mean
        } else {
            160_000 // jerk std
        }
    } else if idx < 134 {
        180_000 // correlation
    } else if idx < 137 {
        65_000 // gravity mean
    } else {
        70_000 // sma / total power
    };
    OpCost::cycles(cycles)
}

/// All 140 feature cost vectors, in catalog order.
pub fn all_costs() -> Vec<OpCost> {
    (0..NUM_FEATURES).map(feature_cost).collect()
}

/// Quantile from an already-sorted slice (one sort per channel instead
/// of one per quantile call — see EXPERIMENTS.md §Perf).
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

fn mad_from_sorted(xs: &[f64], sorted: &[f64]) -> f64 {
    let med = quantile_sorted(sorted, 0.5);
    let mut dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&dev, 0.5)
}

fn spectral(ch: &[f64]) -> [f64; FREQ_KINDS] {
    let ps = power_spectrum(ch); // bins 0..=64
    let total: f64 = ps[1..].iter().sum::<f64>().max(1e-12);
    // Bands: (1..4), (4..8), (8..16), (16..=64) bins ≈ 0.4-1.6, 1.6-3.1,
    // 3.1-6.2, 6.2-25 Hz.
    let band = |a: usize, b: usize| -> f64 { ps[a..b].iter().sum::<f64>() / total };
    let centroid =
        ps[1..].iter().enumerate().map(|(i, &p)| (i + 1) as f64 * p).sum::<f64>() / total;
    let peak = ps[1..]
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| (i + 1) as f64)
        .unwrap_or(0.0);
    let entropy = -ps[1..]
        .iter()
        .map(|&p| {
            let q = p / total;
            if q > 1e-15 {
                q * q.ln()
            } else {
                0.0
            }
        })
        .sum::<f64>();
    [band(1, 4), band(4, 8), band(8, 16), band(16, 65), centroid, peak, entropy]
}

/// Extract the full 140-feature vector (catalog order) from a raw window.
pub fn extract_all(w: &Window) -> Vec<f64> {
    let prep = preprocess(w);
    extract_from_preprocessed(&prep)
}

/// Extraction given preprocessed channels (the cached form the app uses).
pub fn extract_from_preprocessed(prep: &Preprocessed) -> Vec<f64> {
    let mut out = Vec::with_capacity(NUM_FEATURES);
    // Time stats.
    for ch in prep.channels.iter() {
        let mean = stats::mean(ch);
        let std = stats::std_dev(ch);
        let mut sorted = ch.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(mean);
        out.push(std);
        out.push(mad_from_sorted(ch, &sorted));
        out.push(ch.iter().cloned().fold(f64::INFINITY, f64::min));
        out.push(ch.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
        out.push(ch.iter().map(|x| x * x).sum::<f64>() / ch.len() as f64);
        out.push(quantile_sorted(&sorted, 0.75) - quantile_sorted(&sorted, 0.25));
    }
    // Spectral.
    for ch in prep.channels.iter() {
        out.extend_from_slice(&spectral(ch));
    }
    // Jerk (first difference) mean-abs and std.
    for ch in prep.channels.iter() {
        let jerk: Vec<f64> =
            ch.windows(2).map(|p| (p[1] - p[0]) * SAMPLE_RATE_HZ).collect();
        out.push(jerk.iter().map(|j| j.abs()).sum::<f64>() / jerk.len() as f64);
        out.push(stats::std_dev(&jerk));
    }
    // Correlations.
    let c = &prep.channels;
    for (a, b) in [(0, 1), (0, 2), (1, 2), (3, 4), (3, 5), (4, 5)] {
        out.push(stats::correlation(&c[a], &c[b]));
    }
    // Gravity posture.
    out.extend_from_slice(&prep.gravity);
    // Signal magnitude areas + total power.
    let sma_a = (0..WINDOW_LEN)
        .map(|t| c[0][t].abs() + c[1][t].abs() + c[2][t].abs())
        .sum::<f64>()
        / WINDOW_LEN as f64;
    let sma_g = (0..WINDOW_LEN)
        .map(|t| c[3][t].abs() + c[4][t].abs() + c[5][t].abs())
        .sum::<f64>()
        / WINDOW_LEN as f64;
    let power = c[6].iter().map(|x| x * x).sum::<f64>() / WINDOW_LEN as f64;
    out.push(sma_a);
    out.push(sma_g);
    out.push(power);
    debug_assert_eq!(out.len(), NUM_FEATURES);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::har::dataset::{generate_window, Volunteer};
    use crate::har::Activity;
    use crate::util::rng::Rng;

    fn sample(activity: Activity, seed: u64) -> Window {
        let mut rng = Rng::new(seed);
        let who = Volunteer::sample(&mut rng);
        generate_window(activity, &who, &mut rng, 0.0)
    }

    #[test]
    fn catalog_has_140_features_with_names_and_costs() {
        let w = sample(Activity::Walking, 1);
        let f = extract_all(&w);
        assert_eq!(f.len(), NUM_FEATURES);
        assert!(f.iter().all(|v| v.is_finite()));
        let names: std::collections::HashSet<String> =
            (0..NUM_FEATURES).map(feature_name).collect();
        assert_eq!(names.len(), NUM_FEATURES, "names must be unique");
        assert_eq!(all_costs().len(), NUM_FEATURES);
        assert!(all_costs().iter().all(|c| c.cycles > 0));
    }

    #[test]
    fn full_pipeline_energy_in_paper_regime() {
        // Total extraction cost should be a handful of buffer-fulls: the
        // intermittent regime of §5 (see DESIGN.md §5).
        let mcu = crate::energy::mcu::McuModel::paper_default();
        let total: f64 = all_costs().iter().map(|c| mcu.energy(c)).sum();
        assert!(
            (5e-3..20e-3).contains(&total),
            "total feature energy {total} J out of expected range"
        );
    }

    #[test]
    fn walking_and_laying_differ_in_dynamic_features() {
        let walk = extract_all(&sample(Activity::Walking, 2));
        let lay = extract_all(&sample(Activity::Laying, 2));
        // baz std (idx 2*7+1 = 15) much larger while walking.
        assert!(walk[15] > 3.0 * lay[15], "walk={} lay={}", walk[15], lay[15]);
        // total_power (idx 139).
        assert!(walk[139] > 3.0 * lay[139]);
    }

    #[test]
    fn gravity_features_separate_postures() {
        let stand = extract_all(&sample(Activity::Standing, 3));
        let lay = extract_all(&sample(Activity::Laying, 3));
        // gravity_z = idx 136, gravity_x = idx 134.
        assert!(stand[136] > lay[136] + 4.0);
        assert!(lay[134] > stand[134] + 2.0);
    }

    #[test]
    fn spectral_peak_tracks_gait_frequency() {
        let mut rng = Rng::new(4);
        let who = Volunteer { gait_hz: 2.0, ..Volunteer::sample(&mut rng) };
        let w = generate_window(Activity::Walking, &who, &mut rng, 0.0);
        let f = extract_all(&w);
        // baz peak bin: idx 56 + 2*7 + 5 = 75. 2 Hz at 50 Hz/128 bins →
        // bin ≈ 5.1; harmonics may push the peak to ~2x that.
        let peak_bin = f[75];
        assert!((3.0..=12.0).contains(&peak_bin), "peak_bin={peak_bin}");
    }

    #[test]
    fn extraction_is_deterministic() {
        let w = sample(Activity::Sitting, 5);
        assert_eq!(extract_all(&w), extract_all(&w));
    }
}
