//! Dependency-free stand-in for the PJRT client (`--no-default-features`
//! builds, i.e. whenever the `pjrt` feature is off).
//!
//! [`Tensor`] is the same pure-Rust container the real client exposes;
//! [`ArtifactRuntime::load`] always fails with a clear message, which the
//! artifact tests and benches already treat as "skip" (it is the same
//! path they take when `make artifacts` has not run).

use std::fmt;
use std::path::Path;

/// A shaped f32 tensor travelling to/from the PJRT executables.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product();
        Tensor { shape, data: vec![0.0; len] }
    }

    pub fn scalar_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }
}

/// Error produced by every operation of the stubbed runtime.
#[derive(Clone, Debug)]
pub struct PjrtUnavailable;

impl fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "built without the `pjrt` feature; \
             rebuild with `--features pjrt` and the XLA toolchain"
        )
    }
}

impl std::error::Error for PjrtUnavailable {}

/// Stubbed artifact registry: construction always fails.
pub struct ArtifactRuntime {
    // Uninhabited: a stub runtime can never actually exist, which makes
    // every method body trivially unreachable.
    never: std::convert::Infallible,
}

impl ArtifactRuntime {
    /// Always fails: the PJRT client is not compiled in.
    pub fn load(_dir: impl AsRef<Path>) -> Result<ArtifactRuntime, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }

    pub fn names(&self) -> Vec<String> {
        match self.never {}
    }

    pub fn device_count(&self) -> usize {
        match self.never {}
    }

    pub fn input_shapes(&self, _name: &str) -> Vec<Vec<usize>> {
        match self.never {}
    }

    pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Tensor, PjrtUnavailable> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_the_missing_feature() {
        let err = ArtifactRuntime::load("artifacts").err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn tensor_is_fully_functional() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.data.len(), 6);
        let s = Tensor::scalar_vec(vec![1.0, 2.0]);
        assert_eq!(s.shape, vec![2]);
    }
}
