//! PJRT client wrapper: HLO-text artifacts → compiled executables.
//!
//! Follows the load_hlo reference (/opt/xla-example): text is the
//! interchange format because xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos (64-bit instruction ids); `HloModuleProto::
//! from_text_file` reassigns ids and round-trips cleanly. Every artifact
//! is lowered with `return_tuple=True`, so outputs unwrap with
//! `to_tuple1`.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json;

/// A shaped f32 tensor travelling to/from the PJRT executables.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product();
        Tensor { shape, data: vec![0.0; len] }
    }

    pub fn scalar_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// Compiled artifact registry backed by the PJRT CPU client.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Parsed manifest (shapes, descriptions).
    pub manifest: json::Value,
    dir: PathBuf,
}

impl ArtifactRuntime {
    /// Load every artifact listed in `<dir>/manifest.json` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest_text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = json::parse(&manifest_text)
            .map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        let arts = manifest
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(ArtifactRuntime { client, executables, manifest, dir })
    }

    /// Names of the loaded artifacts.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.executables.keys().cloned().collect();
        v.sort();
        v
    }

    /// Expected input shapes for an artifact, from the manifest.
    pub fn input_shapes(&self, name: &str) -> Vec<Vec<usize>> {
        self.manifest
            .get("artifacts")
            .get(name)
            .get("inputs")
            .as_arr()
            .map(|arr| {
                arr.iter()
                    .map(|shape| {
                        shape
                            .as_arr()
                            .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                            .unwrap_or_default()
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Execute an artifact with the given inputs; returns the first (and
    /// only) element of the lowered 1-tuple as a flat f32 tensor.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' in {:?}", self.dir))?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let shape = out.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }

    /// Number of PJRT devices (CPU: 1).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
        let z = Tensor::zeros(vec![4, 5]);
        assert_eq!(z.data.len(), 20);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        let _ = Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    // Execution against real artifacts lives in rust/tests/
    // integration_runtime.rs (requires `make artifacts` first).
}
