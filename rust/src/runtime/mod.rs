//! PJRT runtime: load and execute the AOT artifacts.
//!
//! The compile path (python/compile/aot.py, run once by `make artifacts`)
//! lowers the L2 JAX pipelines to HLO *text*; this module is the request
//! path: a [`client::ArtifactRuntime`] compiles each artifact on the PJRT
//! CPU client at startup and executes it with concrete buffers — Python
//! never runs here. The emulation experiments use it to replay thousands
//! of device rounds as one batched call, cross-checked against the
//! pure-Rust twins in the integration tests.
//!
//! The real client depends on the `xla` crate (and the XLA toolchain
//! underneath it), so it is gated behind the off-by-default `pjrt`
//! feature. Without the feature, [`stub::ArtifactRuntime`] keeps the
//! same surface: `load` always errors, so artifact-dependent tests and
//! benches skip themselves exactly as they do when `make artifacts` has
//! not run.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use client::{ArtifactRuntime, Tensor};
#[cfg(not(feature = "pjrt"))]
pub use stub::{ArtifactRuntime, Tensor};
