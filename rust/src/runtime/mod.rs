//! PJRT runtime: load and execute the AOT artifacts.
//!
//! The compile path (python/compile/aot.py, run once by `make artifacts`)
//! lowers the L2 JAX pipelines to HLO *text*; this module is the request
//! path: a [`client::ArtifactRuntime`] compiles each artifact on the PJRT
//! CPU client at startup and executes it with concrete buffers — Python
//! never runs here. The emulation experiments use it to replay thousands
//! of device rounds as one batched call, cross-checked against the
//! pure-Rust twins in the integration tests.

pub mod client;

pub use client::{ArtifactRuntime, Tensor};
