//! Mini property-based testing kit.
//!
//! `proptest` is not in the offline crate set, so this module provides the
//! subset the test suite needs: seeded generators, a configurable number of
//! cases, and greedy input shrinking for failing cases. Properties are
//! plain closures over a [`Gen`]; on failure the kit re-runs the property
//! on progressively smaller inputs (via the generator's recorded choices)
//! and reports the smallest failing seed.
//!
//! ```
//! use aic::util::testkit::{property, Gen};
//! property("reverse twice is identity", 256, |g: &mut Gen| {
//!     let xs = g.vec_f64(0..=32, -1e3..1e3);
//!     let mut r = xs.clone();
//!     r.reverse();
//!     r.reverse();
//!     assert_eq!(r, xs);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::RangeInclusive;

/// Input generator handed to properties. Wraps an [`Rng`] and records a
/// size budget that shrinking reduces.
pub struct Gen {
    rng: Rng,
    /// Scale factor in (0, 1]; shrinking lowers it to shrink magnitudes
    /// and collection lengths.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), size: 1.0 }
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in an inclusive range, biased smaller as `size` shrinks.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        if lo >= hi {
            return lo;
        }
        let span = ((hi - lo) as f64 * self.size).max(1.0) as usize;
        lo + self.rng.index(span + 1).min(hi - lo)
    }

    /// i64 in an inclusive range.
    pub fn i64_in(&mut self, range: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*range.start(), *range.end());
        let span = (hi - lo) as u64;
        lo + self.rng.below(span + 1) as i64
    }

    /// f64 in a half-open range, magnitude scaled by `size`.
    pub fn f64_in(&mut self, range: std::ops::Range<f64>) -> f64 {
        let x = self.rng.range(range.start, range.end);
        x * self.size + (1.0 - self.size) * (range.start + range.end) / 2.0
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of f64 with random length in `len` and values in `vals`.
    pub fn vec_f64(
        &mut self,
        len: RangeInclusive<usize>,
        vals: std::ops::Range<f64>,
    ) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(vals.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` random cases of `prop`. Panics (with the failing seed and
/// shrink report) if any case fails. Property failures are signalled by
/// panicking inside the closure (e.g. `assert!`).
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    // Derive per-case seeds from the property name so adding properties
    // elsewhere does not perturb this one's inputs.
    let name_hash = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = name_hash.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let outcome = std::panic::catch_unwind(|| {
            // Silence the default panic hook output for expected probes.
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(payload) = outcome {
            // Shrink: retry with smaller size factors, keep the smallest failure.
            let mut smallest = 1.0f64;
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let failed = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed);
                    g.size = size;
                    prop(&mut g);
                })
                .is_err();
                if failed {
                    smallest = size;
                } else {
                    break;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 smallest failing size {smallest}): {msg}"
            );
        }
    }
}

/// Assert two campaigns of the same cell agree within the tolerance the
/// fixed-step reference integrator's own `charge_dt = 0.02` s
/// discretisation introduces: per-round outcomes, power-cycle counts and
/// ledger totals. Generic over the output type — the comparison is
/// structural (outputs may legitimately differ when boot-time jitter
/// shifts an acquisition across a scene boundary).
///
/// This is the engine-equivalence gate shared by
/// `tests/engine_equivalence.rs` (replay and kinetic supplies) and
/// `tests/synth_properties.rs` (generated synthetic environments).
pub fn assert_campaigns_close<O>(
    name: &str,
    a: &crate::exec::Campaign<O>,
    r: &crate::exec::Campaign<O>,
) {
    let du = |x: u64, y: u64| x.abs_diff(y);
    assert!(
        du(a.power_cycles, r.power_cycles) <= (r.power_cycles / 7).max(3),
        "{name}: power cycles {} (analytic) vs {} (reference)",
        a.power_cycles,
        r.power_cycles
    );
    assert!(
        du(a.power_failures, r.power_failures) <= (r.power_failures / 7).max(3),
        "{name}: failures {} vs {}",
        a.power_failures,
        r.power_failures
    );
    assert!(
        (a.rounds.len() as i64 - r.rounds.len() as i64).abs() <= 3,
        "{name}: rounds {} vs {}",
        a.rounds.len(),
        r.rounds.len()
    );
    let ea = a.app_energy + a.state_energy;
    let er = r.app_energy + r.state_energy;
    assert!(
        (ea - er).abs() / er.max(1e-12) < 0.08,
        "{name}: ledger total {ea} vs {er}"
    );
    let emitted_a = a.emitted().count() as i64;
    let emitted_r = r.emitted().count() as i64;
    assert!(
        (emitted_a - emitted_r).abs() <= 3,
        "{name}: emitted {emitted_a} vs {emitted_r}"
    );
    let aligned = a.rounds.len().min(r.rounds.len());
    let mut outcome_mismatches = 0usize;
    for (i, (ra, rr)) in a.rounds.iter().zip(r.rounds.iter()).enumerate() {
        if ra.emitted_at.is_some() != rr.emitted_at.is_some() {
            outcome_mismatches += 1;
        }
        assert!(
            (ra.steps_executed as i64 - rr.steps_executed as i64).abs() <= 12,
            "{name} round {i}: steps {} vs {}",
            ra.steps_executed,
            rr.steps_executed
        );
        // Boot-time jitter bounds the acquisition skew: one stride of
        // discretisation, amplified at worst by one burst gap on the
        // bursty traces (waiting out the next burst). Slot sleeps
        // re-align the engines every round, so skew does not compound.
        assert!(
            (ra.acquired_at - rr.acquired_at).abs() <= 30.0,
            "{name} round {i}: acquired at {} vs {}",
            ra.acquired_at,
            rr.acquired_at
        );
    }
    assert!(
        outcome_mismatches * 5 <= aligned.max(1),
        "{name}: {outcome_mismatches}/{aligned} rounds flipped emitted/dropped"
    );
}

/// Assert a checked run came back violation-free, with a readable dump
/// of what fired otherwise. The fault-injection suite calls this once
/// per (runtime, workload, engine, schedule) cell, so the label carries
/// the whole cell identity.
pub fn assert_no_violations(name: &str, violations: &[crate::exec::Violation]) {
    assert!(
        violations.is_empty(),
        "{name}: {} invariant violation(s): {:?}",
        violations.len(),
        violations
    );
}

/// How many randomized fault schedules per (runtime, workload) cell the
/// fault-injection suite runs. Defaults to `default`; widen (or narrow,
/// for a quick local iteration) with the `AIC_FAULT_SEEDS` environment
/// variable — CI pins it so runs are reproducible.
pub fn fault_seeds(default: u64) -> u64 {
    match std::env::var("AIC_FAULT_SEEDS") {
        Ok(s) => s.parse().unwrap_or(default),
        Err(_) => default,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("add commutes", 64, |g| {
            let a = g.f64_in(-1e6..1e6);
            let b = g.f64_in(-1e6..1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        property("always fails", 8, |g| {
            let v = g.usize_in(0..=10);
            assert!(v > 100, "v={v}");
        });
    }

    #[test]
    fn generator_ranges_respected() {
        property("ranges respected", 128, |g| {
            let n = g.usize_in(3..=7);
            assert!((3..=7).contains(&n));
            let x = g.f64_in(-2.0..2.0);
            assert!((-2.0..2.0).contains(&x));
            let v = g.vec_f64(0..=5, 0.0..1.0);
            assert!(v.len() <= 5);
        });
    }

    #[test]
    fn cases_are_deterministic() {
        // Two identically-named properties see identical inputs.
        let mut first = Vec::new();
        property("determinism probe", 4, |g| {
            // record by printing into a thread local
            FIRST.with(|f| f.borrow_mut().push(g.f64_in(0.0..1.0)));
        });
        FIRST.with(|f| first = f.borrow().clone());
        let mut second = Vec::new();
        property("determinism probe", 4, |g| {
            SECOND.with(|f| f.borrow_mut().push(g.f64_in(0.0..1.0)));
        });
        SECOND.with(|f| second = f.borrow().clone());
        assert_eq!(first, second);
    }

    thread_local! {
        static FIRST: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
        static SECOND: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
    }
}
