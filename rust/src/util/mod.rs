//! Foundational substrates.
//!
//! The offline build environment ships only the `xla` and `anyhow` crates,
//! so everything a comparable project would pull from crates.io is
//! implemented here as a first-class, tested module: a seeded PRNG with
//! distributions ([`rng`]), a minimal JSON reader/writer ([`json`]),
//! statistics / special functions / quadrature ([`stats`]), a radix-2 FFT
//! ([`fft`]), Q15 fixed-point arithmetic matching the paper's MCU
//! implementation ([`fixed`]), a property-based testing kit ([`testkit`]),
//! a command-line parser ([`cli`]) and a criterion-style benchmark harness
//! ([`bench`]).

pub mod bench;
pub mod cli;
pub mod dsp;
pub mod fft;
pub mod fixed;
pub mod json;
pub mod rng;
pub mod stats;
pub mod testkit;
