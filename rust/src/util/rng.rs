//! Deterministic pseudo-random number generation.
//!
//! All stochastic components of the simulator (energy traces, synthetic
//! sensor data, perforation choices, property tests) draw from this seeded
//! xoshiro256++ generator so every experiment is exactly reproducible from
//! its seed. The generator is the public-domain xoshiro256++ 1.0 by
//! Blackman & Vigna; seeds are expanded with SplitMix64 as recommended.

/// xoshiro256++ PRNG with convenience distributions.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a named sub-component.
    ///
    /// Streams derived with different tags are statistically independent;
    /// used to give each simulated device / volunteer its own substream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let b = self.next_u64().rotate_left(17) ^ tag;
        Rng::new(a ^ b.wrapping_mul(0xD1342543DE82EF95))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via the polar Box-Muller transform (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gaussian()
    }

    /// Exponential with the given rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - uniform() is in (0, 1], so ln never sees 0.
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var={m2}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
