//! Q15 fixed-point arithmetic.
//!
//! The paper's prototype runs on an MSP430 with no FPU; both classification
//! implementations use fixed point (§4.3). This module provides the
//! MCU-faithful arithmetic so the simulated device computes *exactly* what
//! the 16-bit hardware would, and tests can bound the Q15-vs-f32
//! classification disagreement.
//!
//! Q15: value = raw / 2^15, range [-1, 1). Dot products accumulate in a
//! 32-bit Q30 register exactly like the MSP430's hardware multiplier
//! (MPY32) would, then renormalise once — matching the prototype's
//! space-efficient inner loop.

/// A Q15 fixed-point number (16-bit, 15 fractional bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Q15(pub i16);

pub const Q15_ONE_RAW: i32 = 1 << 15;

impl Q15 {
    pub const MAX: Q15 = Q15(i16::MAX);
    pub const MIN: Q15 = Q15(i16::MIN);
    pub const ZERO: Q15 = Q15(0);

    /// Convert from f64, saturating to [-1, 1 - 2^-15].
    pub fn from_f64(x: f64) -> Q15 {
        let scaled = (x * Q15_ONE_RAW as f64).round();
        Q15(scaled.clamp(i16::MIN as f64, i16::MAX as f64) as i16)
    }

    pub fn to_f64(self) -> f64 {
        self.0 as f64 / Q15_ONE_RAW as f64
    }

    /// Saturating addition.
    pub fn sat_add(self, other: Q15) -> Q15 {
        Q15(self.0.saturating_add(other.0))
    }

    /// Saturating subtraction.
    pub fn sat_sub(self, other: Q15) -> Q15 {
        Q15(self.0.saturating_sub(other.0))
    }

    /// Q15 x Q15 -> Q15 with rounding, as the MSP430 MPY32 sequence does.
    pub fn mul(self, other: Q15) -> Q15 {
        let prod = self.0 as i32 * other.0 as i32; // Q30
        let rounded = (prod + (1 << 14)) >> 15;
        Q15(rounded.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }
}

/// A Q30 accumulator for long dot products (i64 backing register:
/// the MSP430 prototype chains the 32-bit MAC through a software-extended
/// 48-bit accumulator for n=140-length dot products; i64 is a superset).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Acc(pub i64);

impl Acc {
    pub const ZERO: Acc = Acc(0);

    /// Multiply-accumulate: acc += a * b (Q30 product, exact).
    #[inline]
    pub fn mac(&mut self, a: Q15, b: Q15) {
        self.0 += a.0 as i64 * b.0 as i64;
    }

    /// Collapse to Q15 with rounding and saturation.
    pub fn to_q15(self) -> Q15 {
        let rounded = (self.0 + (1 << 14)) >> 15;
        Q15(rounded.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    /// Exact value as f64 (Q30 scale).
    pub fn to_f64(self) -> f64 {
        self.0 as f64 / (Q15_ONE_RAW as f64 * Q15_ONE_RAW as f64)
    }
}

/// Fixed-point dot product over Q15 slices, returning the exact Q30 sum.
pub fn dot_q15(a: &[Q15], b: &[Q15]) -> Acc {
    assert_eq!(a.len(), b.len());
    let mut acc = Acc::ZERO;
    for (x, y) in a.iter().zip(b.iter()) {
        acc.mac(*x, *y);
    }
    acc
}

/// Quantise an f64 slice to Q15 with a shared scale factor so the largest
/// magnitude maps near +-1. Returns (values, scale) with x ~= q.to_f64()*scale.
pub fn quantise_slice(xs: &[f64]) -> (Vec<Q15>, f64) {
    let maxab = xs.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    let scale = if maxab == 0.0 { 1.0 } else { maxab * 1.0001 };
    (xs.iter().map(|x| Q15::from_f64(x / scale)).collect(), scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_lsb() {
        for i in -100..=100 {
            let x = i as f64 / 101.0;
            let q = Q15::from_f64(x);
            assert!((q.to_f64() - x).abs() <= 1.0 / Q15_ONE_RAW as f64, "x={x}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(Q15::from_f64(5.0), Q15::MAX);
        assert_eq!(Q15::from_f64(-5.0), Q15::MIN);
        assert_eq!(Q15::MAX.sat_add(Q15::MAX), Q15::MAX);
        assert_eq!(Q15::MIN.sat_sub(Q15::MAX), Q15::MIN);
    }

    #[test]
    fn mul_matches_float_within_lsb() {
        let cases = [(0.5, 0.5), (0.25, -0.75), (-0.99, -0.99), (0.1, 0.3)];
        for (a, b) in cases {
            let q = Q15::from_f64(a).mul(Q15::from_f64(b));
            assert!((q.to_f64() - a * b).abs() < 2.0 / Q15_ONE_RAW as f64, "{a}*{b}");
        }
    }

    #[test]
    fn dot_product_accuracy() {
        let mut rng = crate::util::rng::Rng::new(21);
        let a: Vec<f64> = (0..140).map(|_| rng.range(-0.08, 0.08)).collect();
        let b: Vec<f64> = (0..140).map(|_| rng.range(-0.08, 0.08)).collect();
        let qa: Vec<Q15> = a.iter().map(|&x| Q15::from_f64(x)).collect();
        let qb: Vec<Q15> = b.iter().map(|&x| Q15::from_f64(x)).collect();
        let exact: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got = dot_q15(&qa, &qb).to_f64();
        // 140 products, each with ~2^-16 quantisation error on each operand.
        assert!((got - exact).abs() < 1e-3, "got={got} exact={exact}");
    }

    #[test]
    fn quantise_slice_preserves_ratios() {
        let xs = [3.0, -1.5, 0.75, 6.0];
        let (qs, scale) = quantise_slice(&xs);
        for (q, x) in qs.iter().zip(&xs) {
            assert!((q.to_f64() * scale - x).abs() < scale / 16384.0);
        }
    }

    #[test]
    fn acc_collapse_rounds() {
        let mut acc = Acc::ZERO;
        acc.mac(Q15::from_f64(0.5), Q15::from_f64(0.5));
        assert!((acc.to_q15().to_f64() - 0.25).abs() < 1.0 / Q15_ONE_RAW as f64);
    }
}
