//! Digital filter primitives: biquad sections, classic designs, and the
//! Goertzel single-bin DFT.
//!
//! Used by the HAR preprocessing chain (3rd-order Butterworth low-pass at
//! 20 Hz and the gravity-separation low-pass, §4.2), by the kinetic
//! harvester model (resonant transducer = band-pass around the ReVibe
//! modelQ's customised resonance frequency), and by the acoustic event
//! detector's anytime band-energy probes ([`goertzel_power`]).

use std::f64::consts::PI;

/// Direct-form-II-transposed biquad section.
#[derive(Clone, Copy, Debug)]
pub struct Biquad {
    pub b0: f64,
    pub b1: f64,
    pub b2: f64,
    pub a1: f64,
    pub a2: f64,
    z1: f64,
    z2: f64,
}

impl Biquad {
    pub fn new(b0: f64, b1: f64, b2: f64, a1: f64, a2: f64) -> Biquad {
        Biquad { b0, b1, b2, a1, a2, z1: 0.0, z2: 0.0 }
    }

    /// Identity (pass-through) section.
    pub fn identity() -> Biquad {
        Biquad::new(1.0, 0.0, 0.0, 0.0, 0.0)
    }

    /// RBJ cookbook 2nd-order Butterworth low-pass (Q = 1/√2).
    pub fn lowpass(fc: f64, fs: f64) -> Biquad {
        Biquad::lowpass_q(fc, fs, std::f64::consts::FRAC_1_SQRT_2)
    }

    /// RBJ low-pass with explicit Q (used for higher-order cascades).
    pub fn lowpass_q(fc: f64, fs: f64, q: f64) -> Biquad {
        let w0 = 2.0 * PI * fc / fs;
        let alpha = w0.sin() / (2.0 * q);
        let cw = w0.cos();
        let a0 = 1.0 + alpha;
        Biquad::new(
            (1.0 - cw) / 2.0 / a0,
            (1.0 - cw) / a0,
            (1.0 - cw) / 2.0 / a0,
            -2.0 * cw / a0,
            (1.0 - alpha) / a0,
        )
    }

    /// First-order low-pass realised as a biquad (for odd-order cascades).
    pub fn lowpass_first_order(fc: f64, fs: f64) -> Biquad {
        // Bilinear transform of H(s) = 1/(1 + s/wc).
        let k = (PI * fc / fs).tan();
        let a0 = k + 1.0;
        Biquad::new(k / a0, k / a0, 0.0, (k - 1.0) / a0, 0.0)
    }

    /// RBJ constant-skirt band-pass (peak gain = Q).
    pub fn bandpass(f0: f64, fs: f64, q: f64) -> Biquad {
        let w0 = 2.0 * PI * f0 / fs;
        let alpha = w0.sin() / (2.0 * q);
        let a0 = 1.0 + alpha;
        Biquad::new(
            q * alpha / a0,
            0.0,
            -q * alpha / a0,
            -2.0 * w0.cos() / a0,
            (1.0 - alpha) / a0,
        )
    }

    /// Process one sample.
    #[inline]
    pub fn step(&mut self, x: f64) -> f64 {
        let y = self.b0 * x + self.z1;
        self.z1 = self.b1 * x - self.a1 * y + self.z2;
        self.z2 = self.b2 * x - self.a2 * y;
        y
    }

    /// Reset internal state.
    pub fn reset(&mut self) {
        self.z1 = 0.0;
        self.z2 = 0.0;
    }
}

/// A cascade of biquad sections.
#[derive(Clone, Debug)]
pub struct Cascade {
    pub sections: Vec<Biquad>,
}

impl Cascade {
    /// N-th order Butterworth low-pass as cascaded sections, following the
    /// standard pole-pairing (Q_k = 1/(2 sin((2k+1)π/2N)) for each pair,
    /// plus one first-order section when N is odd).
    pub fn butterworth_lowpass(order: usize, fc: f64, fs: f64) -> Cascade {
        assert!(order >= 1);
        let mut sections = Vec::new();
        let pairs = order / 2;
        for k in 0..pairs {
            let q = 1.0 / (2.0 * ((2 * k + 1) as f64 * PI / (2.0 * order as f64)).sin());
            sections.push(Biquad::lowpass_q(fc, fs, q));
        }
        if order % 2 == 1 {
            sections.push(Biquad::lowpass_first_order(fc, fs));
        }
        Cascade { sections }
    }

    #[inline]
    pub fn step(&mut self, x: f64) -> f64 {
        self.sections.iter_mut().fold(x, |acc, s| s.step(acc))
    }

    /// Filter a whole signal (stateful; call [`reset`] between signals).
    pub fn filter(&mut self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.step(x)).collect()
    }

    pub fn reset(&mut self) {
        for s in &mut self.sections {
            s.reset();
        }
    }
}

/// Squared DFT magnitude `|X[k]|²` of `x` at integer bin `k` via the
/// Goertzel recurrence: one O(N) pass, no twiddle table — the classic
/// way an MCU evaluates a handful of spectral bins without paying for a
/// full FFT. Exactly equals the corresponding bin of
/// [`crate::util::fft::dft_naive`] up to float rounding.
///
/// The recurrence `s₀ = x + a·s₁ − s₂` is serially dependent, so the
/// plain loop cannot vectorise. This version expands the state
/// transition over four samples: with `a = 2cos(w)` the 2×2 companion
/// matrix powers have Chebyshev-recurrence entries `c₂ = a²−1`,
/// `c₃ = a·c₂−a`, `c₄ = a·c₃−c₂`, giving
///
/// ```text
/// s₁' = x₃ + a·x₂ + c₂·x₁ + c₃·x₀ + c₄·s₁ − c₃·s₂
/// s₂' = x₂ + a·x₁ + c₂·x₀ + c₃·s₁ − c₂·s₂
/// ```
///
/// per 4-sample chunk — two independent fused dot products the compiler
/// can schedule wide (safe code, no `unsafe`). The scalar reference is
/// retained as [`goertzel_power_scalar`]; `tests/kernel_equivalence.rs`
/// bounds the (reassociation-only) difference between the two.
pub fn goertzel_power(x: &[f64], k: usize) -> f64 {
    let n = x.len() as f64;
    let w = 2.0 * PI * k as f64 / n;
    let a = 2.0 * w.cos();
    let c2 = a * a - 1.0;
    let c3 = a * c2 - a;
    let c4 = a * c3 - c2;
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    let mut chunks = x.chunks_exact(4);
    for c in &mut chunks {
        let (x0, x1, x2, x3) = (c[0], c[1], c[2], c[3]);
        let t1 = x3 + a * x2 + c2 * x1 + c3 * x0 + c4 * s1 - c3 * s2;
        let t2 = x2 + a * x1 + c2 * x0 + c3 * s1 - c2 * s2;
        s1 = t1;
        s2 = t2;
    }
    for &xi in chunks.remainder() {
        let s0 = xi + a * s1 - s2;
        s2 = s1;
        s1 = s0;
    }
    s1 * s1 + s2 * s2 - a * s1 * s2
}

/// The scalar reference for [`goertzel_power`]: the textbook
/// one-sample-at-a-time recurrence. Kept (and kept exercised by the
/// kernel-equivalence suite) so the chunked kernel is verified against
/// it rather than eyeballed.
pub fn goertzel_power_scalar(x: &[f64], k: usize) -> f64 {
    let n = x.len() as f64;
    let w = 2.0 * PI * k as f64 / n;
    let coeff = 2.0 * w.cos();
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    for &xi in x {
        let s0 = xi + coeff * s1 - s2;
        s2 = s1;
        s1 = s0;
    }
    s1 * s1 + s2 * s2 - coeff * s1 * s2
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Steady-state gain of a filter at frequency f (empirical).
    fn gain_at(cascade: &mut Cascade, f: f64, fs: f64) -> f64 {
        cascade.reset();
        let n = (fs * 4.0) as usize;
        let mut max_out: f64 = 0.0;
        for i in 0..n {
            let x = (2.0 * PI * f * i as f64 / fs).sin();
            let y = cascade.step(x);
            if i > n / 2 {
                max_out = max_out.max(y.abs());
            }
        }
        max_out
    }

    #[test]
    fn butterworth3_passband_and_stopband() {
        let fs = 50.0;
        let mut c = Cascade::butterworth_lowpass(3, 20.0, fs);
        assert_eq!(c.sections.len(), 2); // one biquad + one 1st-order
        // Passband: 2 Hz nearly unity.
        assert!((gain_at(&mut c, 2.0, fs) - 1.0).abs() < 0.02);
        // Cutoff: -3 dB.
        let g = gain_at(&mut c, 20.0, fs);
        assert!((g - std::f64::consts::FRAC_1_SQRT_2).abs() < 0.05, "g={g}");
        // 24 Hz (close to Nyquist): attenuated.
        assert!(gain_at(&mut c, 24.0, fs) < 0.4);
    }

    #[test]
    fn dc_gain_is_unity() {
        let mut c = Cascade::butterworth_lowpass(3, 20.0, 50.0);
        let mut y = 0.0;
        for _ in 0..2000 {
            y = c.step(1.0);
        }
        assert!((y - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bandpass_selects_resonance() {
        let fs = 50.0;
        let mut bp = Cascade { sections: vec![Biquad::bandpass(2.0, fs, 3.0)] };
        let at_res = gain_at(&mut bp, 2.0, fs);
        let below = gain_at(&mut bp, 0.3, fs);
        let above = gain_at(&mut bp, 10.0, fs);
        assert!(at_res > 4.0 * below, "res={at_res} below={below}");
        assert!(at_res > 4.0 * above, "res={at_res} above={above}");
    }

    #[test]
    fn filter_is_stateful_then_resettable() {
        let mut c = Cascade::butterworth_lowpass(2, 5.0, 50.0);
        let a = c.filter(&[1.0, 1.0, 1.0]);
        c.reset();
        let b = c.filter(&[1.0, 1.0, 1.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn goertzel_matches_naive_dft_power() {
        let mut rng = crate::util::rng::Rng::new(21);
        let x: Vec<f64> = (0..128).map(|_| rng.gaussian()).collect();
        let (re, im) = crate::util::fft::dft_naive(&x);
        for k in [0usize, 1, 5, 16, 29, 51, 63, 64] {
            let want = re[k] * re[k] + im[k] * im[k];
            let got = goertzel_power(&x, k);
            assert!(
                (got - want).abs() < 1e-6 * want.max(1.0),
                "bin {k}: goertzel {got} vs dft {want}"
            );
        }
    }

    #[test]
    fn chunked_goertzel_matches_scalar_across_remainders() {
        // Lengths 1..16 cover every chunks_exact(4) remainder shape.
        let mut rng = crate::util::rng::Rng::new(9);
        for n in 1..16usize {
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            for k in 0..n {
                let scalar = goertzel_power_scalar(&x, k);
                let chunked = goertzel_power(&x, k);
                assert!(
                    (chunked - scalar).abs() <= 1e-10 * scalar.abs().max(1.0),
                    "n={n} k={k}: chunked {chunked} vs scalar {scalar}"
                );
            }
        }
    }

    #[test]
    fn goertzel_isolates_integer_bin_tones() {
        // A real sinusoid at integer bin k contributes zero energy to
        // every other interior integer bin, for any phase — the
        // orthogonality the audio detector's deterministic margins rely
        // on.
        let n = 128;
        for phase in [0.0, 0.7, 2.3] {
            let x: Vec<f64> = (0..n)
                .map(|i| (2.0 * PI * 22.0 * i as f64 / n as f64 + phase).sin())
                .collect();
            let want = (n as f64 / 2.0).powi(2);
            let on = goertzel_power(&x, 22);
            assert!((on - want).abs() < 1e-6 * want, "on-bin {on}");
            for k in [1usize, 21, 23, 40, 63] {
                let off = goertzel_power(&x, k);
                assert!(off < 1e-9, "phase {phase}: bin {k} leaked {off}");
            }
        }
    }
}
