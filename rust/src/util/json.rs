//! Minimal JSON reader/writer.
//!
//! Serde is not available in the offline crate set; this hand-rolled JSON
//! module covers the interchange needs of the repo: the AOT artifact
//! manifest (`artifacts/manifest.json`), experiment configuration files,
//! and figure-data reports. Full JSON grammar except `\u` surrogate pairs
//! are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic serialisation.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict unsigned-integer view: rejects negatives and fractions
    /// (scenario seeds and sizes must round-trip exactly).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; Null when out of range.
    pub fn at(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn nums(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    /// An array of non-negative integers (exact in `Num` below 2^53 —
    /// the experiment store's count payloads).
    pub fn u64s(xs: &[u64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    /// Strictly-typed `u64` array accessor — `None` unless every element
    /// is a non-negative integer (the inverse of [`Value::u64s`]).
    pub fn as_u64s(&self) -> Option<Vec<u64>> {
        self.as_arr()?.iter().map(Value::as_u64).collect()
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

// Typed optional accessors shared by every JSON-spec reader (scenarios,
// synth environments): a missing key is `Ok(None)`, a present-but-
// mistyped value is a hard error — never a silent fall-back to a
// default (the same contract unknown-key checks enforce).

pub fn opt_str<'a>(v: &'a Value, key: &str) -> Result<Option<&'a str>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => other.as_str().map(Some).ok_or_else(|| format!("'{key}' must be a string")),
    }
}

pub fn opt_f64(v: &Value, key: &str) -> Result<Option<f64>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => other.as_f64().map(Some).ok_or_else(|| format!("'{key}' must be a number")),
    }
}

pub fn opt_usize(v: &Value, key: &str) -> Result<Option<usize>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => other
            .as_u64()
            .map(|n| Some(n as usize))
            .ok_or_else(|| format!("'{key}' must be an unsigned integer")),
    }
}

pub fn opt_u64(v: &Value, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => {
            other.as_u64().map(Some).ok_or_else(|| format!("'{key}' must be an unsigned integer"))
        }
    }
}

pub fn opt_bool(v: &Value, key: &str) -> Result<Option<bool>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => other.as_bool().map(Some).ok_or_else(|| format!("'{key}' must be a boolean")),
    }
}

pub fn opt_arr<'a>(v: &'a Value, key: &str) -> Result<Option<&'a [Value]>, String> {
    match v.get(key) {
        Value::Null => Ok(None),
        other => other.as_arr().map(Some).ok_or_else(|| format!("'{key}' must be an array")),
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

/// Maximum container nesting the parser accepts. The recursive-descent
/// parser uses the call stack, so unbounded nesting would turn a hostile
/// document (`[[[[...`) into a stack overflow — an abort, not an `Err`.
/// No legitimate document in this repo nests beyond a handful of levels.
const MAX_DEPTH: usize = 128;

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            // JSON has no Inf/NaN: a literal that overflows f64 (1e999)
            // must be an error, not a silent infinity that later leaks
            // into scenario horizons or energy budgets.
            Ok(n) if n.is_finite() => Ok(Value::Num(n)),
            Ok(_) => Err(self.err("number out of range")),
            Err(_) => Err(self.err("bad number")),
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

/// Serialise a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

/// Serialise with two-space indentation (for human-readable reports).
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_pretty(v, &mut s, 0);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Value, out: &mut String, depth: usize) {
    match v {
        Value::Arr(items) if !items.is_empty() => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                for _ in 0..=depth {
                    out.push_str("  ");
                }
                write_pretty(item, out, depth + 1);
            }
            out.push('\n');
            for _ in 0..depth {
                out.push_str("  ");
            }
            out.push(']');
        }
        Value::Obj(map) if !map.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                for _ in 0..=depth {
                    out.push_str("  ");
                }
                write_str(k, out);
                out.push_str(": ");
                write_pretty(val, out, depth + 1);
            }
            out.push('\n');
            for _ in 0..depth {
                out.push_str("  ");
            }
            out.push('}');
        }
        other => write_value(other, out),
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.0));
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Value::Null);
        assert_eq!(*v.get("missing"), Value::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_hostile_nesting_without_overflowing() {
        // Just inside the limit parses; past it errors instead of
        // blowing the stack.
        let deep_ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep_ok).is_ok());
        let deep_bad = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(parse(&deep_bad).is_err());
        let mixed = "[{\"k\":".repeat(50_000) + "1" + &"}]".repeat(50_000);
        assert!(parse(&mixed).is_err());
    }

    #[test]
    fn rejects_non_finite_numbers() {
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
        assert!(parse("NaN").is_err());
        assert!(parse("Infinity").is_err());
        assert!(parse("-Infinity").is_err());
        assert!(parse("{\"x\": 1e400}").is_err());
        // Ordinary large-but-finite numbers still parse.
        assert_eq!(parse("1e300").unwrap(), Value::Num(1e300));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Value::obj(vec![
            ("name", "fig5".into()),
            ("series", Value::nums(&[1.0, 2.0, 3.5])),
        ]);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_serialised_without_fraction() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }
}
