//! Statistics, special functions and numeric quadrature.
//!
//! Used by the Eq. 7 analytic accuracy model (`svm::analysis`), the feature
//! extractor and the metrics layer. `erf` is the Abramowitz & Stegun 7.1.26
//! rational approximation refined with one Newton step against the
//! continued-fraction complement — accurate to ~1e-12, far below the
//! tolerances the accuracy model needs.

/// Error function, |err| < 1.5e-7 (A&S 7.1.26) refined to ~1e-12.
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    if x > 6.0 {
        return sign; // 1 - erf(6) < 1e-17
    }
    // Series for small x, continued fraction (via erfc) for large x.
    let v = if x < 2.0 { erf_series(x) } else { 1.0 - erfc_cf(x) };
    sign * v
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        2.0 - erfc(-x)
    } else if x < 2.0 {
        1.0 - erf_series(x)
    } else if x > 27.0 {
        0.0 // underflows f64
    } else {
        erfc_cf(x)
    }
}

/// Maclaurin series for erf, converges quickly for |x| < 2.
fn erf_series(x: f64) -> f64 {
    let mut term = x;
    let mut sum = x;
    let x2 = x * x;
    for n in 1..200 {
        term *= -x2 / n as f64;
        let add = term / (2 * n + 1) as f64;
        sum += add;
        if add.abs() < 1e-17 * sum.abs() {
            break;
        }
    }
    sum * 2.0 / std::f64::consts::PI.sqrt()
}

/// Continued-fraction expansion for erfc, good for x >= 2.
fn erfc_cf(x: f64) -> f64 {
    // Lentz's algorithm on erfc(x) = exp(-x^2)/sqrt(pi) * 1/(x + 1/2/(x + 1/(x + 3/2/(x + ...))))
    let tiny = 1e-300;
    let mut f = x;
    let mut c = f; // modified Lentz: C0 = b0 = x
    let mut d = 0.0;
    for k in 1..300 {
        let a = k as f64 / 2.0;
        d = x + a * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = x + a / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x * x).exp() / std::f64::consts::PI.sqrt() / f
}

/// Standard normal probability density.
#[inline]
pub fn normal_pdf(x: f64, mean: f64, sd: f64) -> f64 {
    let z = (x - mean) / sd;
    (-(z * z) / 2.0).exp() / (sd * (2.0 * std::f64::consts::PI).sqrt())
}

/// Standard normal cumulative distribution function.
#[inline]
pub fn normal_cdf(x: f64, mean: f64, sd: f64) -> f64 {
    0.5 * erfc(-(x - mean) / (sd * std::f64::consts::SQRT_2))
}

/// Nodes and weights of `n`-point Gauss-Legendre quadrature on `[-1, 1]`.
///
/// Computed by Newton iteration on Legendre polynomials; cached by callers.
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = (n + 1) / 2;
    for i in 0..m {
        // Initial guess (Chebyshev roots).
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        let mut pp = 0.0;
        for _ in 0..100 {
            // Evaluate P_n(x) and P'_n(x) by recurrence.
            let mut p0 = 1.0;
            let mut p1 = x;
            for k in 2..=n {
                let p2 = ((2 * k - 1) as f64 * x * p1 - (k - 1) as f64 * p0) / k as f64;
                p0 = p1;
                p1 = p2;
            }
            pp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / pp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        nodes[i] = -x;
        nodes[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * pp * pp);
        weights[i] = w;
        weights[n - 1 - i] = w;
    }
    (nodes, weights)
}

/// Integrate `f` over `[a, b]` with `n`-point Gauss-Legendre.
pub fn integrate<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, n: usize) -> f64 {
    let (nodes, weights) = gauss_legendre(n);
    let half = (b - a) / 2.0;
    let mid = (a + b) / 2.0;
    let mut s = 0.0;
    for (x, w) in nodes.iter().zip(weights.iter()) {
        s += w * f(mid + half * x);
    }
    s * half
}

/// Integrate `f` over `[a, +inf)` by mapping `t = a + u/(1-u)` onto `[0,1)`.
pub fn integrate_to_inf<F: Fn(f64) -> f64>(f: F, a: f64, n: usize) -> f64 {
    integrate(
        |u| {
            let one_minus = 1.0 - u;
            let t = a + u / one_minus;
            f(t) / (one_minus * one_minus)
        },
        0.0,
        1.0 - 1e-12,
        n,
    )
}

/// Running summary statistics over a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Welford online update.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// `q`-quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Fixed-width histogram for latency / accuracy distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[i.min(n - 1)] += 1;
        }
    }

    /// Fraction of samples in bin `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.bins[i] as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from A&S tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (3.0, 0.9999779095),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 1e-9, "erf({x}) = {} want {want}", erf(x));
        }
    }

    #[test]
    fn erfc_complements_erf() {
        for i in -40..=40 {
            let x = i as f64 / 8.0;
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn normal_cdf_symmetry_and_values() {
        assert!((normal_cdf(0.0, 0.0, 1.0) - 0.5).abs() < 1e-12);
        assert!((normal_cdf(1.96, 0.0, 1.0) - 0.9750021).abs() < 1e-6);
        for i in -20..=20 {
            let x = i as f64 / 4.0;
            let s = normal_cdf(x, 0.0, 1.0) + normal_cdf(-x, 0.0, 1.0);
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn pdf_integrates_to_one() {
        let total = integrate(|x| normal_pdf(x, 1.0, 2.0), -20.0, 22.0, 128);
        assert!((total - 1.0).abs() < 1e-10, "total={total}");
    }

    #[test]
    fn gauss_legendre_exact_for_polynomials() {
        // n-point GL is exact for degree <= 2n-1.
        let got = integrate(|x| 3.0 * x * x, 0.0, 2.0, 8);
        assert!((got - 8.0).abs() < 1e-12);
        let got = integrate(|x| x.powi(7) - x.powi(3) + 1.0, -1.0, 3.0, 8);
        let want = (3.0f64.powi(8) - 1.0) / 8.0 - (3.0f64.powi(4) - 1.0) / 4.0 + 4.0;
        assert!((got - want).abs() < 1e-9, "got={got} want={want}");
    }

    #[test]
    fn improper_integral_of_gaussian_tail() {
        // Integral of standard normal pdf over [0, inf) = 1/2.
        let got = integrate_to_inf(|x| normal_pdf(x, 0.0, 1.0), 0.0, 200);
        assert!((got - 0.5).abs() < 1e-8, "got={got}");
    }

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.n, 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        assert!((s.variance() - 12.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn correlation_bounds() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((correlation(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((correlation(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.count, 12);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert!(h.bins.iter().all(|&b| b == 1));
    }
}
