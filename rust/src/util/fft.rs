//! Radix-2 FFT for the Rust-side (MCU-faithful) feature extractor.
//!
//! The paper's HAR pipeline computes FFT-derived features (band energies,
//! spectral centroid) on-device; this module is the Rust twin of the
//! DFT-as-matmul Pallas kernel (`python/compile/kernels/features.py`).
//! Iterative in-place Cooley-Tukey, power-of-two lengths only — windows in
//! this codebase are 128 samples.

use std::f64::consts::PI;

/// In-place iterative radix-2 FFT over interleaved (re, im) pairs.
///
/// `re.len()` must be a power of two. Forward transform; no normalisation
/// (matches numpy's convention).
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    // Butterflies.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cr - im[i + k + len / 2] * ci,
                    re[i + k + len / 2] * ci + im[i + k + len / 2] * cr,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Magnitude spectrum of a real signal: `|FFT(x)|` for bins `0..n/2+1`.
pub fn magnitude_spectrum(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut re = x.to_vec();
    let mut im = vec![0.0; n];
    fft_inplace(&mut re, &mut im);
    (0..=n / 2).map(|k| (re[k] * re[k] + im[k] * im[k]).sqrt()).collect()
}

/// Power spectral density estimate (periodogram, no window).
pub fn power_spectrum(x: &[f64]) -> Vec<f64> {
    let n = x.len() as f64;
    magnitude_spectrum(x).iter().map(|m| m * m / n).collect()
}

/// Naive O(n^2) DFT used as a test oracle and as the exact twin of the
/// DFT-matrix Pallas kernel.
pub fn dft_naive(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.len();
    let mut re = vec![0.0; n];
    let mut im = vec![0.0; n];
    for (k, (rk, ik)) in re.iter_mut().zip(im.iter_mut()).enumerate() {
        for (j, &xj) in x.iter().enumerate() {
            let ang = -2.0 * PI * (k * j) as f64 / n as f64;
            *rk += xj * ang.cos();
            *ik += xj * ang.sin();
        }
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f64> = (0..64).map(|_| rng.gaussian()).collect();
        let (re_ref, im_ref) = dft_naive(&x);
        let mut re = x.clone();
        let mut im = vec![0.0; 64];
        fft_inplace(&mut re, &mut im);
        for k in 0..64 {
            assert!((re[k] - re_ref[k]).abs() < 1e-9, "re[{k}]");
            assert!((im[k] - im_ref[k]).abs() < 1e-9, "im[{k}]");
        }
    }

    #[test]
    fn pure_tone_peaks_at_its_bin() {
        let n = 128;
        let f = 10; // bin index
        let x: Vec<f64> =
            (0..n).map(|i| (2.0 * PI * f as f64 * i as f64 / n as f64).sin()).collect();
        let mag = magnitude_spectrum(&x);
        let peak = mag
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, f);
        assert!((mag[f] - n as f64 / 2.0).abs() < 1e-6);
    }

    #[test]
    fn parseval_energy_identity() {
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f64> = (0..128).map(|_| rng.gaussian()).collect();
        let time_energy: f64 = x.iter().map(|v| v * v).sum();
        let mut re = x.clone();
        let mut im = vec![0.0; 128];
        fft_inplace(&mut re, &mut im);
        let freq_energy: f64 =
            re.iter().zip(im.iter()).map(|(r, i)| r * r + i * i).sum::<f64>() / 128.0;
        assert!((time_energy - freq_energy).abs() < 1e-8 * time_energy);
    }

    #[test]
    fn dc_signal() {
        let x = vec![3.0; 32];
        let mag = magnitude_spectrum(&x);
        assert!((mag[0] - 96.0).abs() < 1e-9);
        for &m in &mag[1..] {
            assert!(m.abs() < 1e-9);
        }
    }
}
