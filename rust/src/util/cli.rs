//! Tiny command-line parser for the `aic` binary and the examples.
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; typed getters with defaults; and usage synthesis. Clap is not
//! in the offline crate set.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    ///
    /// `--name value` is ambiguous between a boolean flag followed by a
    /// positional and an option with a value; callers that use boolean
    /// flags pass them in `bool_flags` to disambiguate (the clap
    /// equivalent of declaring `ArgAction::SetTrue`).
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        items: I,
        bool_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// Parse with no declared boolean flags.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        Args::parse_with_flags(items, &[])
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse the process arguments with declared boolean flags.
    pub fn from_env_with_flags(bool_flags: &[&str]) -> Args {
        Args::parse_with_flags(std::env::args().skip(1), bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// First positional argument (the subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// The i-th positional argument (0 = the subcommand) — lets
    /// `aic sweep file.json` spell the scenario path without a flag.
    pub fn positional_at(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_with_flags(s.split_whitespace().map(|t| t.to_string()), &["verbose", "dry-run"])
    }

    #[test]
    fn parses_mixed_forms() {
        let a = args("run --trace rf --steps=100 --verbose out.csv");
        assert_eq!(a.command(), Some("run"));
        assert_eq!(a.get("trace"), Some("rf"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "out.csv"]);
    }

    #[test]
    fn defaults() {
        let a = args("bench");
        assert_eq!(a.get_or("trace", "som"), "som");
        assert_eq!(a.get_f64("bound", 0.8), 0.8);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn positional_access() {
        let a = args("sweep grid.json");
        assert_eq!(a.command(), Some("sweep"));
        assert_eq!(a.positional_at(1), Some("grid.json"));
        assert_eq!(a.positional_at(2), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("--dry-run --seed 9");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_u64("seed", 0), 9);
    }
}
