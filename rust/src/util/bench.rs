//! Criterion-style benchmark harness.
//!
//! Criterion is not in the offline crate set; this harness provides the
//! workflow `cargo bench` expects from the figure benches: named benchmark
//! groups, warm-up, multiple timed samples, mean / p50 / p99 reporting,
//! throughput units, and a machine-readable JSON line per benchmark
//! (consumed by `EXPERIMENTS.md` tooling).
//!
//! Figure benches also use [`Bench::report_table`] to print the rows/series
//! a paper figure reports; those are *measurements of the simulated
//! system*, not wall-clock timings.

use std::time::{Duration, Instant};

/// A benchmark runner with fixed sample counts (deterministic duration).
pub struct Bench {
    /// Benchmark binary name printed in headers.
    pub name: String,
    warmup_iters: u32,
    samples: u32,
}

/// Prevent the optimiser from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // Honour quick runs: AIC_BENCH_FAST=1 reduces sample counts (CI).
        let fast = std::env::var("AIC_BENCH_FAST").is_ok();
        Bench {
            name: name.to_string(),
            warmup_iters: if fast { 1 } else { 3 },
            samples: if fast { 5 } else { 15 },
        }
    }

    /// Time `f`, which performs one logical iteration, over the configured
    /// number of samples. Prints a criterion-like summary line.
    pub fn bench<F: FnMut()>(&self, id: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        let stats = Stats::from_times(&times);
        println!(
            "{:<44} time: [{} {} {}]",
            format!("{}/{}", self.name, id),
            fmt_dur(stats.min),
            fmt_dur(stats.mean),
            fmt_dur(stats.max),
        );
        println!(
            "  {{\"bench\":\"{}/{}\",\"mean_ns\":{},\"p50_ns\":{},\"p99_ns\":{},\"samples\":{}}}",
            self.name,
            id,
            stats.mean.as_nanos(),
            stats.p50.as_nanos(),
            stats.p99.as_nanos(),
            times.len()
        );
        stats
    }

    /// Like [`bench`] but reports throughput in `elems/s` given the number
    /// of logical elements one iteration processes.
    pub fn bench_throughput<F: FnMut()>(&self, id: &str, elems: u64, mut f: F) -> Stats {
        let stats = self.bench(id, &mut f);
        let per_sec = elems as f64 / stats.mean.as_secs_f64();
        println!("  thrpt: {:.3e} elem/s", per_sec);
        stats
    }

    /// Print a paper-figure data table (markdown) under this bench's name.
    pub fn report_table(&self, title: &str, header: &[&str], rows: &[Vec<String>]) {
        println!("\n## {} — {}", self.name, title);
        println!("| {} |", header.join(" | "));
        println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in rows {
            println!("| {} |", row.join(" | "));
        }
        println!();
    }
}

/// Timing statistics for one benchmark id.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub min: Duration,
    pub max: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

impl Stats {
    fn from_times(times: &[Duration]) -> Stats {
        let mut sorted = times.to_vec();
        sorted.sort();
        let total: Duration = sorted.iter().sum();
        let q = |f: f64| sorted[((sorted.len() - 1) as f64 * f) as usize];
        Stats {
            min: sorted[0],
            max: *sorted.last().unwrap(),
            mean: total / sorted.len() as u32,
            p50: q(0.5),
            p99: q(0.99),
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let times = vec![
            Duration::from_nanos(10),
            Duration::from_nanos(30),
            Duration::from_nanos(20),
        ];
        let s = Stats::from_times(&times);
        assert_eq!(s.min, Duration::from_nanos(10));
        assert_eq!(s.max, Duration::from_nanos(30));
        assert_eq!(s.mean, Duration::from_nanos(20));
    }

    #[test]
    fn bench_runs_closure() {
        std::env::set_var("AIC_BENCH_FAST", "1");
        let b = Bench::new("test");
        let mut count = 0u32;
        b.bench("noop", || count += 1);
        assert!(count >= 6); // warmup + samples
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
    }
}
