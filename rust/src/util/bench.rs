//! Criterion-style benchmark harness.
//!
//! Criterion is not in the offline crate set; this harness provides the
//! workflow `cargo bench` expects from the figure benches: named benchmark
//! groups, warm-up, multiple timed samples, mean / p50 / p99 reporting,
//! throughput units, and a machine-readable JSON line per benchmark.
//!
//! Beyond the per-measurement lines, the harness aggregates every
//! measurement of a run into a single JSON artifact when
//! `AIC_BENCH_OUT=<path>` is set: results are merged into the file under
//! the bench binary's name, so `AIC_BENCH_OUT=BENCH.json cargo bench`
//! produces one artifact for the whole suite. The committed
//! `BENCH_before.json` / `BENCH_after.json` perf baselines are produced
//! this way (see EXPERIMENTS.md §Perf); `AIC_ENGINE` is recorded so
//! analytic and fixed-step reference runs are distinguishable.
//!
//! Figure benches also use [`Bench::report_table`] to print the rows/series
//! a paper figure reports; those are *measurements of the simulated
//! system*, not wall-clock timings.

use crate::util::json::{self, Value};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A benchmark runner with fixed sample counts (deterministic duration).
pub struct Bench {
    /// Benchmark binary name printed in headers.
    pub name: String,
    warmup_iters: u32,
    samples: u32,
    records: RefCell<Vec<(String, Stats, u32)>>,
}

/// Prevent the optimiser from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // Honour quick runs: AIC_BENCH_FAST=1 reduces sample counts (CI).
        let fast = std::env::var("AIC_BENCH_FAST").is_ok();
        Bench {
            name: name.to_string(),
            warmup_iters: if fast { 1 } else { 3 },
            samples: if fast { 5 } else { 15 },
            records: RefCell::new(Vec::new()),
        }
    }

    /// Time `f`, which performs one logical iteration, over the configured
    /// number of samples. Prints a criterion-like summary line.
    pub fn bench<F: FnMut()>(&self, id: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        let stats = Stats::from_times(&times);
        println!(
            "{:<44} time: [{} {} {}]",
            format!("{}/{}", self.name, id),
            fmt_dur(stats.min),
            fmt_dur(stats.mean),
            fmt_dur(stats.max),
        );
        println!(
            "  {{\"bench\":\"{}/{}\",\"mean_ns\":{},\"p50_ns\":{},\"p99_ns\":{},\"samples\":{}}}",
            self.name,
            id,
            stats.mean.as_nanos(),
            stats.p50.as_nanos(),
            stats.p99.as_nanos(),
            times.len()
        );
        self.records.borrow_mut().push((id.to_string(), stats, times.len() as u32));
        stats
    }

    /// Like [`bench`] but reports throughput in `elems/s` given the number
    /// of logical elements one iteration processes.
    pub fn bench_throughput<F: FnMut()>(&self, id: &str, elems: u64, mut f: F) -> Stats {
        let stats = self.bench(id, &mut f);
        let per_sec = elems as f64 / stats.mean.as_secs_f64();
        println!("  thrpt: {:.3e} elem/s", per_sec);
        stats
    }

    /// Print a paper-figure data table (markdown) under this bench's name.
    pub fn report_table(&self, title: &str, header: &[&str], rows: &[Vec<String>]) {
        println!("\n## {} — {}", self.name, title);
        println!("| {} |", header.join(" | "));
        println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in rows {
            println!("| {} |", row.join(" | "));
        }
        println!();
    }

    /// Merge this run's measurements into the `AIC_BENCH_OUT` artifact
    /// (no-op when the variable is unset). Called on drop so every bench
    /// binary contributes without explicit plumbing.
    fn write_artifact(&self) {
        let Ok(path) = std::env::var("AIC_BENCH_OUT") else { return };
        self.write_artifact_to(&path);
    }

    /// Merge this run's measurements into the JSON artifact at `path`
    /// (results land under `benches.<group name>`, replacing any prior
    /// entry for the same group; other keys are preserved).
    pub fn write_artifact_to(&self, path: &str) {
        if path.is_empty() || self.records.borrow().is_empty() {
            return;
        }
        let mut root = std::fs::read_to_string(path)
            .ok()
            .and_then(|s| json::parse(&s).ok())
            .and_then(|v| match v {
                Value::Obj(o) => Some(o),
                _ => None,
            })
            .unwrap_or_default();
        let engine = crate::exec::engine::EngineKind::from_env().label();
        root.insert("engine".into(), Value::Str(engine.into()));
        // A fresh measurement supersedes any "pending" marker a
        // committed placeholder artifact carries.
        root.remove("note");
        let mut benches = match root.remove("benches") {
            Some(Value::Obj(o)) => o,
            _ => BTreeMap::new(),
        };
        let results: Vec<Value> = self
            .records
            .borrow()
            .iter()
            .map(|(id, s, n)| {
                let mut o = BTreeMap::new();
                o.insert("bench".into(), Value::Str(format!("{}/{}", self.name, id)));
                o.insert("mean_ns".into(), Value::Num(s.mean.as_nanos() as f64));
                o.insert("p50_ns".into(), Value::Num(s.p50.as_nanos() as f64));
                o.insert("p99_ns".into(), Value::Num(s.p99.as_nanos() as f64));
                o.insert("min_ns".into(), Value::Num(s.min.as_nanos() as f64));
                o.insert("max_ns".into(), Value::Num(s.max.as_nanos() as f64));
                o.insert("samples".into(), Value::Num(*n as f64));
                Value::Obj(o)
            })
            .collect();
        benches.insert(self.name.clone(), Value::Arr(results));
        root.insert("benches".into(), Value::Obj(benches));
        if let Err(e) = std::fs::write(path, json::to_string_pretty(&Value::Obj(root))) {
            eprintln!("(bench artifact {path} not written: {e})");
        } else {
            println!("(bench artifact merged into {path})");
        }
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        // Only bench binaries auto-export; unit tests creating Bench
        // values must not touch a developer's exported AIC_BENCH_OUT.
        if cfg!(not(test)) {
            self.write_artifact();
        }
    }
}

/// Timing statistics for one benchmark id.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub min: Duration,
    pub max: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

impl Stats {
    fn from_times(times: &[Duration]) -> Stats {
        let mut sorted = times.to_vec();
        sorted.sort();
        let total: Duration = sorted.iter().sum();
        let q = |f: f64| sorted[((sorted.len() - 1) as f64 * f) as usize];
        Stats {
            min: sorted[0],
            max: *sorted.last().unwrap(),
            mean: total / sorted.len() as u32,
            p50: q(0.5),
            p99: q(0.99),
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let times = vec![
            Duration::from_nanos(10),
            Duration::from_nanos(30),
            Duration::from_nanos(20),
        ];
        let s = Stats::from_times(&times);
        assert_eq!(s.min, Duration::from_nanos(10));
        assert_eq!(s.max, Duration::from_nanos(30));
        assert_eq!(s.mean, Duration::from_nanos(20));
    }

    #[test]
    fn bench_runs_closure() {
        // No env mutation: tests run in parallel threads and setenv
        // races with every concurrent env::var in the process.
        let b = Bench::new("test");
        let mut count = 0u32;
        b.bench("noop", || count += 1);
        assert!(count >= 6); // warmup + samples
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
    }

    #[test]
    fn artifact_merges_across_bench_groups() {
        let path = std::env::temp_dir().join("aic_bench_artifact_test.json");
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        // Two bench "binaries" merging into the same artifact. Write via
        // the explicit path entry point: tests must not set the
        // process-global AIC_BENCH_OUT (parallel tests share the env).
        let a = Bench::new("groupA");
        a.bench("x", || {});
        a.write_artifact_to(&path_s);
        let b = Bench::new("groupB");
        b.bench("y", || {});
        b.write_artifact_to(&path_s);
        let parsed = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let obj = parsed.as_obj().unwrap();
        let benches = obj.get("benches").unwrap().as_obj().unwrap();
        assert!(benches.contains_key("groupA"));
        assert!(benches.contains_key("groupB"));
        let rows = benches.get("groupA").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_obj().unwrap().get("bench").unwrap().as_str(), Some("groupA/x"));
        assert!(rows[0].as_obj().unwrap().get("mean_ns").unwrap().as_f64().is_some());
        let _ = std::fs::remove_file(&path);
    }
}
