//! Synthetic test pictures.
//!
//! The paper stores a set of test pictures on FRAM and grades outputs by
//! picture complexity (Fig. 12: a simple test pattern, then progressively
//! busier scenes). The three generators here span the same range:
//! a checkerboard (simple, strong isolated corners), a polygon scene
//! (medium), and a cluttered blocks-and-texture scene (complex). All are
//! seeded and deterministic.

use crate::imgproc::Image;
use crate::util::rng::Rng;

/// Picture complexity classes, mirroring Fig. 12(a)-(c).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Picture {
    /// Checkerboard — the "simple test" of Fig. 12(a).
    Checker,
    /// A few filled convex polygons.
    Polygons,
    /// Many overlapping rectangles plus texture noise.
    Cluttered,
}

impl Picture {
    pub const ALL: [Picture; 3] = [Picture::Checker, Picture::Polygons, Picture::Cluttered];

    pub fn name(&self) -> &'static str {
        match self {
            Picture::Checker => "checker",
            Picture::Polygons => "polygons",
            Picture::Cluttered => "cluttered",
        }
    }
}

/// Render a test picture at the given size.
pub fn render(kind: Picture, width: usize, height: usize, seed: u64) -> Image {
    let mut img = Image::new(width, height);
    render_into(kind, width, height, seed, &mut img);
    img
}

/// Render a test picture into a caller-owned image, reusing its pixel
/// buffer (no allocation once the buffer has warmed to the size).
/// Identical output to [`render`].
pub fn render_into(kind: Picture, width: usize, height: usize, seed: u64, img: &mut Image) {
    match kind {
        Picture::Checker => checkerboard(width, height, 8, img),
        Picture::Polygons => polygons(width, height, seed, 5, img),
        Picture::Cluttered => cluttered(width, height, seed, img),
    }
}

/// Standard evaluation size (the paper cites ~25 KB per capture [52]:
/// 160×160 at 8 bpp).
pub const EVAL_SIZE: usize = 160;

fn checkerboard(width: usize, height: usize, cells: usize, img: &mut Image) {
    img.reset(width, height, 0.0);
    let cw = width / cells;
    let ch = height / cells;
    for y in 0..height {
        for x in 0..width {
            let v = ((x / cw.max(1)) + (y / ch.max(1))) % 2;
            img.set(x, y, v as f64);
        }
    }
}

/// Fill a convex polygon given vertices (scanline test via cross products).
fn fill_convex(img: &mut Image, pts: &[(f64, f64)], value: f64) {
    let inside = |x: f64, y: f64| -> bool {
        let n = pts.len();
        let mut sign = 0i8;
        for i in 0..n {
            let (x1, y1) = pts[i];
            let (x2, y2) = pts[(i + 1) % n];
            let cross = (x2 - x1) * (y - y1) - (y2 - y1) * (x - x1);
            let s = if cross > 0.0 {
                1
            } else if cross < 0.0 {
                -1
            } else {
                0
            };
            if s != 0 {
                if sign == 0 {
                    sign = s;
                } else if sign != s {
                    return false;
                }
            }
        }
        true
    };
    for y in 0..img.height {
        for x in 0..img.width {
            if inside(x as f64 + 0.5, y as f64 + 0.5) {
                img.set(x, y, value);
            }
        }
    }
}

fn polygons(width: usize, height: usize, seed: u64, count: usize, img: &mut Image) {
    let mut rng = Rng::new(seed ^ 0x90170);
    // Mid-gray background so both darker and lighter shapes give edges.
    img.reset(width, height, 0.5);
    for i in 0..count {
        let cx = rng.range(0.2, 0.8) * width as f64;
        let cy = rng.range(0.2, 0.8) * height as f64;
        let r = rng.range(0.08, 0.22) * width as f64;
        let sides = 3 + rng.index(3); // triangles to pentagons
        let phase = rng.range(0.0, std::f64::consts::TAU);
        let mut pts = [(0.0, 0.0); 5];
        for (k, p) in pts.iter_mut().enumerate().take(sides) {
            let a = phase + std::f64::consts::TAU * k as f64 / sides as f64;
            *p = (cx + r * a.cos(), cy + r * a.sin());
        }
        let shade = if i % 2 == 0 { 0.95 } else { 0.05 };
        fill_convex(img, &pts[..sides], shade);
    }
}

fn cluttered(width: usize, height: usize, seed: u64, img: &mut Image) {
    let mut rng = Rng::new(seed ^ 0xC1077);
    img.reset(width, height, 0.5);
    // Overlapping axis-aligned rectangles: dense corner population.
    for _ in 0..14 {
        let x0 = rng.index(width * 3 / 4);
        let y0 = rng.index(height * 3 / 4);
        let w = 8 + rng.index(width / 3);
        let h = 8 + rng.index(height / 3);
        let shade = rng.range(0.0, 1.0);
        for y in y0..(y0 + h).min(height) {
            for x in x0..(x0 + w).min(width) {
                img.set(x, y, shade);
            }
        }
    }
    // Mild texture noise (robustness to which motivates approximation).
    for v in img.data.iter_mut() {
        *v = (*v + 0.02 * rng.gaussian()).clamp(0.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        for kind in Picture::ALL {
            let a = render(kind, 64, 64, 5);
            let b = render(kind, 64, 64, 5);
            assert_eq!(a, b, "{kind:?}");
        }
    }

    #[test]
    fn render_into_reused_buffer_matches_fresh_render() {
        // A buffer warmed by a different (larger) picture must produce
        // bitwise-identical output when re-rendered into.
        let mut img = render(Picture::Cluttered, 96, 96, 1);
        for kind in Picture::ALL {
            render_into(kind, 64, 64, 5, &mut img);
            assert_eq!(img, render(kind, 64, 64, 5), "{kind:?}");
        }
    }

    #[test]
    fn values_in_unit_range() {
        for kind in Picture::ALL {
            let img = render(kind, 80, 80, 9);
            assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)), "{kind:?}");
        }
    }

    #[test]
    fn checkerboard_alternates() {
        let mut img = Image::new(64, 64);
        checkerboard(64, 64, 8, &mut img);
        assert_eq!(img.at(0, 0), 0.0);
        assert_eq!(img.at(8, 0), 1.0);
        assert_eq!(img.at(8, 8), 0.0);
    }

    #[test]
    fn complexity_ordering_by_edge_content() {
        // Edge energy (sum of |gradient|) should grow from the sparse
        // polygon scene to the cluttered one.
        let edge_energy = |img: &Image| -> f64 {
            let mut e = 0.0;
            for y in 0..img.height {
                for x in 1..img.width {
                    e += (img.at(x, y) - img.at(x - 1, y)).abs();
                }
            }
            e
        };
        let medium = edge_energy(&render(Picture::Polygons, 96, 96, 3));
        let complex = edge_energy(&render(Picture::Cluttered, 96, 96, 3));
        assert!(complex > medium, "cluttered should be busier than polygons");
    }

    #[test]
    fn clamped_access() {
        let mut img = Image::new(16, 16);
        checkerboard(16, 16, 4, &mut img);
        assert_eq!(img.at_clamped(-5, -5), img.at(0, 0));
        assert_eq!(img.at_clamped(100, 100), img.at(15, 15));
    }
}
