//! The corner-detection pipeline as a step program (§6.3).
//!
//! Whenever the device wakes with new energy it loads one of the test
//! pictures (round-robin over kinds × seeds, mirroring the paper's
//! "randomly loads one of the test pictures"), computes Harris responses
//! row by row in the perforation schedule, and stores/emits the corner
//! list. Each step is one image row (one iteration of the perforated
//! loop, the unit the energy estimator prices, Fig. 10).

use crate::energy::mcu::OpCost;
use crate::exec::program::StepProgram;
use crate::imgproc::harris::{
    detect, gradients_into, response_row_with, row_schedule, HarrisConfig, ResponseMap, RowScratch,
};
use crate::imgproc::images::{render_into, Picture, EVAL_SIZE};
use crate::imgproc::{Corner, Image};
use crate::util::rng::Rng;

/// Corner output: what the device stores on FRAM / emits.
#[derive(Clone, Debug)]
pub struct CornerOutput {
    pub picture: Picture,
    pub picture_seed: u64,
    pub corners: Vec<Corner>,
    pub rows_computed: usize,
    pub total_rows: usize,
}

/// Per-pixel cost of one perforated-loop iteration row (structure tensor
/// + response in software fixed point on the MSP430).
pub const CYCLES_PER_PIXEL: u64 = 600;

/// The imaging program.
pub struct CornerProgram {
    cfg: HarrisConfig,
    size: usize,
    /// Picture pool: (kind, seed) pairs cycled per round.
    pool: Vec<(Picture, u64)>,
    rng: Rng,
    // Current round state.
    picture: (Picture, u64),
    image: Image,
    ix: Vec<f64>,
    iy: Vec<f64>,
    map: ResponseMap,
    scratch: RowScratch,
    /// Row order — a pure function of `size`, computed once.
    schedule: Vec<usize>,
    executed: usize,
    planned: usize,
}

impl CornerProgram {
    /// Build with the standard test pool: all picture kinds × `seeds`.
    pub fn new(cfg: HarrisConfig, size: usize, seeds: &[u64], rng_seed: u64) -> CornerProgram {
        let pool: Vec<(Picture, u64)> = Picture::ALL
            .iter()
            .flat_map(|&k| seeds.iter().map(move |&s| (k, s)))
            .collect();
        assert!(!pool.is_empty());
        CornerProgram {
            cfg,
            size,
            pool,
            rng: Rng::new(rng_seed),
            picture: (Picture::Checker, 0),
            image: Image::new(1, 1),
            ix: Vec::new(),
            iy: Vec::new(),
            map: ResponseMap::new(1, 1),
            scratch: RowScratch::default(),
            schedule: row_schedule(size),
            executed: 0,
            planned: 0,
        }
    }

    /// Paper-like evaluation program: 160×160 pictures, 4 seeds per kind.
    pub fn paper_default(rng_seed: u64) -> CornerProgram {
        CornerProgram::new(HarrisConfig::default(), EVAL_SIZE, &[11, 22, 33, 44], rng_seed)
    }

    /// The reference (unperforated) output for the current picture.
    pub fn reference_corners(&self) -> Vec<Corner> {
        crate::imgproc::harris::harris_full(&self.image, &self.cfg)
    }

    /// Total row count (steps of a precise execution).
    pub fn rows(&self) -> usize {
        self.size
    }
}

impl StepProgram for CornerProgram {
    type Output = CornerOutput;

    fn load_next(&mut self, _now: f64) -> bool {
        self.picture = *self.rng.choose(&self.pool);
        render_into(self.picture.0, self.size, self.size, self.picture.1, &mut self.image);
        gradients_into(&self.image, &mut self.ix, &mut self.iy);
        self.map.reset(self.size, self.size);
        self.executed = 0;
        self.planned = self.size;
        true
    }

    fn acquire_cost(&self) -> OpCost {
        // Image load from FRAM (the paper stores test pictures there;
        // the camera-acquisition cost is factored out, §6.3) plus the
        // gradient prologue.
        OpCost {
            cycles: 200_000 + (self.size * self.size) as u64 * 60,
            fram_reads: (self.size * self.size) as u64 / 2,
            ..Default::default()
        }
    }

    fn num_steps(&self) -> usize {
        self.size
    }

    fn plan(&mut self, k: usize) {
        debug_assert!(k <= self.size);
        self.planned = k;
    }

    fn planned_steps(&self) -> usize {
        self.planned
    }

    fn step_cost(&self, _j: usize) -> OpCost {
        OpCost::cycles(self.size as u64 * CYCLES_PER_PIXEL)
    }

    fn execute_step(&mut self, j: usize) {
        debug_assert_eq!(j, self.executed, "rows run in schedule order");
        let y = self.schedule[j];
        response_row_with(&self.ix, &self.iy, &mut self.map, y, &self.cfg, &mut self.scratch);
        self.executed += 1;
    }

    fn state_words(&self, j: usize) -> u64 {
        // Checkpointing runtimes must persist the response rows computed
        // so far (the image itself already lives in FRAM).
        (j * self.size) as u64 + 32
    }

    fn war_words(&self, _j: usize) -> u64 {
        // Row writes are idempotent (each row written once): no WAR cost.
        0
    }

    fn emit_cost(&self) -> OpCost {
        // Store the corner list + summary packet.
        OpCost { cycles: 4_000, ble_bytes: 8, ..Default::default() }
    }

    fn output(&self) -> CornerOutput {
        CornerOutput {
            picture: self.picture.0,
            picture_seed: self.picture.1,
            corners: detect(&self.map, &self.cfg),
            rows_computed: self.executed,
            total_rows: self.size,
        }
    }

    fn reset_round(&mut self) {
        self.map.reset(self.size, self.size);
        self.executed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::mcu::McuModel;
    use crate::imgproc::equivalence::equivalent;

    #[test]
    fn full_execution_matches_reference_detector() {
        let mut prog = CornerProgram::new(HarrisConfig::default(), 64, &[7], 1);
        assert!(prog.load_next(0.0));
        for j in 0..prog.num_steps() {
            prog.execute_step(j);
        }
        let out = prog.output();
        let reference = prog.reference_corners();
        assert_eq!(out.corners.len(), reference.len());
        assert!(equivalent(&reference, &out.corners));
        assert_eq!(out.rows_computed, 64);
    }

    #[test]
    fn partial_execution_still_detects_most_corners() {
        let mut prog = CornerProgram::new(HarrisConfig::default(), 64, &[7], 1);
        assert!(prog.load_next(0.0));
        prog.plan(40); // 62% of rows
        for j in 0..40 {
            prog.execute_step(j);
        }
        let out = prog.output();
        let reference = prog.reference_corners();
        assert!(
            out.corners.len() as f64 >= 0.6 * reference.len() as f64,
            "partial {} vs full {}",
            out.corners.len(),
            reference.len()
        );
    }

    #[test]
    fn image_energy_in_paper_regime() {
        // Whole-image processing should be on the order of one buffer
        // charge (~5-10 mJ), forcing intermittence on weak traces.
        let prog = CornerProgram::paper_default(1);
        let mcu = McuModel::paper_default();
        let total: f64 = (0..160)
            .map(|_| mcu.energy(&OpCost::cycles(160 * CYCLES_PER_PIXEL)))
            .sum();
        assert!((4e-3..12e-3).contains(&total), "image energy {total}");
        let _ = prog;
    }

    #[test]
    fn pool_cycles_through_pictures() {
        let mut prog = CornerProgram::paper_default(5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..30 {
            assert!(prog.load_next(0.0));
            seen.insert((prog.picture.0.name(), prog.picture.1));
        }
        assert!(seen.len() >= 6, "picture pool under-sampled: {}", seen.len());
    }

    #[test]
    fn reset_round_clears_partial_state() {
        let mut prog = CornerProgram::new(HarrisConfig::default(), 32, &[3], 2);
        assert!(prog.load_next(0.0));
        prog.execute_step(0);
        assert_eq!(prog.output().rows_computed, 1);
        prog.reset_round();
        assert_eq!(prog.output().rows_computed, 0);
        assert!(prog.output().corners.is_empty());
    }
}
