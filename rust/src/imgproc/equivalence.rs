//! The paper's corner-equivalence metric (§6.3).
//!
//! Two outputs are *equivalent* when (i) the same number of corners
//! appears, and (ii) each corner of the approximate output lies closer to
//! its counterpart in the reference output than to any other reference
//! corner — so corners can shift slightly but cannot be confused with a
//! different one.

use crate::imgproc::Corner;

fn d2(a: &Corner, b: &Corner) -> f64 {
    let dx = a.x as f64 - b.x as f64;
    let dy = a.y as f64 - b.y as f64;
    dx * dx + dy * dy
}

/// The paper's binary equivalence check.
pub fn equivalent(reference: &[Corner], approx: &[Corner]) -> bool {
    if reference.len() != approx.len() {
        return false;
    }
    if reference.is_empty() {
        return true;
    }
    // Each approx corner's nearest reference corner must be unique
    // (a bijection) — otherwise two corners were confused.
    let mut claimed = vec![false; reference.len()];
    for a in approx {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, r) in reference.iter().enumerate() {
            let d = d2(a, r);
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        if claimed[best] {
            return false; // two approx corners map to the same reference
        }
        claimed[best] = true;
    }
    true
}

/// Mean position error between matched corners (only meaningful when the
/// outputs are equivalent; returns None otherwise).
pub fn mean_position_error(reference: &[Corner], approx: &[Corner]) -> Option<f64> {
    if !equivalent(reference, approx) {
        return None;
    }
    if reference.is_empty() {
        return Some(0.0);
    }
    let mut total = 0.0;
    for a in approx {
        let d = reference.iter().map(|r| d2(a, r)).fold(f64::INFINITY, f64::min);
        total += d.sqrt();
    }
    Some(total / approx.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(x: usize, y: usize) -> Corner {
        Corner { x, y, response: 1.0 }
    }

    #[test]
    fn identical_sets_are_equivalent() {
        let r = vec![c(10, 10), c(40, 40), c(10, 40)];
        assert!(equivalent(&r, &r));
        assert_eq!(mean_position_error(&r, &r), Some(0.0));
    }

    #[test]
    fn count_mismatch_is_not_equivalent() {
        let r = vec![c(10, 10), c(40, 40)];
        let a = vec![c(10, 10)];
        assert!(!equivalent(&r, &a));
        assert!(mean_position_error(&r, &a).is_none());
    }

    #[test]
    fn small_shifts_are_equivalent() {
        let r = vec![c(10, 10), c(40, 40), c(10, 40)];
        let a = vec![c(11, 10), c(39, 41), c(10, 42)];
        assert!(equivalent(&r, &a));
        let err = mean_position_error(&r, &a).unwrap();
        assert!(err > 0.0 && err < 3.0);
    }

    #[test]
    fn confusion_is_rejected() {
        // Two approx corners both nearest to the same reference corner.
        let r = vec![c(10, 10), c(50, 50)];
        let a = vec![c(11, 10), c(12, 11)];
        assert!(!equivalent(&r, &a));
    }

    #[test]
    fn empty_outputs_are_equivalent() {
        assert!(equivalent(&[], &[]));
    }
}
