//! Harris corner detection with loop perforation (§6.2).
//!
//! The detector is the paper's iterative image-processing workload: Sobel
//! gradients, 3×3 structure-tensor sums, the Harris response
//! `R = det(M) − k·tr(M)²`, thresholding and non-maximum suppression.
//! The *row loop* of the response computation is the perforated loop: a
//! perforated execution computes only a subset of rows, chosen in
//! bit-reversed (van der Corput) order so that any prefix of the schedule
//! is near-uniformly spread over the image — skipped rows cost nothing
//! and contribute no response. Corners whose blobs span surviving rows
//! are still found (slightly displaced); beyond ~40-50 % skipping,
//! detections drop or split, exactly the degradation Fig. 12 shows.

use crate::imgproc::{Corner, Image};

/// Harris detector parameters.
#[derive(Clone, Debug)]
pub struct HarrisConfig {
    /// Harris sensitivity k (classically 0.04-0.06).
    pub k: f64,
    /// Absolute response threshold (images are in [0,1]).
    pub threshold: f64,
    /// Non-maximum suppression radius, pixels.
    pub nms_radius: usize,
}

impl Default for HarrisConfig {
    fn default() -> HarrisConfig {
        HarrisConfig { k: 0.04, threshold: 0.8, nms_radius: 2 }
    }
}

/// Sobel gradient images (Ix, Iy). Allocates fresh output buffers; the
/// per-round path reuses buffers via [`gradients_into`].
pub fn gradients(img: &Image) -> (Vec<f64>, Vec<f64>) {
    let mut ix = Vec::new();
    let mut iy = Vec::new();
    gradients_into(img, &mut ix, &mut iy);
    (ix, iy)
}

/// Sobel gradients into caller-owned buffers (no allocation once the
/// buffers have warmed to the image size). The inner loop runs on
/// straight row slices — no per-pixel clamping closure — so the
/// compiler can unroll and vectorise it; border columns use the same
/// clamped expressions as the scalar reference. Per pixel the operand
/// order matches [`gradients_scalar`] exactly, so the results are
/// bitwise identical (asserted by `tests/kernel_equivalence.rs`).
pub fn gradients_into(img: &Image, ix: &mut Vec<f64>, iy: &mut Vec<f64>) {
    let (w, h) = (img.width, img.height);
    ix.clear();
    ix.resize(w * h, 0.0);
    iy.clear();
    iy.resize(w * h, 0.0);
    for y in 0..h {
        let ym = y.saturating_sub(1);
        let yp = (y + 1).min(h - 1);
        let t = &img.data[ym * w..ym * w + w];
        let m = &img.data[y * w..y * w + w];
        let b = &img.data[yp * w..yp * w + w];
        let ox = &mut ix[y * w..y * w + w];
        let oy = &mut iy[y * w..y * w + w];
        let last = w - 1;
        // Border columns: x±1 clamps to the edge.
        let edge = |x: usize| {
            let xm = x.saturating_sub(1);
            let xp = (x + 1).min(last);
            (
                (t[xp] + 2.0 * m[xp] + b[xp]) - (t[xm] + 2.0 * m[xm] + b[xm]),
                (b[xm] + 2.0 * b[x] + b[xp]) - (t[xm] + 2.0 * t[x] + t[xp]),
            )
        };
        (ox[0], oy[0]) = edge(0);
        if last > 0 {
            (ox[last], oy[last]) = edge(last);
        }
        // Interior: branch-free shifted-slice loop.
        for x in 1..last {
            ox[x] = (t[x + 1] + 2.0 * m[x + 1] + b[x + 1])
                - (t[x - 1] + 2.0 * m[x - 1] + b[x - 1]);
            oy[x] = (b[x - 1] + 2.0 * b[x] + b[x + 1]) - (t[x - 1] + 2.0 * t[x] + t[x + 1]);
        }
    }
}

/// The scalar reference for [`gradients`]: per-pixel clamped lookups,
/// exactly as originally written. Retained so the sliced kernel is
/// verified against it rather than eyeballed.
pub fn gradients_scalar(img: &Image) -> (Vec<f64>, Vec<f64>) {
    let (w, h) = (img.width, img.height);
    let mut ix = vec![0.0; w * h];
    let mut iy = vec![0.0; w * h];
    for y in 0..h {
        for x in 0..w {
            let p = |dx: isize, dy: isize| img.at_clamped(x as isize + dx, y as isize + dy);
            ix[y * w + x] = (p(1, -1) + 2.0 * p(1, 0) + p(1, 1))
                - (p(-1, -1) + 2.0 * p(-1, 0) + p(-1, 1));
            iy[y * w + x] = (p(-1, 1) + 2.0 * p(0, 1) + p(1, 1))
                - (p(-1, -1) + 2.0 * p(0, -1) + p(1, -1));
        }
    }
    (ix, iy)
}

/// Partial Harris response map: rows are filled in as the (possibly
/// perforated) loop executes.
#[derive(Clone, Debug)]
pub struct ResponseMap {
    pub width: usize,
    pub height: usize,
    pub r: Vec<f64>,
    /// Whether each row has been computed.
    pub row_done: Vec<bool>,
}

impl ResponseMap {
    pub fn new(width: usize, height: usize) -> ResponseMap {
        ResponseMap { width, height, r: vec![0.0; width * height], row_done: vec![false; height] }
    }

    /// Clear back to the all-rows-pending state in place (same result
    /// as a fresh [`ResponseMap::new`], without reallocating when the
    /// dimensions are unchanged).
    pub fn reset(&mut self, width: usize, height: usize) {
        self.width = width;
        self.height = height;
        self.r.clear();
        self.r.resize(width * height, 0.0);
        self.row_done.clear();
        self.row_done.resize(height, false);
    }

    /// Fraction of rows computed.
    pub fn coverage(&self) -> f64 {
        self.row_done.iter().filter(|&&d| d).count() as f64 / self.height.max(1) as f64
    }
}

/// Reusable buffers for the separable response-row kernel: per-column
/// vertical sums of the three structure-tensor products. One scratch
/// per program/worker keeps the per-step path allocation-free once the
/// buffers have warmed to the row width.
#[derive(Clone, Debug, Default)]
pub struct RowScratch {
    vxx: Vec<f64>,
    vxy: Vec<f64>,
    vyy: Vec<f64>,
}

/// Compute one row of the Harris response from the gradient images.
/// Convenience wrapper over [`response_row_with`] with a throwaway
/// scratch; hot per-step paths hold a [`RowScratch`] and call
/// [`response_row_with`] directly.
pub fn response_row(
    ix: &[f64],
    iy: &[f64],
    map: &mut ResponseMap,
    y: usize,
    cfg: &HarrisConfig,
) {
    response_row_with(ix, iy, map, y, cfg, &mut RowScratch::default());
}

/// One row of the Harris response, separably: first the vertical sums
/// of `gx²`, `gx·gy`, `gy²` over the (clamped) 3-row band — three
/// elementwise passes over row slices the compiler can vectorise —
/// then a horizontal 3-tap sum and the response `det − k·tr²` per
/// column. Equal to [`response_row_scalar`] up to summation
/// reassociation (the 9-term tensor sums are regrouped column-first);
/// `tests/kernel_equivalence.rs` bounds the difference.
pub fn response_row_with(
    ix: &[f64],
    iy: &[f64],
    map: &mut ResponseMap,
    y: usize,
    cfg: &HarrisConfig,
    scratch: &mut RowScratch,
) {
    let (w, h) = (map.width, map.height);
    debug_assert!(y < h);
    scratch.vxx.clear();
    scratch.vxx.resize(w, 0.0);
    scratch.vxy.clear();
    scratch.vxy.resize(w, 0.0);
    scratch.vyy.clear();
    scratch.vyy.resize(w, 0.0);
    let ym = y.saturating_sub(1);
    let yp = (y + 1).min(h - 1);
    for row in [ym, y, yp] {
        let gx = &ix[row * w..row * w + w];
        let gy = &iy[row * w..row * w + w];
        for x in 0..w {
            scratch.vxx[x] += gx[x] * gx[x];
            scratch.vxy[x] += gx[x] * gy[x];
            scratch.vyy[x] += gy[x] * gy[x];
        }
    }
    let (vxx, vxy, vyy) = (&scratch.vxx, &scratch.vxy, &scratch.vyy);
    let k = cfg.k;
    let resp = |sxx: f64, sxy: f64, syy: f64| {
        let det = sxx * syy - sxy * sxy;
        let tr = sxx + syy;
        det - k * tr * tr
    };
    let row = &mut map.r[y * w..y * w + w];
    let last = w - 1;
    {
        // Left border: x−1 clamps onto x.
        let xp = 1.min(last);
        row[0] = resp(
            vxx[0] + vxx[0] + vxx[xp],
            vxy[0] + vxy[0] + vxy[xp],
            vyy[0] + vyy[0] + vyy[xp],
        );
    }
    for x in 1..last {
        row[x] = resp(
            vxx[x - 1] + vxx[x] + vxx[x + 1],
            vxy[x - 1] + vxy[x] + vxy[x + 1],
            vyy[x - 1] + vyy[x] + vyy[x + 1],
        );
    }
    if last > 0 {
        // Right border: x+1 clamps onto x.
        row[last] = resp(
            vxx[last - 1] + vxx[last] + vxx[last],
            vxy[last - 1] + vxy[last] + vxy[last],
            vyy[last - 1] + vyy[last] + vyy[last],
        );
    }
    map.row_done[y] = true;
}

/// The scalar reference for [`response_row_with`]: the per-pixel 3×3
/// structure-tensor loop, exactly as originally written. Retained for
/// the kernel-equivalence suite.
pub fn response_row_scalar(
    ix: &[f64],
    iy: &[f64],
    map: &mut ResponseMap,
    y: usize,
    cfg: &HarrisConfig,
) {
    let (w, h) = (map.width, map.height);
    debug_assert!(y < h);
    for x in 0..w {
        // 3x3 structure tensor around (x, y).
        let (mut sxx, mut sxy, mut syy) = (0.0, 0.0, 0.0);
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                let xi = (x as isize + dx).clamp(0, w as isize - 1) as usize;
                let yi = (y as isize + dy).clamp(0, h as isize - 1) as usize;
                let gx = ix[yi * w + xi];
                let gy = iy[yi * w + xi];
                sxx += gx * gx;
                sxy += gx * gy;
                syy += gy * gy;
            }
        }
        let det = sxx * syy - sxy * sxy;
        let tr = sxx + syy;
        map.r[y * w + x] = det - cfg.k * tr * tr;
    }
    map.row_done[y] = true;
}

/// Threshold + NMS over the computed rows. Missing rows contribute
/// nothing (their responses read as 0, below any sensible threshold).
pub fn detect(map: &ResponseMap, cfg: &HarrisConfig) -> Vec<Corner> {
    let (w, h) = (map.width, map.height);
    let rad = cfg.nms_radius as isize;
    let mut corners = Vec::new();
    for y in 0..h {
        if !map.row_done[y] {
            continue;
        }
        for x in 0..w {
            let v = map.r[y * w + x];
            if v < cfg.threshold {
                continue;
            }
            let mut is_max = true;
            'nms: for dy in -rad..=rad {
                for dx in -rad..=rad {
                    if dx == 0 && dy == 0 {
                        continue;
                    }
                    let xi = x as isize + dx;
                    let yi = y as isize + dy;
                    if xi < 0 || yi < 0 || xi >= w as isize || yi >= h as isize {
                        continue;
                    }
                    let (xi, yi) = (xi as usize, yi as usize);
                    if !map.row_done[yi] {
                        continue;
                    }
                    let u = map.r[yi * w + xi];
                    // Strictly-greater on one side to break plateau ties.
                    if u > v || (u == v && (yi, xi) < (y, x)) {
                        is_max = false;
                        break 'nms;
                    }
                }
            }
            if is_max {
                corners.push(Corner { x, y, response: v });
            }
        }
    }
    corners
}

/// The perforation schedule: rows in bit-reversed order, so the first `k`
/// entries of the schedule are near-uniformly spread for every `k`
/// (nested plans, as the GREEDY runtime extends them mid-round).
pub fn row_schedule(height: usize) -> Vec<usize> {
    let bits = (usize::BITS - (height.max(2) - 1).leading_zeros()) as usize;
    let mut order: Vec<usize> = (0..(1usize << bits))
        .map(|i| {
            let mut r = 0usize;
            for b in 0..bits {
                if i & (1 << b) != 0 {
                    r |= 1 << (bits - 1 - b);
                }
            }
            r
        })
        .filter(|&r| r < height)
        .collect();
    debug_assert_eq!(order.len(), height);
    // Stable de-dup is unnecessary: bit reversal is a permutation.
    order.dedup();
    order
}

/// Full (unperforated) Harris detection.
pub fn harris_full(img: &Image, cfg: &HarrisConfig) -> Vec<Corner> {
    let (ix, iy) = gradients(img);
    let mut map = ResponseMap::new(img.width, img.height);
    let mut scratch = RowScratch::default();
    for y in 0..img.height {
        response_row_with(&ix, &iy, &mut map, y, cfg, &mut scratch);
    }
    detect(&map, cfg)
}

/// Perforated Harris: execute only the first `rows_to_run` entries of the
/// bit-reversed schedule (`skip_fraction = 1 - rows_to_run/height`).
pub fn harris_perforated(img: &Image, cfg: &HarrisConfig, rows_to_run: usize) -> Vec<Corner> {
    let (ix, iy) = gradients(img);
    let mut map = ResponseMap::new(img.width, img.height);
    let mut scratch = RowScratch::default();
    for &y in row_schedule(img.height).iter().take(rows_to_run.min(img.height)) {
        response_row_with(&ix, &iy, &mut map, y, cfg, &mut scratch);
    }
    detect(&map, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imgproc::images::{render, Picture};

    #[test]
    fn checkerboard_corners_found() {
        let img = render(Picture::Checker, 64, 64, 1);
        let corners = harris_full(&img, &HarrisConfig::default());
        // 8x8 cells → 7x7 interior lattice crossings.
        assert!(
            (30..=70).contains(&corners.len()),
            "expected ~49 corners, got {}",
            corners.len()
        );
        // Corners should sit near multiples of 8.
        for c in &corners {
            let dx = (c.x as f64 / 8.0).round() * 8.0 - c.x as f64;
            let dy = (c.y as f64 / 8.0).round() * 8.0 - c.y as f64;
            assert!(dx.abs() <= 2.5 && dy.abs() <= 2.5, "corner off-lattice: {c:?}");
        }
    }

    #[test]
    fn flat_image_has_no_corners() {
        let img = Image::new(32, 32);
        assert!(harris_full(&img, &HarrisConfig::default()).is_empty());
    }

    #[test]
    fn edges_are_not_corners() {
        // A single vertical step edge: strong gradients, no corner.
        let mut img = Image::new(48, 48);
        for y in 0..48 {
            for x in 24..48 {
                img.set(x, y, 1.0);
            }
        }
        let corners = harris_full(&img, &HarrisConfig::default());
        assert!(corners.is_empty(), "step edge produced corners: {corners:?}");
    }

    #[test]
    fn row_schedule_is_a_spread_permutation() {
        for h in [7usize, 64, 160, 200] {
            let sched = row_schedule(h);
            assert_eq!(sched.len(), h);
            let mut sorted = sched.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..h).collect::<Vec<_>>());
            // The first half of the schedule must cover the image evenly:
            // max gap between consecutive chosen rows <= 4 * ideal gap.
            let mut half: Vec<usize> = sched[..h / 2].to_vec();
            half.sort_unstable();
            let max_gap = half.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0);
            assert!(max_gap <= 8, "h={h} max_gap={max_gap}");
        }
    }

    #[test]
    fn mild_perforation_preserves_corner_count() {
        let img = render(Picture::Checker, 64, 64, 1);
        let cfg = HarrisConfig::default();
        let full = harris_full(&img, &cfg);
        // Run 70% of rows.
        let p70 = harris_perforated(&img, &cfg, 64 * 7 / 10);
        let ratio = p70.len() as f64 / full.len().max(1) as f64;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "70% rows: {} vs full {}",
            p70.len(),
            full.len()
        );
    }

    #[test]
    fn extreme_perforation_degrades() {
        let img = render(Picture::Cluttered, 96, 96, 2);
        let cfg = HarrisConfig::default();
        let full = harris_full(&img, &cfg).len();
        let tiny = harris_perforated(&img, &cfg, 10).len();
        assert!(tiny < full, "10/96 rows should find fewer corners: {tiny} vs {full}");
    }

    #[test]
    fn perforation_is_monotone_in_coverage() {
        // More rows never loses *computed* coverage (the schedule nests).
        let img = render(Picture::Polygons, 80, 80, 3);
        let (ix, iy) = gradients(&img);
        let cfg = HarrisConfig::default();
        let sched = row_schedule(80);
        let mut map = ResponseMap::new(80, 80);
        let mut last_coverage = 0.0;
        for chunk in sched.chunks(20) {
            for &y in chunk {
                response_row(&ix, &iy, &mut map, y, &cfg);
            }
            let c = map.coverage();
            assert!(c > last_coverage);
            last_coverage = c;
        }
        assert!((last_coverage - 1.0).abs() < 1e-12);
    }
}
