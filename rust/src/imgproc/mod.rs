//! Embedded image processing (the paper's second application, §6).
//!
//! * [`images`] — synthetic test pictures (simple shapes → complex
//!   scenes) standing in for the FRAM-stored test set of §6.3.
//! * [`harris`] — Harris corner detection with a *row perforation* knob:
//!   the iterative response loop skips a chosen fraction of rows, trading
//!   output quality for energy exactly as the paper's loop perforation.
//! * [`equivalence`] — the paper's output metric: corner sets are
//!   *equivalent* when the count matches and each corner is closest to
//!   its counterpart (§6.3).
//! * [`app`] — the corner pipeline as a [`crate::exec::StepProgram`]
//!   whose steps are row groups of the perforated loop.

pub mod app;
pub mod equivalence;
pub mod harris;
pub mod images;

/// A grayscale image, row-major, values in [0, 1].
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub data: Vec<f64>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, data: vec![0.0; width * height] }
    }

    /// Re-shape in place to `width × height` filled with `fill`,
    /// reusing the existing buffer when large enough.
    pub fn reset(&mut self, width: usize, height: usize, fill: f64) {
        self.width = width;
        self.height = height;
        self.data.clear();
        self.data.resize(width * height, fill);
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize) -> f64 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: f64) {
        self.data[y * self.width + x] = v;
    }

    /// Clamped access (border replication).
    #[inline]
    pub fn at_clamped(&self, x: isize, y: isize) -> f64 {
        let xi = x.clamp(0, self.width as isize - 1) as usize;
        let yi = y.clamp(0, self.height as isize - 1) as usize;
        self.at(xi, yi)
    }
}

/// A detected corner: position plus response strength.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Corner {
    pub x: usize,
    pub y: usize,
    pub response: f64,
}
