//! # Approximate Intermittent Computing (AIC)
//!
//! Reproduction of *"The Case for Approximate Intermittent Computing"*
//! (Bambusi, Cerizzi, Lee, Mottola — 2021): a framework for running
//! data-processing pipelines on batteryless, energy-harvesting devices by
//! trading output accuracy for the guarantee that every computation
//! finishes **within a single power cycle**, eliminating persistent state
//! (checkpoints on NVM) entirely.
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): anytime-SVM
//!   prefix scoring, DFT/statistics feature extraction, perforated Harris
//!   corner response. Compile-time only.
//! * **L2** — JAX pipelines (`python/compile/model.py`) AOT-lowered to HLO
//!   text artifacts (`artifacts/*.hlo.txt`).
//! * **L3** — this crate: the intermittent-execution engine and the
//!   [`exec::Runtime`] trait, the energy substrate, the GREEDY/SMART
//!   approximate runtimes and the Chinchilla / Alpaca / continuous
//!   baselines, the application pipelines (human activity recognition,
//!   embedded image processing, anytime acoustic event detection), the
//!   PJRT runtime that loads the AOT
//!   artifacts for accelerated batch replay (behind the `pjrt` feature),
//!   and the declarative scenario coordinator + fleet that regenerate
//!   every figure of the paper and run arbitrary sweep grids
//!   ([`coordinator::scenario`]).
//!
//! See `DESIGN.md` for the system inventory and the scenario index, and
//! `EXPERIMENTS.md` for measured-vs-paper results.

pub mod util;
pub mod energy;
pub mod exec;
pub mod svm;
pub mod har;
pub mod imgproc;
pub mod audio;
pub mod runtime;
pub mod coordinator;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::util::rng::Rng;
}
