//! Anytime acoustic event detection (the third workload).
//!
//! The paper demonstrates approximate intermittent computing on two
//! "sharply different scenarios" — anytime SVM classification and loop
//! perforation — and argues the approach generalises. This module adds a
//! third shape of approximation: **progressive spectral refinement**. An
//! acoustic sensor node samples a 128-point audio window and must decide
//! which of a small set of tonal events (machine whine, alarm beep,
//! appliance hum, ...) is present, if any. Spectral resolution is the
//! anytime knob:
//!
//! * each step is one Goertzel band-energy pass over the window at one
//!   probe frequency ([`detector::SpectralDetector`]),
//! * the probe schedule is coarse-to-fine — an 8-band survey of the
//!   spectrum first, then the in-between bins at stride 4, 2, 1 —
//!   refining toward the full 128-point spectrum as energy allows,
//! * a threshold classifier maps the probed band energies to an event
//!   class; its detection accuracy is monotonically non-decreasing in
//!   the number of completed refinement steps (probes only accumulate,
//!   and on the synthetic streams a correct classification can never be
//!   un-learned by a finer probe — see [`detector`]).
//!
//! Streams are synthetic and deterministic per seed ([`stream`]): no
//! audio assets are downloaded, mirroring how `har::dataset` stands in
//! for UCI-HAR. [`app::AudioProgram`] packages the pipeline as a
//! [`crate::exec::StepProgram`] so every runtime policy, the scenario
//! grid, and the fleet drive it unchanged.

pub mod app;
pub mod detector;
pub mod stream;

/// Microphone sampling rate, Hz (ultra-low-power MEMS front-end).
pub const AUDIO_SAMPLE_RATE_HZ: f64 = 8000.0;

/// Samples per analysis window (16 ms at 8 kHz; power of two for the
/// 128-point spectrum the refinement converges to).
pub const AUDIO_WINDOW_LEN: usize = 128;

/// Event classes: class 0 is ambient noise / silence, classes `1..=8`
/// are tonal events.
pub const NUM_AUDIO_CLASSES: usize = 9;

/// Spectral bin of each tonal event class (class `c` sits at
/// `EVENT_BINS[c - 1]`). Bins are chosen across the refinement tiers of
/// [`detector::probe_schedule`]: two resolve at the coarse 8-band survey
/// (multiples of 8), two at stride 4, two at stride 2, and two only at
/// full single-bin resolution (odd bins) — so every refinement tier
/// makes new classes separable.
pub const EVENT_BINS: [usize; 8] = [16, 48, 12, 44, 22, 58, 29, 51];

/// Total refinement steps: every interior bin `1..=63` of the 128-point
/// spectrum is probed exactly once across the coarse-to-fine schedule.
pub const NUM_PROBES: usize = 63;

/// Human-readable class name.
pub fn class_name(class: usize) -> String {
    if class == 0 {
        "silence".to_string()
    } else {
        format!("tone{class}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_bins_are_interior_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for &b in &EVENT_BINS {
            // Bins 0 (DC) and 64 (Nyquist) are excluded: a real sinusoid
            // at the Nyquist bin has phase-dependent energy, which would
            // break the deterministic detection margin.
            assert!((1..=63).contains(&b), "bin {b} out of the interior range");
            assert!(seen.insert(b), "bin {b} duplicated");
        }
        assert_eq!(EVENT_BINS.len(), NUM_AUDIO_CLASSES - 1);
    }

    #[test]
    fn class_names() {
        assert_eq!(class_name(0), "silence");
        assert_eq!(class_name(3), "tone3");
    }
}
