//! The acoustic event pipeline as a step program.
//!
//! Acquire a 16 ms microphone window → run Goertzel band-energy probes
//! in coarse-to-fine refinement order (each step = one probe folded into
//! the running band table) → emit the 2-byte classification over BLE.
//! The probe values are computed when the step executes; the *energy* is
//! charged per executed step through the same estimator/engine path as
//! the HAR features and the Harris rows (Fig. 10's uniform knob model).

use crate::audio::detector::SpectralDetector;
use crate::audio::stream::{AudioScript, AudioWindow};
use crate::energy::estimator::{EnergyProfile, SmartTable};
use crate::energy::mcu::{McuModel, OpCost};
use crate::exec::program::StepProgram;

/// Cycles of one Goertzel band-energy pass over the 128-sample window:
/// the software-floating-point multiply–accumulate recurrence on an
/// FPU-less MSP430 (~300 cycles/sample) plus the magnitude epilogue.
/// Prices the full 63-step refinement at ≈ 2.3 mJ — roughly half a
/// buffer charge, the regime where the anytime knob matters.
pub const CYCLES_PER_PROBE: u64 = 120_000;

/// Cost vector of refinement step `j` (uniform: every probe is one
/// Goertzel pass over the same window).
pub fn probe_cost(_j: usize) -> OpCost {
    OpCost::cycles(CYCLES_PER_PROBE)
}

/// Classification output delivered over BLE (ground truth carried along
/// for the metrics layer; it does not influence execution).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AudioOutput {
    /// Detected class (0 = silence/no event).
    pub predicted: usize,
    /// Scene ground truth.
    pub truth: usize,
    /// Refinement steps completed for this window.
    pub probes_used: usize,
}

/// Where the program's audio windows come from.
pub enum AudioSource {
    /// A fixed labelled list (emulation replay); ends when exhausted.
    List(Vec<AudioWindow>),
    /// A deterministic event script sampled at acquisition time
    /// (campaigns); never ends.
    Script(AudioScript),
}

/// The acoustic event detection program.
pub struct AudioProgram {
    pub detector: SpectralDetector,
    source: AudioSource,
    cursor: usize,
    /// Current window samples.
    window: Vec<f64>,
    truth: usize,
    /// Probe powers completed this round (`powers[j]` = step `j`).
    powers: Vec<f64>,
    planned: usize,
}

impl AudioProgram {
    pub fn new(detector: SpectralDetector, source: AudioSource) -> AudioProgram {
        let num_probes = detector.num_probes();
        AudioProgram {
            detector,
            source,
            cursor: 0,
            window: Vec::new(),
            truth: 0,
            powers: Vec::with_capacity(num_probes),
            planned: 0,
        }
    }

    /// Energy profile of the full refinement pipeline (for SMART tables
    /// and the benches) — priced through the existing estimator path.
    pub fn energy_profile(&self, mcu: &McuModel) -> EnergyProfile {
        let costs: Vec<OpCost> = (0..self.detector.num_probes()).map(probe_cost).collect();
        EnergyProfile::from_costs(mcu, &costs)
    }
}

/// Build SMART's offline lookup table for the audio pipeline: the
/// analytic expected-accuracy curve of the refinement schedule plus the
/// estimator's cumulative probe energy.
pub fn smart_table(detector: &SpectralDetector, mcu: &McuModel) -> SmartTable {
    let acc = detector.expected_accuracy();
    let costs: Vec<OpCost> = (0..detector.num_probes()).map(probe_cost).collect();
    let profile = EnergyProfile::from_costs(mcu, &costs);
    let emit = mcu.energy(&OpCost { cycles: 900, ble_bytes: 2, ..Default::default() });
    SmartTable::new(acc, &profile, emit)
}

impl StepProgram for AudioProgram {
    type Output = AudioOutput;

    fn load_next(&mut self, now: f64) -> bool {
        // Assemble the window into the program's own buffer: the
        // steady-state round loop stays allocation-free.
        match &self.source {
            AudioSource::List(list) => {
                if self.cursor >= list.len() {
                    return false;
                }
                let w = &list[self.cursor];
                self.window.clear();
                self.window.extend_from_slice(&w.samples);
                self.truth = w.label;
                self.cursor += 1;
            }
            AudioSource::Script(script) => {
                self.truth = script.window_into(now, &mut self.window);
            }
        }
        self.powers.clear();
        self.planned = self.detector.num_probes();
        true
    }

    fn acquire_cost(&self) -> OpCost {
        // 16 ms of microphone + amplifier duty plus DMA/window setup.
        OpCost { cycles: 30_000, sensor_secs: 0.016, ..Default::default() }
    }

    fn num_steps(&self) -> usize {
        self.detector.num_probes()
    }

    fn plan(&mut self, k: usize) {
        debug_assert!(k <= self.detector.num_probes());
        self.planned = k;
    }

    fn planned_steps(&self) -> usize {
        self.planned
    }

    fn step_cost(&self, j: usize) -> OpCost {
        probe_cost(j)
    }

    fn execute_step(&mut self, j: usize) {
        debug_assert_eq!(j, self.powers.len(), "refinement steps run in order");
        let p = self.detector.probe(&self.window, j);
        self.powers.push(p);
    }

    fn state_words(&self, j: usize) -> u64 {
        // Window samples (128 × 16-bit) + two words per completed probe
        // + running argmax and bookkeeping.
        128 + 2 * j as u64 + 8
    }

    fn war_words(&self, _j: usize) -> u64 {
        // The running best-probe accumulator is read-modify-write.
        4
    }

    fn emit_cost(&self) -> OpCost {
        OpCost { cycles: 900, ble_bytes: 2, ..Default::default() }
    }

    fn output(&self) -> AudioOutput {
        AudioOutput {
            predicted: self.detector.classify(&self.powers),
            truth: self.truth,
            probes_used: self.powers.len(),
        }
    }

    fn reset_round(&mut self) {
        self.powers.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audio::stream::labelled_windows;
    use crate::audio::{NUM_AUDIO_CLASSES, NUM_PROBES};

    fn program_on_list(per_class: usize, seed: u64) -> AudioProgram {
        AudioProgram::new(
            SpectralDetector::paper_default(),
            AudioSource::List(labelled_windows(per_class, seed)),
        )
    }

    #[test]
    fn program_runs_a_full_round() {
        let mut prog = program_on_list(1, 4);
        assert!(prog.load_next(0.0));
        assert_eq!(prog.num_steps(), NUM_PROBES);
        prog.plan(20);
        for j in 0..20 {
            prog.execute_step(j);
        }
        let out = prog.output();
        assert_eq!(out.probes_used, 20);
        assert!(out.predicted < NUM_AUDIO_CLASSES);
    }

    #[test]
    fn full_execution_matches_direct_classification() {
        let windows = labelled_windows(2, 9);
        let detector = SpectralDetector::paper_default();
        let mut prog = AudioProgram::new(
            detector.clone(),
            AudioSource::List(windows.clone()),
        );
        for w in &windows {
            assert!(prog.load_next(0.0));
            for j in 0..prog.num_steps() {
                prog.execute_step(j);
            }
            let out = prog.output();
            assert_eq!(out.predicted, detector.classify_with(&w.samples, NUM_PROBES));
            assert_eq!(out.predicted, w.label, "full resolution is exact");
            assert_eq!(out.truth, w.label);
        }
        // The list source exhausts.
        assert!(!prog.load_next(0.0));
    }

    #[test]
    fn reset_round_clears_partial_state() {
        let mut prog = program_on_list(1, 2);
        assert!(prog.load_next(0.0));
        prog.execute_step(0);
        assert_eq!(prog.output().probes_used, 1);
        prog.reset_round();
        assert_eq!(prog.output().probes_used, 0);
        assert_eq!(prog.output().predicted, 0, "no probes → silence");
    }

    #[test]
    fn script_source_loads_time_dependent_windows() {
        let script = AudioScript::generate(3600.0, 3);
        let truth_at_500 = script.class_at(500.0);
        let mut prog = AudioProgram::new(
            SpectralDetector::paper_default(),
            AudioSource::Script(script),
        );
        assert!(prog.load_next(500.0));
        assert_eq!(prog.output().truth, truth_at_500);
        // Script sources never exhaust.
        assert!(prog.load_next(2e6));
    }

    #[test]
    fn smart_table_monotone_and_priced() {
        let mcu = McuModel::paper_default();
        let detector = SpectralDetector::paper_default();
        let table = smart_table(&detector, &mcu);
        assert_eq!(table.expected_accuracy.len(), NUM_PROBES + 1);
        assert!((table.expected_accuracy[NUM_PROBES] - 1.0).abs() < 1e-12);
        for p in 1..=NUM_PROBES {
            assert!(table.cumulative_energy[p] > table.cumulative_energy[p - 1]);
        }
        // A 50% bound needs strictly fewer probes than a 90% bound.
        let p50 = table.min_features_for(0.50).unwrap();
        let p90 = table.min_features_for(0.90).unwrap();
        assert!(p50 < p90, "p50={p50} p90={p90}");
        // Tier arithmetic: 60% needs five detectable classes, and the
        // fifth event bin (22) is probe index 20 → 21 completed steps.
        assert_eq!(table.min_features_for(0.60), Some(21));
    }

    #[test]
    fn pipeline_energy_in_the_anytime_regime() {
        // The full refinement must cost a substantial fraction of one
        // buffer charge (≈ 4.2 mJ usable), so the knob actually bites.
        let prog = program_on_list(1, 1);
        let mcu = McuModel::paper_default();
        let total = prog.energy_profile(&mcu).total();
        assert!(
            (1e-3..4e-3).contains(&total),
            "full refinement costs {total} J"
        );
    }
}
