//! Synthetic deterministic acoustic event streams.
//!
//! No audio assets are downloaded: every window is synthesised from a
//! seed, mirroring `har::dataset`. A window is bounded uniform ambient
//! noise plus, for event windows, one sinusoid at the class's exact
//! integer spectral bin — the construction whose deterministic margins
//! make the detector's accuracy provably monotone in refinement steps
//! (see [`super::detector`]). An [`AudioScript`] schedules events over a
//! campaign horizon the way `ActivityScript` schedules activities:
//! `window_at(t)` is deterministic in `t`, so replaying a campaign (or
//! running it on a different energy integrator) observes the same scene.

use crate::audio::detector::{MIN_TONE_AMP, NOISE_AMP};
use crate::audio::{AUDIO_WINDOW_LEN, EVENT_BINS, NUM_AUDIO_CLASSES};
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Maximum tone amplitude (events vary in loudness per occurrence).
pub const MAX_TONE_AMP: f64 = 1.3;

/// One labelled analysis window.
#[derive(Clone, Debug)]
pub struct AudioWindow {
    /// `AUDIO_WINDOW_LEN` samples.
    pub samples: Vec<f64>,
    /// Ground-truth class (0 = silence/noise).
    pub label: usize,
}

/// Synthesise one window of class `class` (deterministic in the `rng`
/// state): bounded uniform noise, plus a tone at the class bin with a
/// per-occurrence amplitude and phase.
pub fn synth_window(class: usize, rng: &mut Rng) -> AudioWindow {
    let mut samples = Vec::new();
    synth_window_into(class, rng, &mut samples);
    AudioWindow { samples, label: class }
}

/// [`synth_window`] into a caller-owned sample buffer: identical RNG
/// draw order and bitwise-identical samples, no allocation once the
/// buffer has warmed to `AUDIO_WINDOW_LEN`. The per-round acquisition
/// path uses this to keep the steady-state loop allocation-free.
pub fn synth_window_into(class: usize, rng: &mut Rng, samples: &mut Vec<f64>) {
    debug_assert!(class < NUM_AUDIO_CLASSES);
    let n = AUDIO_WINDOW_LEN;
    let (amp, phase) = if class > 0 {
        (rng.range(MIN_TONE_AMP, MAX_TONE_AMP), rng.range(0.0, 2.0 * PI))
    } else {
        (0.0, 0.0)
    };
    samples.clear();
    samples.reserve(n);
    for i in 0..n {
        let noise = rng.range(-NOISE_AMP, NOISE_AMP);
        samples.push(if class > 0 {
            let bin = EVENT_BINS[class - 1] as f64;
            noise + amp * (2.0 * PI * bin * i as f64 / n as f64 + phase).sin()
        } else {
            noise
        });
    }
}

/// A class-balanced labelled window set: `per_class` windows of each of
/// the 9 classes, deterministic in `seed` (tests, benches, emulation
/// replay).
pub fn labelled_windows(per_class: usize, seed: u64) -> Vec<AudioWindow> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(per_class * NUM_AUDIO_CLASSES);
    for class in 0..NUM_AUDIO_CLASSES {
        for _ in 0..per_class {
            out.push(synth_window(class, &mut rng));
        }
    }
    out
}

/// A deterministic event schedule over a campaign horizon: alternating
/// ambient-noise spans and tonal events, seeded per device.
#[derive(Clone, Debug)]
pub struct AudioScript {
    /// `(class, start_time_secs)` segments, sorted by start time.
    pub segments: Vec<(usize, f64)>,
    pub duration: f64,
    seed: u64,
}

impl AudioScript {
    /// Ambient spans dwell 20–120 s; events last 5–30 s and mostly
    /// return to silence, occasionally chaining straight into another
    /// event (one class at a time — windows carry a single tone by
    /// construction).
    pub fn generate(duration: f64, seed: u64) -> AudioScript {
        let mut rng = Rng::new(seed ^ 0xA0D105EED);
        let mut segments = Vec::new();
        let mut t = 0.0;
        let mut current = 0usize; // scenes open on ambient noise
        while t < duration {
            segments.push((current, t));
            let dwell = if current == 0 {
                rng.range(20.0, 120.0)
            } else {
                rng.range(5.0, 30.0)
            };
            t += dwell;
            current = if current == 0 {
                1 + rng.index(NUM_AUDIO_CLASSES - 1)
            } else if rng.chance(0.7) {
                0
            } else {
                1 + rng.index(NUM_AUDIO_CLASSES - 1)
            };
        }
        AudioScript { segments, duration, seed }
    }

    /// Scene class at absolute time `t`.
    pub fn class_at(&self, t: f64) -> usize {
        match self.segments.binary_search_by(|(_, s)| s.partial_cmp(&t).unwrap()) {
            Ok(i) => self.segments[i].0,
            Err(0) => self.segments[0].0,
            Err(i) => self.segments[i - 1].0,
        }
    }

    /// The labelled window acquired at time `t` (deterministic in `t`,
    /// like `ActivityScript::window_at`).
    pub fn window_at(&self, t: f64) -> AudioWindow {
        let mut samples = Vec::new();
        let label = self.window_into(t, &mut samples);
        AudioWindow { samples, label }
    }

    /// [`AudioScript::window_at`] into a caller-owned sample buffer;
    /// returns the ground-truth label. Bitwise-identical samples, no
    /// allocation once the buffer has warmed.
    pub fn window_into(&self, t: f64, samples: &mut Vec<f64>) -> usize {
        let class = self.class_at(t);
        let mut rng = Rng::new(self.seed ^ (t * 1000.0) as u64);
        synth_window_into(class, &mut rng, samples);
        class
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_bounded_and_labelled() {
        let windows = labelled_windows(2, 3);
        assert_eq!(windows.len(), 2 * NUM_AUDIO_CLASSES);
        for w in &windows {
            assert_eq!(w.samples.len(), AUDIO_WINDOW_LEN);
            let bound = MAX_TONE_AMP + NOISE_AMP;
            assert!(w.samples.iter().all(|s| s.abs() <= bound));
            assert!(w.label < NUM_AUDIO_CLASSES);
        }
        // Silence windows stay inside the noise bound.
        for w in windows.iter().filter(|w| w.label == 0) {
            assert!(w.samples.iter().all(|s| s.abs() <= NOISE_AMP));
        }
    }

    #[test]
    fn script_is_deterministic_and_covers_the_horizon() {
        let a = AudioScript::generate(3600.0, 11);
        let b = AudioScript::generate(3600.0, 11);
        assert_eq!(a.segments, b.segments);
        assert!(!a.segments.is_empty());
        assert_eq!(a.class_at(0.0), a.segments[0].0);
        // window_at is reproducible sample for sample.
        let w1 = a.window_at(1234.0);
        let w2 = a.window_at(1234.0);
        assert_eq!(w1.samples, w2.samples);
        assert_eq!(w1.label, a.class_at(1234.0));
    }

    #[test]
    fn script_schedules_both_silence_and_events() {
        let s = AudioScript::generate(4.0 * 3600.0, 5);
        let classes: std::collections::HashSet<usize> =
            s.segments.iter().map(|&(c, _)| c).collect();
        assert!(classes.contains(&0), "no ambient spans");
        assert!(classes.len() >= 4, "only {} distinct classes", classes.len());
    }

    #[test]
    fn different_seeds_give_different_scenes() {
        let a = AudioScript::generate(1800.0, 1);
        let b = AudioScript::generate(1800.0, 2);
        assert_ne!(a.segments, b.segments);
    }
}
