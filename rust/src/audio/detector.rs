//! The anytime spectral detector: coarse-to-fine Goertzel probes plus a
//! threshold classifier.
//!
//! ## Refinement schedule
//!
//! The 128-point spectrum of a window has 63 usable interior bins
//! (`1..=63`; DC and Nyquist are excluded — a real sinusoid at the
//! Nyquist bin has phase-dependent energy). [`probe_schedule`] orders
//! them coarse-to-fine:
//!
//! | tier | probes | bins | cumulative steps |
//! |---|---|---|---|
//! | 0 | multiples of 8 | 8, 16, ..., 56 | 7 |
//! | 1 | remaining multiples of 4 | 4, 12, ..., 60 | 15 |
//! | 2 | remaining multiples of 2 | 2, 6, ..., 62 | 31 |
//! | 3 | odd bins | 1, 3, ..., 63 | 63 |
//!
//! Tier 0 is the coarse 8-band survey of the band; each later tier
//! halves the spectral stride until every bin of the full spectrum has
//! been probed. The tone bins of the event classes
//! ([`crate::audio::EVENT_BINS`]) are spread across the tiers, so every
//! tier makes new classes separable.
//!
//! ## Why accuracy is monotone in completed steps
//!
//! The synthetic streams ([`super::stream`]) build windows as bounded
//! uniform noise (amplitude ≤ [`NOISE_AMP`]) plus, for event windows, a
//! sinusoid at an exact integer bin with amplitude ≥ [`MIN_TONE_AMP`].
//! Two deterministic bounds follow for any window:
//!
//! * a noise-only probe can never exceed the detection threshold:
//!   `|X[k]| ≤ Σ|xᵢ| ≤ N·NOISE_AMP = 6.4`, power ≤ 41 <
//!   [`DETECT_POWER_THRESHOLD`];
//! * the tone's own bin always exceeds it: `|X[b]| ≥ A·N/2 − N·NOISE_AMP
//!   ≥ 38.4`, power ≥ 1474 — and an integer-bin sinusoid contributes
//!   *zero* to every other integer bin (DFT orthogonality), so no other
//!   probe can outrank it.
//!
//! Hence a window is classified correctly exactly when its tone bin has
//! been probed (silence windows are correct at every prefix), and the
//! probe set only grows — per-window correctness is monotone in the
//! step count, so detection accuracy over any stream is monotonically
//! non-decreasing in completed refinement steps.

use crate::audio::stream::AudioWindow;
use crate::audio::{EVENT_BINS, NUM_AUDIO_CLASSES, NUM_PROBES};
use crate::util::dsp::goertzel_power;

/// Amplitude bound of the ambient noise in the synthetic streams.
pub const NOISE_AMP: f64 = 0.05;

/// Minimum tone amplitude an event window carries.
pub const MIN_TONE_AMP: f64 = 0.7;

/// Power threshold separating "a tone lives in this bin" from noise.
/// Sits a factor ~6 above the worst-case noise power (41) and a factor
/// ~5.7 below the worst-case tone power (1474) — see the module docs.
pub const DETECT_POWER_THRESHOLD: f64 = 256.0;

/// The coarse-to-fine probe order over the interior bins `1..=63`.
pub fn probe_schedule() -> Vec<usize> {
    let mut order = Vec::with_capacity(NUM_PROBES);
    // Tier 0: stride 8 (the 8-band survey).
    order.extend((1..8).map(|i| 8 * i));
    // Tier 1: stride 4, skipping tier-0 bins.
    order.extend((0..8).map(|i| 4 + 8 * i));
    // Tier 2: stride 2, skipping coarser tiers.
    order.extend((0..16).map(|i| 2 + 4 * i));
    // Tier 3: the odd bins — full single-bin resolution.
    order.extend((0..32).map(|i| 1 + 2 * i));
    debug_assert_eq!(order.len(), NUM_PROBES);
    order
}

/// The anytime detector: probe order plus the detection threshold.
#[derive(Clone, Debug)]
pub struct SpectralDetector {
    /// Probe bins in refinement order (step `j` probes `schedule[j]`).
    pub schedule: Vec<usize>,
    /// Power threshold of the classifier.
    pub threshold: f64,
}

impl SpectralDetector {
    pub fn paper_default() -> SpectralDetector {
        SpectralDetector { schedule: probe_schedule(), threshold: DETECT_POWER_THRESHOLD }
    }

    /// Number of refinement steps a precise execution runs.
    pub fn num_probes(&self) -> usize {
        self.schedule.len()
    }

    /// Execute refinement step `j`: the Goertzel band-energy pass at the
    /// step's probe bin.
    pub fn probe(&self, window: &[f64], j: usize) -> f64 {
        goertzel_power(window, self.schedule[j])
    }

    /// Threshold classification from the probes completed so far
    /// (`powers[j]` is the step-`j` probe). Returns the event class, or
    /// 0 when no probe crosses the threshold.
    pub fn classify(&self, powers: &[f64]) -> usize {
        let mut best: Option<(usize, f64)> = None;
        for (j, &p) in powers.iter().enumerate() {
            let better = match best {
                None => p >= self.threshold,
                Some((_, bp)) => p >= self.threshold && p > bp,
            };
            if better {
                best = Some((self.schedule[j], p));
            }
        }
        match best {
            None => 0,
            Some((bin, _)) => {
                EVENT_BINS.iter().position(|&b| b == bin).map_or(0, |i| i + 1)
            }
        }
    }

    /// Convenience: classify a window using exactly `p` refinement steps.
    pub fn classify_with(&self, window: &[f64], p: usize) -> usize {
        let p = p.min(self.num_probes());
        let powers: Vec<f64> = (0..p).map(|j| self.probe(window, j)).collect();
        self.classify(&powers)
    }

    /// Expected detection accuracy per completed step count under a
    /// uniform class prior: `out[p] = (1 + detectable(p)) / 9`, where
    /// `detectable(p)` counts event bins among the first `p` probes.
    /// This is the offline curve SMART's lookup table is built from
    /// (the audio twin of the Eq. 7 analysis).
    pub fn expected_accuracy(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.num_probes() + 1);
        let mut detectable = 0usize;
        out.push(1.0 / NUM_AUDIO_CLASSES as f64);
        for &bin in &self.schedule {
            if EVENT_BINS.contains(&bin) {
                detectable += 1;
            }
            out.push((1 + detectable) as f64 / NUM_AUDIO_CLASSES as f64);
        }
        out
    }

    /// Measured detection accuracy for each prefix length in `ps` over a
    /// labelled window set (the audio twin of
    /// [`crate::svm::anytime::AnytimeSvm::accuracy_curve`]).
    pub fn accuracy_curve(&self, windows: &[AudioWindow], ps: &[usize]) -> Vec<f64> {
        let mut correct = vec![0usize; ps.len()];
        for w in windows {
            let powers: Vec<f64> =
                (0..self.num_probes()).map(|j| self.probe(&w.samples, j)).collect();
            for (pi, &p) in ps.iter().enumerate() {
                if self.classify(&powers[..p.min(powers.len())]) == w.label {
                    correct[pi] += 1;
                }
            }
        }
        correct.iter().map(|&c| c as f64 / windows.len().max(1) as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audio::stream::labelled_windows;

    #[test]
    fn schedule_covers_every_interior_bin_once() {
        let order = probe_schedule();
        assert_eq!(order.len(), NUM_PROBES);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (1..=63).collect::<Vec<_>>());
        // Coarse-to-fine: the first tier is the stride-8 survey.
        assert_eq!(&order[..7], &[8, 16, 24, 32, 40, 48, 56]);
    }

    #[test]
    fn event_bins_spread_across_all_tiers() {
        let order = probe_schedule();
        let pos = |bin: usize| order.iter().position(|&b| b == bin).unwrap();
        // Two classes resolve per tier (cumulative steps 7/15/31/63).
        let tiers = [0..7, 7..15, 15..31, 31..63];
        for (i, tier) in tiers.iter().enumerate() {
            let n = EVENT_BINS.iter().filter(|&&b| tier.contains(&pos(b))).count();
            assert_eq!(n, 2, "tier {i} holds {n} event bins");
        }
    }

    #[test]
    fn expected_accuracy_is_monotone_from_chance_to_one() {
        let d = SpectralDetector::paper_default();
        let acc = d.expected_accuracy();
        assert_eq!(acc.len(), NUM_PROBES + 1);
        assert!((acc[0] - 1.0 / 9.0).abs() < 1e-12);
        assert!((acc[NUM_PROBES] - 1.0).abs() < 1e-12);
        for p in 1..acc.len() {
            assert!(acc[p] >= acc[p - 1], "expected accuracy dipped at {p}");
        }
        // Tier boundaries: 3/9, 5/9, 7/9, 9/9.
        for (steps, want) in [(7usize, 3.0), (15, 5.0), (31, 7.0), (63, 9.0)] {
            assert!((acc[steps] - want / 9.0).abs() < 1e-12, "steps {steps}");
        }
    }

    #[test]
    fn measured_accuracy_matches_the_analytic_curve() {
        let d = SpectralDetector::paper_default();
        let windows = labelled_windows(4, 0xA0D10);
        let ps: Vec<usize> = (0..=NUM_PROBES).collect();
        let measured = d.accuracy_curve(&windows, &ps);
        let expected = d.expected_accuracy();
        // The deterministic margins make the analytic curve exact on a
        // class-balanced window set.
        for p in 0..=NUM_PROBES {
            assert!(
                (measured[p] - expected[p]).abs() < 1e-12,
                "p={p}: measured {} expected {}",
                measured[p],
                expected[p]
            );
        }
    }

    #[test]
    fn full_resolution_is_perfect_on_labelled_streams() {
        let d = SpectralDetector::paper_default();
        for w in labelled_windows(3, 7) {
            assert_eq!(d.classify_with(&w.samples, NUM_PROBES), w.label);
        }
    }

    #[test]
    fn noise_never_crosses_the_threshold() {
        let d = SpectralDetector::paper_default();
        for w in labelled_windows(6, 99).iter().filter(|w| w.label == 0) {
            let worst = (0..NUM_PROBES)
                .map(|j| d.probe(&w.samples, j))
                .fold(0.0f64, f64::max);
            assert!(
                worst < DETECT_POWER_THRESHOLD,
                "noise probe reached {worst}"
            );
        }
    }
}
